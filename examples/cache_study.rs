//! Cache behaviour study: threshold sweep on a live router — the §6.1
//! "Practical Considerations and Parameter Tuning" experiment.
//!
//! For each similarity threshold, replays the same workload through a fresh
//! router (real embedder + vector DB; mock generation so the sweep is fast)
//! and reports hit rate, estimated quality of tweaked responses (quality
//! model over the measured similarities + intent ground truth), and cost —
//! the three-way trade-off the threshold knob controls.
//!
//! Run: `cargo run --release --example cache_study -- --n 600`

use tweakllm::baselines::MockLlm;
use tweakllm::bench::Table;
use tweakllm::config::Config;
use tweakllm::coordinator::{Pathway, Router};
use tweakllm::datasets::{ChatTrace, TraceProfile};
use tweakllm::eval::quality::QualityModel;
use tweakllm::runtime::{Embedder, Runtime, TextEmbedder};
use tweakllm::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.usize("n", 600)?;
    let seed = args.u64("seed", 20250923)?;

    eprintln!("[cache_study] loading artifacts...");
    let rt = Runtime::load("artifacts", &[])?;
    let trace = ChatTrace::generate(TraceProfile::lmsys(), n, seed);
    // text -> intent lookup for the quality model
    let intent_of: std::collections::HashMap<&str, _> =
        trace.queries.iter().map(|q| (q.text.as_str(), q.intent)).collect();

    let mut table = Table::new(
        "Threshold sweep — hit rate vs tweak quality vs cost (LMSYS-like)",
        &["τ", "hit %", "exact %", "tweak quality", "big quality", "cost %"],
    );

    for tau in [0.6f32, 0.7, 0.75, 0.8, 0.85, 0.9, 0.95] {
        let mut cfg = Config::paper();
        cfg.similarity_threshold = tau;
        cfg.exact_match_fast_path = true;
        // Mock models: the sweep needs routing + similarity, not tokens.
        let embedder: Box<dyn TextEmbedder> = Box::new(Embedder::new(&rt)?);
        let mut router = Router::with_models(
            embedder,
            Box::new(MockLlm::new("big")),
            Box::new(MockLlm::new("small")),
            cfg,
        );
        let mut qm = QualityModel::new(seed ^ tau.to_bits() as u64);
        let mut tweak_q = Vec::new();
        let mut big_q = Vec::new();
        for q in &trace.queries {
            let r = router.handle(&q.text)?;
            match r.pathway {
                Pathway::TweakHit => {
                    let cached_intent = r
                        .cached_query
                        .as_deref()
                        .and_then(|cq| intent_of.get(cq))
                        .copied();
                    let new_intent = q.intent;
                    let quality = match cached_intent {
                        Some(ci) => qm.small_tweaked(
                            r.similarity.unwrap_or(0.7),
                            Some((&new_intent, &ci)),
                        ),
                        None => qm.small_tweaked(r.similarity.unwrap_or(0.7), None),
                    };
                    tweak_q.push(quality.mean());
                }
                Pathway::Miss => big_q.push(qm.big_direct().mean()),
                Pathway::ExactHit => {}
            }
        }
        let c = &router.counters;
        let total = c.get("requests").max(1);
        let mean = |v: &[f64]| {
            if v.is_empty() { f64::NAN } else { v.iter().sum::<f64>() / v.len() as f64 }
        };
        table.push(vec![
            format!("{tau:.2}"),
            format!("{:.1}", 100.0 * (c.get("tweak_hits") + c.get("exact_hits")) as f64 / total as f64),
            format!("{:.1}", 100.0 * c.get("exact_hits") as f64 / total as f64),
            format!("{:.3}", mean(&tweak_q)),
            format!("{:.3}", mean(&big_q)),
            format!(
                "{:.1}",
                100.0 * router.ledger.dollars(&router.config.cost)
                    / router.ledger.baseline_dollars(&router.config.cost).max(1e-12)
            ),
        ]);
        eprintln!("[cache_study] τ={tau:.2} done ({} entries cached)", router.cache().len());
    }
    println!("{}", table.render());
    println!(
        "reading: lower τ buys hit-rate (cost ↓) at the price of lower tweak \
         quality — §6.1's trade-off. Exact hits are free at any τ."
    );
    Ok(())
}
