//! Per-stage runtime profiler (the §Perf L2/L3 measurement tool).
//!
//! Times each compiled artifact in isolation — embed variants, prefill,
//! single decode steps — separating literal-construction cost from
//! execute cost, so EXPERIMENTS.md §Perf can attribute the budget.
//!
//! Run: `cargo run --release --example profile_runtime [--steps 16]`

use anyhow::Result;
use tweakllm::runtime::{HostTensor, Runtime, SamplingParams, TextEmbedder};
use tweakllm::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps = args.usize("steps", 16)?;
    let dir = args.str("artifacts", "artifacts");
    let rt = Runtime::load(&dir, &[])?;
    println!("platform: {}", rt.platform());

    // --- embed variants ---
    let embedder = tweakllm::runtime::Embedder::new(&rt)?;
    for b in [1usize, 8, 32] {
        let texts: Vec<String> =
            (0..b).map(|i| format!("why is topic {i} good for benchmarking?")).collect();
        let views: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
        // warmup
        embedder.embed_batch(&views)?;
        let t = std::time::Instant::now();
        let reps = 5;
        for _ in 0..reps {
            embedder.embed_batch(&views)?;
        }
        let per = t.elapsed() / (reps * b as u32);
        println!("embed_b{b:<3}      per-text: {per:?}");
    }

    // --- decoders ---
    for model in ["small", "big"] {
        let prefill = rt.executable(&format!("{model}_prefill"))?;
        let decode = rt.executable(&format!("{model}_decode"))?;
        let spec = rt.manifest.model(model)?;
        let max_prefill = spec.cfg("max_prefill")?;

        let mut ids = vec![0i32; max_prefill];
        for (i, t) in ids.iter_mut().enumerate().take(24) {
            *t = 5 + (i as i32 * 37) % 8000;
        }
        let tok = HostTensor::i32(ids.clone(), &[max_prefill]);
        let len = HostTensor::i32(vec![24], &[1]);

        // prefill timing
        let t = std::time::Instant::now();
        let outs = prefill.run(&[tok.clone(), len.clone()])?;
        let prefill_cold = t.elapsed();
        let t = std::time::Instant::now();
        let outs2 = prefill.run(&[tok, len])?;
        let prefill_warm = t.elapsed();
        drop(outs2);
        println!("{model}_prefill   cold: {prefill_cold:?}  warm: {prefill_warm:?}");

        let kv_spec = decode.spec.inputs[2].clone();
        let mut it = outs.into_iter();
        let _logits = it.next().unwrap();
        let mut k = HostTensor::from_literal(&it.next().unwrap(), &kv_spec)?;
        let mut v = HostTensor::from_literal(&it.next().unwrap(), &kv_spec)?;

        // decode-step timing, split into literal prep vs execute
        let mut exec_total = std::time::Duration::ZERO;
        let t_all = std::time::Instant::now();
        for s in 0..steps {
            let tokl = HostTensor::i32(vec![100 + s as i32], &[1]);
            let posl = HostTensor::i32(vec![24 + s as i32], &[1]);
            let te = std::time::Instant::now();
            let inputs = [tokl, posl, k, v];
            let mut outs = decode.run(&inputs)?;
            exec_total += te.elapsed();
            v = HostTensor::from_literal(&outs.pop().unwrap(), &kv_spec)?;
            k = HostTensor::from_literal(&outs.pop().unwrap(), &kv_spec)?;
        }
        let total = t_all.elapsed();
        println!(
            "{model}_decode    per-step total: {:?}  (execute+fetch: {:?})",
            total / steps as u32,
            exec_total / steps as u32
        );
    }

    // --- full generate through the facade: literal vs device-resident ---
    // Same seed per transport so the token streams (and thus the work done)
    // are identical; only the KV transport differs.
    for model in ["small", "big"] {
        let g = tweakllm::runtime::Generator::new(&rt, model)?;
        let params = SamplingParams { max_new_tokens: steps, ..Default::default() };
        for (label, resident) in [("literal ", false), ("resident", true)] {
            if resident && !g.resident_available() {
                println!(
                    "{model} generate [resident] skipped: artifact set predates \
                     device-resident decode (re-run `make artifacts`)"
                );
                continue;
            }
            let mut rng = tweakllm::util::Rng::new(1);
            let t = std::time::Instant::now();
            let gen =
                g.generate_on(&["profile this prompt please"], &params, &mut rng, resident)?;
            let decode_s = gen.stats.decode_micros as f64 / 1e6;
            let tok_per_s = if decode_s > 0.0 {
                gen.stats.generated_tokens as f64 / decode_s
            } else {
                0.0
            };
            println!(
                "{model} generate [{label}] {} tok in {:?}  (prefill {}us, decode {}us, {:.1} tok/s)",
                gen.stats.generated_tokens,
                t.elapsed(),
                gen.stats.prefill_micros,
                gen.stats.decode_micros,
                tok_per_s
            );
        }
    }
    Ok(())
}
