//! SSE streaming client exemplar for the OpenAI-compatible front end —
//! plus a mock-backed `--serve` mode so the whole loop runs without
//! compiled artifacts (CI smoke uses it).
//!
//! Serve (mock models, no artifacts):
//!   cargo run --release --example stream_chat -- --serve --http-port 7412
//!
//! Stream a completion (prints deltas as they arrive + a TTFT summary):
//!   cargo run --release --example stream_chat -- \
//!       --addr 127.0.0.1:7412 "why do cats purr so much?"
//!
//! The same endpoint answers curl:
//!   curl -N http://127.0.0.1:7412/v1/chat/completions \
//!     -d '{"stream":true,"messages":[{"role":"user","content":"hi"}]}'

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use tweakllm::baselines::MockLlm;
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Engine, Router};
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::server::HttpServer;
use tweakllm::util::{Args, Json};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env();
    if args.has("serve") {
        return serve(&args);
    }
    let addr = args.str("addr", "127.0.0.1:7412");
    let prompt = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "why is coffee good for health?".to_string());
    stream_once(&addr, &prompt)
}

/// Mock-backed engine + HTTP front end: the CI smoke target. Paced decode
/// so streaming is observable, deterministic text so reruns compare.
fn serve(args: &Args) -> Result<()> {
    let port = args.usize("http-port", 7412)?;
    let (_engine, handle) = Engine::start(|| {
        let mut cfg = Config::paper();
        cfg.index.kind = IndexKindConfig::Flat;
        cfg.exact_match_fast_path = true;
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        let big = MockLlm::new("big").with_pace(16, std::time::Duration::from_millis(5));
        let small = MockLlm::new("small").with_pace(8, std::time::Duration::from_millis(5));
        Ok(Router::with_models(embedder, Box::new(big), Box::new(small), cfg))
    })?;
    let http = HttpServer::bind(&format!("127.0.0.1:{port}"), handle)?;
    println!(
        "listening on http://{}/v1/chat/completions (mock models)",
        http.local_addr()?
    );
    http.serve()
}

/// POST one streamed completion and print deltas as they arrive.
fn stream_once(addr: &str, prompt: &str) -> Result<()> {
    let body = Json::obj_from(vec![
        ("model", Json::s("tweakllm")),
        ("stream", Json::Bool(true)),
        (
            "messages",
            Json::Arr(vec![Json::obj_from(vec![
                ("role", Json::s("user")),
                ("content", Json::s(prompt)),
            ])]),
        ),
    ])
    .to_string();
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.set_nodelay(true).ok();
    write!(
        stream,
        "POST /v1/chat/completions HTTP/1.1\r\nHost: {addr}\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;

    let t0 = Instant::now();
    let mut ttft = None;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    // Status line + headers (the server closes the connection at [DONE]).
    reader.read_line(&mut line)?;
    if !line.starts_with("HTTP/1.1 200") {
        bail!("server answered {}", line.trim_end());
    }
    while reader.read_line(&mut line)? > 0 {
        if line == "\r\n" || line == "\n" {
            break;
        }
        line.clear();
    }

    let mut out = std::io::stdout();
    line.clear();
    while reader.read_line(&mut line)? > 0 {
        let payload = line.trim_end();
        line.clear();
        let Some(payload) = payload.strip_prefix("data: ") else {
            continue; // SSE comments (keepalives) and blank separators
        };
        if payload == "[DONE]" {
            break;
        }
        let chunk = Json::parse(payload)?;
        if let Some(err) = chunk.opt("error") {
            bail!("stream error: {}", err.get("message")?.str()?);
        }
        let choice = &chunk.get("choices")?.arr()?[0];
        if let Some(delta) = choice.get("delta")?.opt("content") {
            if ttft.is_none() {
                ttft = Some(t0.elapsed());
            }
            out.write_all(delta.str()?.as_bytes())?;
            out.flush()?;
        }
        if choice.opt("finish_reason").is_some() {
            let ext = chunk.get("tweakllm")?;
            let sim = ext
                .opt("similarity")
                .map(|s| format!("{:.3}", s.f64().unwrap_or(0.0)))
                .unwrap_or_else(|| "-".into());
            println!(
                "\n--\npathway={} similarity={sim} trace_id={} ttft={:.1}ms total={:.1}ms",
                ext.get("pathway")?.str()?,
                ext.get("trace_id")?.usize()?,
                ttft.unwrap_or_default().as_secs_f64() * 1e3,
                t0.elapsed().as_secs_f64() * 1e3,
            );
        }
    }
    Ok(())
}
