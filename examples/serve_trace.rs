//! End-to-end serving driver (the EXPERIMENTS.md validation run).
//!
//! Starts the full stack — engine thread owning the PJRT runtime, dynamic
//! batcher, TCP front-end — then replays an LMSYS-like workload through
//! real sockets with several concurrent client threads, and reports
//! latency/throughput/hit-rate/cost. All three layers compose here:
//! L1 Pallas kernels inside the L2 HLO programs, driven by the L3 router.
//!
//! Run: `cargo run --release --example serve_trace -- --requests 64 --clients 4`
//! `--show-traces N` (default 4) prints per-request stage waterfalls pulled
//! from the server's `{"admin": "trace"}` verb after the run.

use std::sync::{Arc, Mutex};

use tweakllm::config::Config;
use tweakllm::coordinator::{Engine, Router};
use tweakllm::datasets::{ChatTrace, TraceProfile};
use tweakllm::runtime::Runtime;
use tweakllm::server::{Client, Server};
use tweakllm::util::{Args, Summary};

/// Render one trace (the `trace` verb's JSON) as an aligned stage waterfall:
/// one row per span, bar offset/width proportional to its slice of total_us.
fn print_waterfall(t: &tweakllm::util::Json) {
    const COLS: usize = 48;
    let f = |key: &str| t.opt(key).and_then(|v| v.f64().ok()).unwrap_or(0.0);
    let total = f("total_us").max(1.0);
    let query = t.opt("query").and_then(|q| q.str().ok()).unwrap_or("?");
    let pathway = t.opt("pathway").and_then(|p| p.str().ok()).unwrap_or("?");
    let sim = t
        .opt("similarity")
        .and_then(|s| s.f64().ok())
        .map(|s| format!("{s:.3}"))
        .unwrap_or_else(|| "-".into());
    println!(
        "  #{} {pathway} sim={sim} total={:.1}ms rounds={} \"{}\"",
        f("id"),
        total / 1e3,
        f("decode_rounds"),
        &query[..query.len().min(48)]
    );
    let spans = match t.opt("spans").and_then(|s| s.arr().ok()) {
        Some(s) => s,
        None => return,
    };
    let mut rounds_shown = 0usize;
    for s in spans {
        let stage = s.opt("stage").and_then(|v| v.str().ok()).unwrap_or("?");
        if stage == "decode_round" {
            // one sample row is enough; the rest would swamp the waterfall
            rounds_shown += 1;
            if rounds_shown > 1 {
                continue;
            }
        }
        let start = s.opt("start_us").and_then(|v| v.f64().ok()).unwrap_or(0.0);
        let end = s.opt("end_us").and_then(|v| v.f64().ok()).unwrap_or(start);
        let lo = ((start / total) * COLS as f64) as usize;
        let hi = (((end / total) * COLS as f64).ceil() as usize).clamp(lo + 1, COLS);
        let mut bar = String::with_capacity(COLS);
        for i in 0..COLS {
            bar.push(if i >= lo && i < hi { '#' } else { '.' });
        }
        let indent = if stage == "decode_round" { "  " } else { "" };
        println!(
            "    {indent}{:<14} |{bar}| {:>9.1}us",
            stage,
            end - start
        );
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_requests = args.usize("requests", 64)?;
    let n_clients = args.usize("clients", 4)?;
    let max_new = args.usize("max-new", 16)?;

    // --- engine + server ---
    let mut cfg = Config::paper();
    cfg.exact_match_fast_path = true;
    cfg.big_llm.max_new_tokens = max_new;
    cfg.small_llm.max_new_tokens = max_new;
    let artifact_dir = cfg.artifact_dir.clone();
    eprintln!("[serve_trace] starting engine (artifacts: {artifact_dir})...");
    let (engine, handle) = Engine::start(move || {
        let rt = Runtime::load(&artifact_dir, &[])?;
        eprintln!("[serve_trace] engine up on platform {}", rt.platform());
        Router::from_runtime(&rt, cfg)
    })?;
    let server = Server::bind("127.0.0.1:0", handle.clone())?;
    let addr = server.local_addr()?.to_string();
    let stop = server.shutdown_handle()?;
    let server_thread = std::thread::spawn(move || server.serve());
    eprintln!("[serve_trace] listening on {addr}");

    // --- workload ---
    let trace = ChatTrace::generate(TraceProfile::lmsys(), n_requests, 20250923);
    let work: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(
        trace.queries.iter().rev().map(|q| q.text.clone()).collect(),
    ));

    // --- concurrent clients over real sockets ---
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let work = Arc::clone(&work);
        let addr = addr.clone();
        joins.push(std::thread::spawn(
            move || -> anyhow::Result<Vec<(String, f64)>> {
                let mut client = Client::connect(&addr)?;
                let mut out = Vec::new();
                loop {
                    let q = match work.lock().unwrap().pop() {
                        Some(q) => q,
                        None => break,
                    };
                    let t = std::time::Instant::now();
                    let resp = client.query(&q)?;
                    let ms = t.elapsed().as_secs_f64() * 1e3;
                    let pathway = resp
                        .opt("pathway")
                        .and_then(|p| p.str().ok())
                        .unwrap_or("error")
                        .to_string();
                    if pathway == "error" {
                        eprintln!("[client {c}] error: {}", resp.to_string());
                    }
                    out.push((pathway, ms));
                }
                Ok(out)
            },
        ));
    }
    let mut by_path: std::collections::HashMap<String, Vec<f64>> = Default::default();
    let mut total = 0usize;
    for j in joins {
        for (p, ms) in j.join().unwrap()? {
            by_path.entry(p).or_default().push(ms);
            total += 1;
        }
    }
    let wall = t0.elapsed();

    // --- report ---
    println!("\n=== serve_trace report ===");
    println!(
        "requests: {total}  clients: {n_clients}  wall: {:.2}s  throughput: {:.2} req/s",
        wall.as_secs_f64(),
        total as f64 / wall.as_secs_f64()
    );
    for (path, samples) in &by_path {
        let s = Summary::of(samples);
        println!(
            "  {path:<10} n={:<4} mean={:>8.1}ms p50={:>8.1}ms p99={:>8.1}ms",
            s.n, s.mean, s.p50, s.p99
        );
    }
    let stats = handle.stats()?;
    println!(
        "hit rate: {:.1}%  cache: {} entries  mean embed batch: {:.2}",
        100.0 * (stats.tweak_hits + stats.exact_hits) as f64
            / stats.requests.max(1) as f64,
        stats.cache_size,
        stats.mean_batch_size,
    );
    println!(
        "cost: ${:.6} vs all-Big ${:.6} -> {:.1}% of baseline",
        stats.cost_dollars,
        stats.baseline_dollars,
        100.0 * stats.cost_dollars / stats.baseline_dollars.max(1e-12)
    );
    println!("\nengine stage latency:\n{}", stats.latency_table);

    // --- per-request stage waterfalls from the trace verb ---
    let n_show = args.usize("show-traces", 4)?;
    if n_show > 0 {
        let mut client = Client::connect(&addr)?;
        let report = client.trace(n_show)?;
        println!(
            "\nper-request span traces (last {n_show} of {} finished):",
            report.opt("finished").and_then(|v| v.f64().ok()).unwrap_or(0.0)
        );
        if let Some(traces) = report.opt("traces").and_then(|t| t.arr().ok()) {
            for t in traces {
                print_waterfall(t);
            }
        }
    }

    stop.signal();
    let _ = server_thread.join();
    engine.shutdown();
    Ok(())
}
