//! End-to-end serving driver (the EXPERIMENTS.md validation run).
//!
//! Starts the full stack — engine thread owning the PJRT runtime, dynamic
//! batcher, TCP front-end — then replays an LMSYS-like workload through
//! real sockets with several concurrent client threads, and reports
//! latency/throughput/hit-rate/cost. All three layers compose here:
//! L1 Pallas kernels inside the L2 HLO programs, driven by the L3 router.
//!
//! Run: `cargo run --release --example serve_trace -- --requests 64 --clients 4`

use std::sync::{Arc, Mutex};

use tweakllm::config::Config;
use tweakllm::coordinator::{Engine, Router};
use tweakllm::datasets::{ChatTrace, TraceProfile};
use tweakllm::runtime::Runtime;
use tweakllm::server::{Client, Server};
use tweakllm::util::{Args, Summary};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_requests = args.usize("requests", 64)?;
    let n_clients = args.usize("clients", 4)?;
    let max_new = args.usize("max-new", 16)?;

    // --- engine + server ---
    let mut cfg = Config::paper();
    cfg.exact_match_fast_path = true;
    cfg.big_llm.max_new_tokens = max_new;
    cfg.small_llm.max_new_tokens = max_new;
    let artifact_dir = cfg.artifact_dir.clone();
    eprintln!("[serve_trace] starting engine (artifacts: {artifact_dir})...");
    let (engine, handle) = Engine::start(move || {
        let rt = Runtime::load(&artifact_dir, &[])?;
        eprintln!("[serve_trace] engine up on platform {}", rt.platform());
        Router::from_runtime(&rt, cfg)
    })?;
    let server = Server::bind("127.0.0.1:0", handle.clone())?;
    let addr = server.local_addr()?.to_string();
    let stop = server.shutdown_handle()?;
    let server_thread = std::thread::spawn(move || server.serve());
    eprintln!("[serve_trace] listening on {addr}");

    // --- workload ---
    let trace = ChatTrace::generate(TraceProfile::lmsys(), n_requests, 20250923);
    let work: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(
        trace.queries.iter().rev().map(|q| q.text.clone()).collect(),
    ));

    // --- concurrent clients over real sockets ---
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let work = Arc::clone(&work);
        let addr = addr.clone();
        joins.push(std::thread::spawn(
            move || -> anyhow::Result<Vec<(String, f64)>> {
                let mut client = Client::connect(&addr)?;
                let mut out = Vec::new();
                loop {
                    let q = match work.lock().unwrap().pop() {
                        Some(q) => q,
                        None => break,
                    };
                    let t = std::time::Instant::now();
                    let resp = client.query(&q)?;
                    let ms = t.elapsed().as_secs_f64() * 1e3;
                    let pathway = resp
                        .opt("pathway")
                        .and_then(|p| p.str().ok())
                        .unwrap_or("error")
                        .to_string();
                    if pathway == "error" {
                        eprintln!("[client {c}] error: {}", resp.to_string());
                    }
                    out.push((pathway, ms));
                }
                Ok(out)
            },
        ));
    }
    let mut by_path: std::collections::HashMap<String, Vec<f64>> = Default::default();
    let mut total = 0usize;
    for j in joins {
        for (p, ms) in j.join().unwrap()? {
            by_path.entry(p).or_default().push(ms);
            total += 1;
        }
    }
    let wall = t0.elapsed();

    // --- report ---
    println!("\n=== serve_trace report ===");
    println!(
        "requests: {total}  clients: {n_clients}  wall: {:.2}s  throughput: {:.2} req/s",
        wall.as_secs_f64(),
        total as f64 / wall.as_secs_f64()
    );
    for (path, samples) in &by_path {
        let s = Summary::of(samples);
        println!(
            "  {path:<10} n={:<4} mean={:>8.1}ms p50={:>8.1}ms p99={:>8.1}ms",
            s.n, s.mean, s.p50, s.p99
        );
    }
    let stats = handle.stats()?;
    println!(
        "hit rate: {:.1}%  cache: {} entries  mean embed batch: {:.2}",
        100.0 * (stats.tweak_hits + stats.exact_hits) as f64
            / stats.requests.max(1) as f64,
        stats.cache_size,
        stats.mean_batch_size,
    );
    println!(
        "cost: ${:.6} vs all-Big ${:.6} -> {:.1}% of baseline",
        stats.cost_dollars,
        stats.baseline_dollars,
        100.0 * stats.cost_dollars / stats.baseline_dollars.max(1e-12)
    );
    println!("\nengine stage latency:\n{}", stats.latency_table);

    stop.signal();
    let _ = server_thread.join();
    engine.shutdown();
    Ok(())
}
