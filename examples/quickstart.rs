//! Quickstart: the smallest complete TweakLLM program.
//!
//! Loads the compiled artifacts, builds a router with the paper's Table-1
//! configuration, sends a few queries, and shows the three pathways
//! (miss → Big LLM; semantic hit → Small LLM tweak; exact hit → verbatim).
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use tweakllm::config::Config;
use tweakllm::coordinator::{Pathway, Router};
use tweakllm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1) Load the AOT artifacts (HLO text + weights) onto the PJRT CPU client.
    let mut cfg = Config::paper();
    cfg.exact_match_fast_path = true; // §6.1 optimization
    cfg.big_llm.max_new_tokens = 16; // keep the demo snappy
    cfg.small_llm.max_new_tokens = 16;
    let rt = Runtime::load(&cfg.artifact_dir, &[])?;
    println!("loaded PJRT platform: {}", rt.platform());

    // 2) Build the Figure-1 router: embedder + vector DB + Big/Small LLMs.
    let mut router = Router::from_runtime(&rt, cfg)?;

    // 3) Serve queries.
    let queries = [
        "why is coffee good for health?",                   // cold: miss -> Big
        "can you explain why coffee is good for health?",   // paraphrase: tweak
        "why is coffee good for health?",                   // identical: exact
        "draft an email asking my landlord about parking",  // unrelated: miss
    ];
    for q in queries {
        let r = router.handle(q)?;
        let pathway = match r.pathway {
            Pathway::Miss => "MISS  -> Big LLM",
            Pathway::TweakHit => "HIT   -> Small LLM tweak",
            Pathway::ExactHit => "EXACT -> cached verbatim",
        };
        println!(
            "\nquery:      {q}\npathway:    {pathway}\nsimilarity: {}\nlatency:    {:.1} ms\nresponse:   {}",
            r.similarity.map(|s| format!("{s:.3}")).unwrap_or_else(|| "-".into()),
            r.total_micros as f64 / 1000.0,
            &r.text[..r.text.len().min(72)],
        );
    }

    // 4) Inspect the economics.
    let cost = router.ledger.dollars(&router.config.cost);
    let base = router.ledger.baseline_dollars(&router.config.cost);
    println!(
        "\ncache entries: {}  |  hit rate: {:.0}%  |  cost vs all-Big: {:.0}%",
        router.cache().len(),
        router.hit_rate() * 100.0,
        100.0 * cost / base.max(1e-12),
    );
    Ok(())
}
