"""AOT pipeline: manifest structure + a real lower-to-HLO-text round trip
(compile the text back through XLA via jax's CPU client to prove the
artifact is loadable — the same thing the Rust runtime does)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_roundtrip(tmp_path):
    # Lower a tiny fn, then parse the text back and re-execute through the
    # jax CPU backend -- validates the interchange format end to end.
    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "parameter(0)" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACT_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_all_artifacts_present(self, manifest):
        names = {a["name"] for a in manifest["artifacts"]}
        expected = {"small_prefill", "small_decode", "big_prefill", "big_decode"}
        expected |= {f"embed_b{b}" for b in configs.EMBED_BATCH_SIZES}
        expected.add(f"cosine_scores_b{configs.COSINE_DB_BLOCK}")
        assert expected <= names
        for a in manifest["artifacts"]:
            assert os.path.exists(os.path.join(ARTIFACT_DIR, a["file"]))

    def test_weight_files_match_tensor_index(self, manifest):
        for mname, m in manifest["models"].items():
            path = os.path.join(ARTIFACT_DIR, m["weights_file"])
            size = os.path.getsize(path)
            expect = sum(t["numel"] for t in m["tensors"]) * 4
            assert size == expect, mname

    def test_weight_args_match_tensor_count(self, manifest):
        models = manifest["models"]
        for a in manifest["artifacts"]:
            if a["weight_set"]:
                assert a["n_weight_args"] == len(models[a["weight_set"]]["tensors"])

    def test_io_shapes_sane(self, manifest):
        for a in manifest["artifacts"]:
            for io in a["inputs"] + a["outputs"]:
                assert all(d > 0 for d in io["shape"])
                assert io["dtype"] in ("float32", "int32")

    def test_decode_io_symmetry(self, manifest):
        # decode consumes and produces identically-shaped caches (the Rust
        # generator feeds outputs straight back in).
        by_name = {a["name"]: a for a in manifest["artifacts"]}
        for m in ("small", "big"):
            d = by_name[f"{m}_decode"]
            ins = {i["name"]: i["shape"] for i in d["inputs"]}
            outs = {o["name"]: o["shape"] for o in d["outputs"]}
            assert ins["k_cache"] == outs["k_cache"]
            assert ins["v_cache"] == outs["v_cache"]

    def test_special_tokens(self, manifest):
        st = manifest["special_tokens"]
        assert st["pad"] == 0 and st["first_word"] > st["unk"]
