"""Resume-capable prefill (cross-request KV prefix reuse) bit-identity gate.

A resumed prefill — cached packed state supplying K/V[:, :P], suffix rows
recomputed — must reproduce the cold ``prefill_resident`` packed state *bit
for bit*, for every compiled PREFIX_CHUNKS boundary, on both the kernel and
oracle paths, eager and jitted (the artifacts are jitted kernels). The donor
state may come from a prompt of a *different* length and suffix, as long as
the first P tokens match: causal masking makes cached prefix rows
independent of the donor's continuation, which is what makes a cross-request
cache sound.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, params


@pytest.fixture(scope="module")
def small_llm():
    cfg = configs.SMALL_LLM
    specs = params.decoder_param_specs(cfg)
    ps = params.init_decoder(cfg)
    names = params.param_names(specs)
    return cfg, [jnp.asarray(ps[n]) for n in names], names


def _prompt(cfg, n, seed=0, prefix=None):
    """Random n-token prompt; `prefix` (np array) pins the leading tokens."""
    rng = np.random.default_rng(seed)
    toks = np.zeros((cfg.max_prefill,), np.int32)
    toks[:n] = rng.integers(configs.FIRST_WORD_ID, cfg.vocab_size, n)
    if prefix is not None:
        toks[: len(prefix)] = prefix
    return jnp.asarray(toks), jnp.asarray([n], jnp.int32)


def _donor_and_target(cfg, pre, donor_len, target_len):
    """Two prompts sharing exactly the first `pre` tokens."""
    donor, d_len = _prompt(cfg, donor_len, seed=1)
    shared = np.asarray(donor[:pre])
    target, t_len = _prompt(cfg, target_len, seed=2, prefix=shared)
    assert not np.array_equal(
        np.asarray(donor[: min(donor_len, target_len)]),
        np.asarray(target[: min(donor_len, target_len)]),
    ), "suffixes must differ for the test to mean anything"
    return donor, d_len, target, t_len


class TestPrefillResume:
    @pytest.mark.parametrize("pre", configs.PREFIX_CHUNKS)
    @pytest.mark.parametrize("use_kernels", [True, False], ids=["kernels", "oracle"])
    def test_resume_matches_cold_bitwise(self, small_llm, pre, use_kernels):
        cfg, plist, names = small_llm
        donor, d_len, target, t_len = _donor_and_target(cfg, pre, 150, 170)
        donor_state = model.prefill_resident(
            cfg, plist, names, donor, d_len, use_kernels
        )
        cold = model.prefill_resident(cfg, plist, names, target, t_len, use_kernels)
        resumed = model.prefill_resume(
            cfg, plist, names, target, t_len, donor_state, pre, use_kernels
        )
        np.testing.assert_array_equal(np.asarray(resumed), np.asarray(cold))

    @pytest.mark.parametrize("pre", configs.PREFIX_CHUNKS)
    def test_resume_matches_cold_jitted_kernels(self, small_llm, pre):
        # The artifact configuration: jit + kernels. This is the lowering
        # that aot.py ships, so bit-identity here is the real gate.
        cfg, plist, names = small_llm
        donor, d_len, target, t_len = _donor_and_target(cfg, pre, 140, 180)
        cold_fn = jax.jit(
            lambda t, n: model.prefill_resident(cfg, plist, names, t, n, True)
        )
        res_fn = jax.jit(
            lambda t, n, s: model.prefill_resume(
                cfg, plist, names, t, n, s, pre, True
            )
        )
        donor_state = cold_fn(donor, d_len)
        cold = cold_fn(target, t_len)
        resumed = res_fn(target, t_len, donor_state)
        np.testing.assert_array_equal(np.asarray(resumed), np.asarray(cold))

    def test_donor_shorter_than_target_prefix_chunk_still_exact(self, small_llm):
        # Donor barely longer than the chunk boundary; target much longer.
        cfg, plist, names = small_llm
        pre = configs.PREFIX_CHUNKS[0]
        donor, d_len, target, t_len = _donor_and_target(cfg, pre, pre + 3, 190)
        donor_state = model.prefill_resident(
            cfg, plist, names, donor, d_len, use_kernels=False
        )
        cold = model.prefill_resident(
            cfg, plist, names, target, t_len, use_kernels=False
        )
        resumed = model.prefill_resume(
            cfg, plist, names, target, t_len, donor_state, pre, use_kernels=False
        )
        np.testing.assert_array_equal(np.asarray(resumed), np.asarray(cold))

    def test_resumed_state_decodes_identically(self, small_llm):
        # End-to-end: a decode step from the resumed state equals one from
        # the cold state (trivially implied by state equality, but this is
        # the property the Rust engine-level gate depends on).
        cfg, plist, names = small_llm
        pre = configs.PREFIX_CHUNKS[1]
        donor, d_len, target, t_len = _donor_and_target(cfg, pre, 160, 170)
        donor_state = model.prefill_resident(
            cfg, plist, names, donor, d_len, use_kernels=False
        )
        cold = model.prefill_resident(
            cfg, plist, names, target, t_len, use_kernels=False
        )
        resumed = model.prefill_resume(
            cfg, plist, names, target, t_len, donor_state, pre, use_kernels=False
        )
        tok = jnp.asarray([77], jnp.int32)
        pos = t_len
        a = model.decode_step_resident(
            cfg, plist, names, tok, pos, cold, use_kernels=False
        )
        b = model.decode_step_resident(
            cfg, plist, names, tok, pos, resumed, use_kernels=False
        )
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_rejects_out_of_range_prefix(self, small_llm):
        cfg, plist, names = small_llm
        toks, ln = _prompt(cfg, 100)
        state = jnp.zeros((model.state_len(cfg),), jnp.float32)
        with pytest.raises(ValueError):
            model.prefill_resume(
                cfg, plist, names, toks, ln, state, cfg.max_prefill
            )


class TestScatterResume:
    B = 3

    def test_scatter_resume_places_one_slot(self, small_llm):
        cfg, plist, names = small_llm
        sl = model.state_len(cfg)
        pre = configs.PREFIX_CHUNKS[0]
        donor, d_len, target, t_len = _donor_and_target(cfg, pre, 130, 150)
        donor_state = model.prefill_resident(
            cfg, plist, names, donor, d_len, use_kernels=False
        )
        rng = np.random.default_rng(7)
        batch = jnp.asarray(
            rng.normal(size=(model.batch_state_len(cfg, self.B),)).astype(
                np.float32
            )
        )
        out = model.prefill_scatter_resume(
            cfg, plist, names, target, t_len, jnp.asarray([1], jnp.int32),
            donor_state, batch, pre, use_kernels=False,
        )
        one = model.prefill_resume(
            cfg, plist, names, target, t_len, donor_state, pre, use_kernels=False
        )
        cold = model.prefill_resident(
            cfg, plist, names, target, t_len, use_kernels=False
        )
        np.testing.assert_array_equal(np.asarray(one), np.asarray(cold))
        np.testing.assert_array_equal(np.asarray(out[sl : 2 * sl]), np.asarray(one))
        np.testing.assert_array_equal(np.asarray(out[:sl]), np.asarray(batch[:sl]))
        np.testing.assert_array_equal(
            np.asarray(out[2 * sl :]), np.asarray(batch[2 * sl :])
        )
