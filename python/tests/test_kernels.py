"""L1 correctness: every Pallas kernel vs its pure-jnp oracle in ref.py.

Hypothesis sweeps shapes (and block sizes, which must never change numerics)
so a tiling bug that only shows on ragged/odd shapes cannot slip through.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.cosine_topk import cosine_scores, cosine_topk
from compile.kernels.decode_attention import decode_attention
from compile.kernels.matmul import matmul_bias
from compile.kernels.rmsnorm import rmsnorm

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    rows=st.sampled_from([1, 3, 16, 64, 128, 192]),
    d=st.sampled_from([8, 32, 128, 256]),
    block=st.sampled_from([16, 64, 128]),
)
def test_rmsnorm_matches_ref(rows, d, block):
    x = _rand(0, (rows, d), 2.0)
    w = _rand(1, (d,))
    got = rmsnorm(x, w, block_rows=block)
    want = ref.rmsnorm(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rmsnorm_scale_invariant_direction():
    # rmsnorm(c*x) == rmsnorm(x) for c > 0 (up to eps) -- a core invariant.
    x = _rand(2, (4, 64))
    w = jnp.ones((64,))
    a = rmsnorm(x, w)
    b = rmsnorm(17.0 * x, w)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_rmsnorm_unit_rows():
    # Output rows have RMS ~= mean(weight applied) when weight == 1.
    x = _rand(3, (8, 128), 5.0)
    out = rmsnorm(x, jnp.ones((128,)))
    rms = jnp.sqrt(jnp.mean(out * out, axis=-1))
    np.testing.assert_allclose(rms, jnp.ones(8), rtol=1e-3)


# ---------------------------------------------------------------------------
# matmul_bias
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 5, 64, 96]),
    k=st.sampled_from([16, 128, 384]),
    n=st.sampled_from([24, 128, 512]),
    act=st.sampled_from(["none", "gelu"]),
    bm=st.sampled_from([16, 64]),
    bn=st.sampled_from([64, 128]),
)
def test_matmul_matches_ref(m, k, n, act, bm, bn):
    x = _rand(0, (m, k))
    w = _rand(1, (k, n), 1.0 / np.sqrt(k))
    b = _rand(2, (n,), 0.1)
    got = matmul_bias(x, w, b, act, block_m=bm, block_n=bn)
    want = ref.matmul_bias(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_activation():
    x, w, b = jnp.ones((2, 2)), jnp.ones((2, 2)), jnp.ones((2,))
    with pytest.raises(ValueError):
        matmul_bias(x, w, b, "relu6")


def test_matmul_zero_bias_identity():
    x = _rand(4, (8, 16))
    eye = jnp.eye(16)
    got = matmul_bias(x, eye, jnp.zeros((16,)))
    np.testing.assert_allclose(got, x, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# attention (prefill)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    h=st.sampled_from([1, 4, 8]),
    s=st.sampled_from([64, 128, 192]),
    hd=st.sampled_from([16, 32]),
    frac=st.floats(0.1, 1.0),
    causal=st.booleans(),
    bq=st.sampled_from([32, 64]),
    bkv=st.sampled_from([32, 64]),
)
def test_attention_matches_ref(h, s, hd, frac, causal, bq, bkv):
    length = max(1, int(s * frac))
    q = _rand(0, (h, s, hd))
    k = _rand(1, (h, s, hd))
    v = _rand(2, (h, s, hd))
    got = attention(
        q, k, v, jnp.array([length], jnp.int32), causal=causal,
        block_q=bq, block_kv=bkv,
    )
    want = ref.attention(q, k, v, length, causal=causal)
    # Only rows < length are defined (padding rows are masked garbage).
    np.testing.assert_allclose(
        got[:, :length], want[:, :length], rtol=1e-4, atol=1e-4
    )


def test_attention_is_convex_combination():
    # Each output row must lie in the convex hull of V rows: bounded by
    # [min(v), max(v)] per channel.
    h, s, hd = 2, 64, 32
    q = _rand(0, (h, s, hd))
    k = _rand(1, (h, s, hd))
    v = _rand(2, (h, s, hd))
    out = attention(q, k, v, jnp.array([s], jnp.int32), causal=False)
    assert float(out.max()) <= float(v.max()) + 1e-5
    assert float(out.min()) >= float(v.min()) - 1e-5


def test_attention_causal_first_row_is_v0():
    # With causal masking, the first query position can only attend to k0,
    # so out[:, 0] == v[:, 0] exactly (softmax over a single logit).
    h, s, hd = 2, 64, 16
    q = _rand(3, (h, s, hd))
    k = _rand(4, (h, s, hd))
    v = _rand(5, (h, s, hd))
    out = attention(q, k, v, jnp.array([s], jnp.int32), causal=True)
    np.testing.assert_allclose(out[:, 0], v[:, 0], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    h=st.sampled_from([1, 4, 8]),
    s=st.sampled_from([64, 256]),
    hd=st.sampled_from([16, 32]),
    posfrac=st.floats(0.0, 0.999),
)
def test_decode_attention_matches_ref(h, s, hd, posfrac):
    pos = int(s * posfrac)
    q = _rand(0, (h, hd))
    k = _rand(1, (h, s, hd))
    v = _rand(2, (h, s, hd))
    got = decode_attention(q, k, v, jnp.array([pos], jnp.int32))
    want = ref.decode_attention(q, k, v, pos)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_decode_attention_pos0_returns_v0():
    h, s, hd = 4, 64, 32
    q = _rand(0, (h, hd))
    k = _rand(1, (h, s, hd))
    v = _rand(2, (h, s, hd))
    out = decode_attention(q, k, v, jnp.array([0], jnp.int32))
    np.testing.assert_allclose(out, v[:, 0], rtol=1e-5, atol=1e-5)


def test_decode_matches_prefill_row():
    # The decode kernel at pos p must equal the prefill kernel's row p.
    h, s, hd = 4, 128, 32
    q = _rand(0, (h, s, hd))
    k = _rand(1, (h, s, hd))
    v = _rand(2, (h, s, hd))
    p = 77
    full = attention(q, k, v, jnp.array([s], jnp.int32), causal=True)
    one = decode_attention(q[:, p], k, v, jnp.array([p], jnp.int32))
    np.testing.assert_allclose(one, full[:, p], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# cosine scores / top-k
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 7, 512, 1024, 4096]),
    d=st.sampled_from([64, 384]),
    block=st.sampled_from([128, 512]),
)
def test_cosine_scores_matches_ref(n, d, block):
    db = _rand(0, (n, d))
    db = db / jnp.linalg.norm(db, axis=1, keepdims=True)
    q = db[n // 2]
    got = cosine_scores(db, q, block_rows=block)
    want = ref.cosine_scores(db, q)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_cosine_topk_self_match():
    db = _rand(1, (256, 384))
    db = db / jnp.linalg.norm(db, axis=1, keepdims=True)
    scores, idx = cosine_topk(db, db[13], k=4)
    assert int(idx[0]) == 13
    np.testing.assert_allclose(float(scores[0]), 1.0, rtol=1e-5)


def test_cosine_scores_bounded():
    db = _rand(2, (128, 64))
    db = db / jnp.linalg.norm(db, axis=1, keepdims=True)
    q = db[0]
    s = cosine_scores(db, q)
    assert float(s.max()) <= 1.0 + 1e-5 and float(s.min()) >= -1.0 - 1e-5
