"""L2 correctness: the kernel-backed models vs their pure-jnp twins, plus the
semantic properties the TweakLLM cache depends on (paraphrase similarity,
prefill/decode consistency)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, params

ENC = configs.ENCODER


@pytest.fixture(scope="module")
def enc_params():
    specs = params.encoder_param_specs(ENC)
    ps = params.init_encoder(ENC)
    names = params.param_names(specs)
    return [jnp.asarray(ps[n]) for n in names], names


@pytest.fixture(scope="module")
def small_llm():
    cfg = configs.SMALL_LLM
    specs = params.decoder_param_specs(cfg)
    ps = params.init_decoder(cfg)
    names = params.param_names(specs)
    return cfg, [jnp.asarray(ps[n]) for n in names], names


def _tok_batch(rows):
    b = len(rows)
    toks = np.zeros((b, ENC.max_seq), np.int32)
    lens = np.zeros((b,), np.int32)
    for i, row in enumerate(rows):
        toks[i, : len(row)] = row
        lens[i] = len(row)
    return jnp.asarray(toks), jnp.asarray(lens)


class TestEmbedder:
    def test_kernel_matches_oracle(self, enc_params):
        plist, names = enc_params
        toks, lens = _tok_batch([[5, 6, 7, 8], [9, 10, 11, 12, 13, 14]])
        a = model.embed_batch(ENC, plist, names, toks, lens, use_kernels=True)
        b = model.embed_batch(ENC, plist, names, toks, lens, use_kernels=False)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_unit_norm(self, enc_params):
        plist, names = enc_params
        toks, lens = _tok_batch([[5, 6, 7], [100, 200, 300, 400]])
        e = model.embed_batch(ENC, plist, names, toks, lens)
        np.testing.assert_allclose(
            np.linalg.norm(e, axis=1), np.ones(2), rtol=1e-5
        )

    def test_identical_queries_cosine_one(self, enc_params):
        plist, names = enc_params
        toks, lens = _tok_batch([[42, 43, 44, 45]] * 2)
        e = model.embed_batch(ENC, plist, names, toks, lens)
        assert float(e[0] @ e[1]) > 0.9999

    def test_paraphrase_closer_than_unrelated(self, enc_params):
        # The property the whole cache depends on: token-overlapping
        # paraphrases land closer than disjoint queries.
        plist, names = enc_params
        base = [50, 51, 52, 53, 54, 55]
        paraphrase = [50, 51, 52, 53, 54, 99]  # one token swapped
        reorder = [55, 50, 51, 52, 53, 54]
        unrelated = [900, 901, 902, 903, 904, 905]
        toks, lens = _tok_batch([base, paraphrase, reorder, unrelated])
        e = model.embed_batch(ENC, plist, names, toks, lens)
        sim_para = float(e[0] @ e[1])
        sim_reorder = float(e[0] @ e[2])
        sim_unrel = float(e[0] @ e[3])
        assert sim_para > sim_unrel
        assert sim_reorder > sim_unrel
        assert sim_para > 0.6

    def test_length_respected(self, enc_params):
        # Tokens past `length` must not affect the embedding.
        plist, names = enc_params
        toks_a, lens = _tok_batch([[5, 6, 7]])
        toks_b = toks_a.at[0, 3:10].set(777)
        ea = model.embed_batch(ENC, plist, names, toks_a, lens)
        eb = model.embed_batch(ENC, plist, names, toks_b, lens)
        np.testing.assert_allclose(ea, eb, rtol=1e-5, atol=1e-5)


class TestDecoder:
    def _prompt(self, cfg, n, seed=0):
        rng = np.random.default_rng(seed)
        toks = np.zeros((cfg.max_prefill,), np.int32)
        toks[:n] = rng.integers(configs.FIRST_WORD_ID, cfg.vocab_size, n)
        return jnp.asarray(toks), jnp.asarray([n], jnp.int32)

    def test_prefill_kernel_matches_oracle(self, small_llm):
        cfg, plist, names = small_llm
        toks, ln = self._prompt(cfg, 23)
        lg_k, kc_k, vc_k = model.prefill(cfg, plist, names, toks, ln, True)
        lg_r, kc_r, vc_r = model.prefill(cfg, plist, names, toks, ln, False)
        np.testing.assert_allclose(lg_k, lg_r, rtol=2e-3, atol=2e-3)
        # cache rows < length must agree too (pad rows are garbage)
        np.testing.assert_allclose(
            kc_k[:, :, :23], kc_r[:, :, :23], rtol=2e-3, atol=2e-3
        )
        np.testing.assert_allclose(
            vc_k[:, :, :23], vc_r[:, :, :23], rtol=2e-3, atol=2e-3
        )

    def test_decode_step_matches_oracle(self, small_llm):
        cfg, plist, names = small_llm
        toks, ln = self._prompt(cfg, 11)
        _, kc, vc = model.prefill(cfg, plist, names, toks, ln, True)
        tok = jnp.asarray([77], jnp.int32)
        pos = jnp.asarray([11], jnp.int32)
        lg_k, _, _ = model.decode_step(cfg, plist, names, tok, pos, kc, vc, True)
        lg_r, _, _ = model.decode_step(cfg, plist, names, tok, pos, kc, vc, False)
        np.testing.assert_allclose(lg_k, lg_r, rtol=2e-3, atol=2e-3)

    def test_decode_consistent_with_prefill(self, small_llm):
        # Decoding token t at position L must produce the same logits as
        # prefilling the (L+1)-length prompt ending in t.
        cfg, plist, names = small_llm
        toks, ln = self._prompt(cfg, 9)
        lg, kc, vc = model.prefill(cfg, plist, names, toks, ln, True)
        nxt = int(jnp.argmax(lg))
        lg2, _, _ = model.decode_step(
            cfg, plist, names,
            jnp.asarray([nxt], jnp.int32), jnp.asarray([9], jnp.int32),
            kc, vc, True,
        )
        toks2 = toks.at[9].set(nxt)
        lg_full, _, _ = model.prefill(
            cfg, plist, names, toks2, jnp.asarray([10], jnp.int32), True
        )
        np.testing.assert_allclose(lg2, lg_full, rtol=5e-3, atol=5e-3)

    def test_prefill_ignores_padding(self, small_llm):
        cfg, plist, names = small_llm
        toks, ln = self._prompt(cfg, 8)
        toks_dirty = toks.at[8:20].set(4242)
        a, _, _ = model.prefill(cfg, plist, names, toks, ln, True)
        b, _, _ = model.prefill(cfg, plist, names, toks_dirty, ln, True)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_logits_finite_and_varied(self, small_llm):
        cfg, plist, names = small_llm
        toks, ln = self._prompt(cfg, 30, seed=3)
        lg, _, _ = model.prefill(cfg, plist, names, toks, ln, True)
        assert np.isfinite(np.asarray(lg)).all()
        assert float(jnp.std(lg)) > 0.1  # not collapsed


class TestBatchedDecode:
    """The slot-based batched decode convention must be pure layout around
    the unchanged single-slot computations: scatter places one packed state,
    a batched step equals B independent resident steps, and inactive slots
    ride through bit-for-bit."""

    B = 3

    def _prompt(self, cfg, n, seed=0):
        rng = np.random.default_rng(seed)
        toks = np.zeros((cfg.max_prefill,), np.int32)
        toks[:n] = rng.integers(configs.FIRST_WORD_ID, cfg.vocab_size, n)
        return jnp.asarray(toks), jnp.asarray([n], jnp.int32)

    def _garbage_state(self, cfg, seed=42):
        rng = np.random.default_rng(seed)
        return jnp.asarray(
            rng.normal(size=(model.batch_state_len(cfg, self.B),)).astype(
                np.float32
            )
        )

    def test_prefill_scatter_places_one_slot(self, small_llm):
        cfg, plist, names = small_llm
        sl = model.state_len(cfg)
        toks, ln = self._prompt(cfg, 7)
        batch = self._garbage_state(cfg)
        out = model.prefill_scatter(
            cfg, plist, names, toks, ln, jnp.asarray([1], jnp.int32), batch,
            use_kernels=False,
        )
        one = model.prefill_resident(cfg, plist, names, toks, ln, use_kernels=False)
        np.testing.assert_array_equal(out[sl : 2 * sl], one)
        np.testing.assert_array_equal(out[:sl], batch[:sl])
        np.testing.assert_array_equal(out[2 * sl :], batch[2 * sl :])

    def test_batched_step_equals_independent_steps(self, small_llm):
        cfg, plist, names = small_llm
        sl = model.state_len(cfg)
        batch = self._garbage_state(cfg)
        for slot, (n, seed) in enumerate([(7, 0), (11, 1), (5, 2)]):
            toks, ln = self._prompt(cfg, n, seed)
            batch = model.prefill_scatter(
                cfg, plist, names, toks, ln,
                jnp.asarray([slot], jnp.int32), batch, use_kernels=False,
            )
        tokens = jnp.asarray([70, 71, 72], jnp.int32)
        pos = jnp.asarray([7, 11, 5], jnp.int32)
        active = jnp.asarray([1, 0, 1], jnp.int32)
        out = model.decode_batch_resident(
            cfg, plist, names, tokens, pos, active, batch, use_kernels=False
        )
        for slot in (0, 2):
            want = model.decode_step_resident(
                cfg, plist, names,
                tokens[slot : slot + 1], pos[slot : slot + 1],
                batch[slot * sl : (slot + 1) * sl], use_kernels=False,
            )
            np.testing.assert_array_equal(out[slot * sl : (slot + 1) * sl], want)
        # the masked slot is untouched, bit for bit
        np.testing.assert_array_equal(out[sl : 2 * sl], batch[sl : 2 * sl])

    def test_peek_logits_batch_slices_tails(self, small_llm):
        cfg, plist, names = small_llm
        sl = model.state_len(cfg)
        batch = self._garbage_state(cfg, seed=9)
        rows = model.peek_logits_batch(cfg, batch, self.B)
        assert rows.shape == (self.B, cfg.vocab_size)
        for slot in range(self.B):
            want = model.peek_logits(cfg, batch[slot * sl : (slot + 1) * sl])
            np.testing.assert_array_equal(rows[slot], want)

    def test_jitted_chained_rounds_match_single_slot_loop(self, small_llm):
        # The Rust runtime's exact calling pattern, three rounds deep and
        # jit-compiled: batched rounds must reproduce the per-slot resident
        # loop bit-for-bit (this is the substrate half of the batched ≡
        # sequential identity gate).
        cfg, plist, names = small_llm
        sl = model.state_len(cfg)

        step_one = jax.jit(
            lambda t, p, s: model.decode_step_resident(
                cfg, plist, names, t, p, s, use_kernels=False
            )
        )
        step_batch = jax.jit(
            lambda t, p, a, s: model.decode_batch_resident(
                cfg, plist, names, t, p, a, s, use_kernels=False
            )
        )

        batch = self._garbage_state(cfg, seed=5)
        singles = []
        lens = [(6, 3), (9, 4)]
        for slot, (n, seed) in enumerate(lens):
            toks, ln = self._prompt(cfg, n, seed)
            batch = model.prefill_scatter(
                cfg, plist, names, toks, ln,
                jnp.asarray([slot], jnp.int32), batch, use_kernels=False,
            )
            singles.append(batch[slot * sl : (slot + 1) * sl])
        active = jnp.asarray([1, 1, 0], jnp.int32)
        for r in range(3):
            tokens = jnp.asarray([40 + r, 50 + r, 0], jnp.int32)
            pos = jnp.asarray([lens[0][0] + r, lens[1][0] + r, 0], jnp.int32)
            batch = step_batch(tokens, pos, active, batch)
            for slot in range(2):
                singles[slot] = step_one(
                    tokens[slot : slot + 1], pos[slot : slot + 1], singles[slot]
                )
        for slot in range(2):
            np.testing.assert_array_equal(
                batch[slot * sl : (slot + 1) * sl], singles[slot]
            )
        rows = model.peek_logits_batch(cfg, batch, self.B)
        for slot in range(2):
            np.testing.assert_array_equal(
                rows[slot], model.peek_logits(cfg, singles[slot])
            )


class TestParams:
    def test_deterministic_init(self):
        a = params.init_decoder(configs.SMALL_LLM)
        b = params.init_decoder(configs.SMALL_LLM)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])

    def test_big_small_distinct(self):
        a = params.init_decoder(configs.SMALL_LLM)
        b = params.init_decoder(configs.BIG_LLM)
        assert a["tok_emb"].shape != b["tok_emb"].shape

    def test_export_roundtrip(self, tmp_path):
        cfg = configs.SMALL_LLM
        specs = params.decoder_param_specs(cfg)
        ps = params.init_decoder(cfg)
        path = str(tmp_path / "w.bin")
        idx = params.export_weights(ps, specs, path)
        raw = np.fromfile(path, "<f4")
        assert raw.size == sum(t["numel"] for t in idx)
        for t in idx:
            got = raw[t["offset"] // 4 : t["offset"] // 4 + t["numel"]]
            np.testing.assert_array_equal(
                got, ps[t["name"]].reshape(-1)
            )
