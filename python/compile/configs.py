"""Shared model / artifact configuration for the TweakLLM substrate models.

These configs are the single source of truth for the build path (model.py,
aot.py) and are exported into ``artifacts/manifest.json`` so the Rust runtime
never hard-codes a shape.

Sizes are deliberately small: the testbed is a single-core CPU PJRT client,
and the paper's Big/Small distinction is about *cost ratio* (25x per output
token, modelled in the Rust cost model), not about us matching GPT-4o's
parameter count. See DESIGN.md "Substitutions".
"""

from dataclasses import dataclass, field


VOCAB_SIZE = 8192
EMBED_OUT_DIM = 384  # paper: all-MiniLM-L6-v2 output dimension
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
SEP_ID = 3
UNK_ID = 4
FIRST_WORD_ID = 5  # hashed word ids occupy [FIRST_WORD_ID, VOCAB_SIZE)


@dataclass(frozen=True)
class EncoderConfig:
    """MiniLM-style sentence embedder (bag-of-embeddings + light mixing)."""

    vocab_size: int = VOCAB_SIZE
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 64
    out_dim: int = EMBED_OUT_DIM
    # Residual mixing weight of the contextualizing layer. Small on purpose:
    # the bag-of-embeddings signal must dominate so that paraphrases (shared
    # tokens) land close in embedding space -- the behaviour MiniLM-class
    # models exhibit and that the paper's C1 failure mode depends on.
    mix_alpha: float = 0.3
    # Weight of the nonlinear branch of the output projection (the linear
    # branch preserves cosine structure, Johnson-Lindenstrauss style).
    proj_beta: float = 0.2

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class DecoderConfig:
    """Decoder-only causal transformer (the Big / Small LLM substrate)."""

    name: str = "small"
    vocab_size: int = VOCAB_SIZE
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 512
    max_prefill: int = 192  # longest prompt (tweak template incl. cached Q/R)
    max_seq: int = 256  # prefill + generated tokens
    block_q: int = 64
    block_kv: int = 64

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


ENCODER = EncoderConfig()
SMALL_LLM = DecoderConfig(
    name="small", d_model=128, n_layers=2, n_heads=4, d_ff=512
)
BIG_LLM = DecoderConfig(
    name="big", d_model=256, n_layers=4, n_heads=8, d_ff=1024
)

# Batch-size variants compiled for the embedder. The Rust dynamic batcher
# rounds a micro-batch up to the nearest compiled variant and pads.
EMBED_BATCH_SIZES = (1, 8, 32)

# Row-block size of the compiled cosine-similarity scorer artifact. The Rust
# vector store chunks the DB matrix into blocks of this many rows.
COSINE_DB_BLOCK = 4096

# Steps fused into one decode-span executable (§Perf L2). Must stay in sync
# with the Rust generator's span driver (it reads the span from the
# artifact's input shapes, so only aot.py hard-codes it).
DECODE_SPAN = 8

# Slot counts compiled for the batched resident decode path (one
# `{model}_decode_batch{B}_res` executable advances all B slots per call).
# The Rust runtime picks the largest compiled bucket that fits
# `[scheduler] decode_batch`; absent artifacts fall back to per-session
# dispatch automatically.
DECODE_BATCH_SIZES = (4, 8)

# Prefix lengths compiled for the resume-capable prefill artifacts
# (`{model}_prefill_resume{P}` / `{model}_prefill_scatter_resume{B}_{P}`).
# XLA shapes are static, so cross-request KV prefix reuse quantizes the
# shared prompt prefix to these chunk boundaries: a resumed prefill restores
# the first P cached K/V positions and recomputes only the
# (max_prefill - P)-row suffix. Multiples of block_q keep the Pallas
# attention/matmul tilings identical to the cold prefill (the bit-identity
# requirement); values must stay < max_prefill.
PREFIX_CHUNKS = (64, 128)

RNG_SEED = 20250923  # paper's date line; fixed for reproducibility

# Function words whose token-embedding rows are scaled down in the encoder
# (by STOPWORD_SCALE). Trained sentence encoders learn exactly this
# IDF-style downweighting; with random weights we inject it explicitly so
# that sentence similarity is driven by content words, not by shared
# question scaffolding ("why is ... good for ..."). The list must describe
# the *function* vocabulary only — polarity adjectives stay full-weight, so
# "why is X good" vs "why is X bad" remains a high-cosine near-duplicate
# (the paper's false-positive regime).
STOPWORDS = (
    "a an the is are was were be being been do does did done am "
    "can could should would will shall may might must "
    "i you he she we they it its my your me us them this that these those "
    "of for to in on at with about as by from into over under than then "
    "and or but not no nor so up down out off if else "
    "what which who whom whose how why when where "
    "come comes make makes made get gets got getting go going goes "
    "any some just really very please hey thanks thank appreciate "
    "question honest serious quick wondering curious tell know "
    "advance help i'm im ? ! . ,"
).split()

STOPWORD_SCALE = 0.22

# Synonym groups whose embedding rows are tied together (row = a*rep +
# b*noise with a^2+b^2=1, giving within-group cosine ~= a^2). Mirrors
# `rust/src/datasets/vocabulary.rs::SYNONYMS` — a trained encoder puts
# synonyms nearby; the hashed table needs it injected. Polarity antonyms
# (good/bad, great/terrible, ...) are deliberately NOT tied: keeping them
# unrelated is what makes polarity flips a single-content-word change.
SYNONYM_GROUPS = (
    ("why", "how come"),  # multi-word handled at tokenizer level as words
    ("explain", "describe", "clarify"),
    ("best", "ideal", "top"),
    ("improve", "boost", "increase"),
    ("tips", "advice", "suggestions"),
    ("good", "solid", "decent"),
    ("better", "superior"),
    ("know", "understand", "learn"),
)

SYNONYM_TIE = 0.88  # within-group cosine ≈ SYNONYM_TIE^2 ≈ 0.77
