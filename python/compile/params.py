"""Deterministic parameter initialization + binary export.

Weights are *runtime arguments* of every compiled artifact (baking ~8M f32
constants into HLO text would bloat the artifacts past what the XLA text
parser handles comfortably). This module owns:

  * the canonical *ordered* flattening of each model's parameters -- the
    order of `param_names()` IS the argument order of the lowered HLO and is
    recorded in artifacts/manifest.json for the Rust runtime;
  * deterministic initialization from configs.RNG_SEED, so `make artifacts`
    is reproducible bit-for-bit;
  * raw little-endian f32 export (artifacts/weights/<model>.bin).

Initialization scales are chosen so the *embedder* behaves like a sentence
encoder (bag-of-embeddings dominant; see configs.EncoderConfig) and the
decoders produce well-conditioned logits for sampling.
"""

from __future__ import annotations

import numpy as np

from .configs import (
    DecoderConfig,
    EncoderConfig,
    FIRST_WORD_ID,
    RNG_SEED,
    STOPWORD_SCALE,
    STOPWORDS,
    SYNONYM_GROUPS,
    SYNONYM_TIE,
)


# ---------------------------------------------------------------------------
# Rust-tokenizer hash mirror (util::rng::hash_bytes + tokenizer::word_id).
# Needed so the encoder can downweight the embedding rows of function words
# — the ids are assigned by the Rust tokenizer's hash at runtime.
# ---------------------------------------------------------------------------

_M64 = (1 << 64) - 1


def _splitmix64(state: int) -> int:
    state = (state + 0x9E3779B97F4A7C15) & _M64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    return z ^ (z >> 31)


def hash_bytes(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & _M64
    return _splitmix64(h)


def word_id(word: str, vocab_size: int) -> int:
    h = hash_bytes(word.encode())
    return FIRST_WORD_ID + h % (vocab_size - FIRST_WORD_ID)


def _rng(tag: str) -> np.random.Generator:
    # Stable per-tensor stream: seed derived from the global seed + tag hash.
    h = np.uint64(1469598103934665603)
    for b in tag.encode():
        h = np.uint64((int(h) ^ b) * 1099511628211 % (1 << 64))
    return np.random.default_rng([RNG_SEED, int(h % (1 << 32))])


def _normal(tag: str, shape, scale: float) -> np.ndarray:
    return (_rng(tag).standard_normal(shape) * scale).astype(np.float32)


def _zeros(shape) -> np.ndarray:
    return np.zeros(shape, np.float32)


def _ones(shape) -> np.ndarray:
    return np.ones(shape, np.float32)


# ---------------------------------------------------------------------------
# Encoder (embedder)
# ---------------------------------------------------------------------------


def encoder_param_specs(cfg: EncoderConfig) -> list[tuple[str, tuple[int, ...]]]:
    d, ff, od = cfg.d_model, cfg.d_ff, cfg.out_dim
    return [
        ("tok_emb", (cfg.vocab_size, d)),
        ("ln1_w", (d,)),
        ("w_qkv", (d, 3 * d)),
        ("b_qkv", (3 * d,)),
        ("w_o", (d, d)),
        ("b_o", (d,)),
        ("ln2_w", (d,)),
        ("w_ff1", (d, ff)),
        ("b_ff1", (ff,)),
        ("w_ff2", (ff, d)),
        ("b_ff2", (d,)),
        ("w_proj", (d, od)),  # linear branch: preserves cosine structure
        ("w_nl1", (d, ff)),  # nonlinear branch
        ("b_nl1", (ff,)),
        ("w_nl2", (ff, od)),
        ("b_nl2", (od,)),
        # Mean-centering vector, computed at AOT time over a probe corpus
        # and subtracted before normalization. Without it every embedding
        # shares a large common component (the GELU branch has positive
        # mean), giving unrelated sentences a cosine floor of ~0.7 — trained
        # encoders do this centering implicitly. See aot.py.
        ("z_mean", (od,)),
    ]


def init_encoder(cfg: EncoderConfig) -> dict[str, np.ndarray]:
    d = cfg.d_model
    params: dict[str, np.ndarray] = {}
    for name, shape in encoder_param_specs(cfg):
        tag = f"enc/{name}"
        if name == "tok_emb":
            emb = _normal(tag, shape, 1.0 / np.sqrt(d))
            # Tie synonym rows toward a shared representative (see configs).
            a = SYNONYM_TIE
            b = float(np.sqrt(1.0 - a * a))
            for group in SYNONYM_GROUPS:
                rep = _normal(f"enc/syn/{group[0]}", (d,), 1.0 / np.sqrt(d))
                for w in group:
                    for token in w.split():  # multi-word synonyms: tie each
                        wid = word_id(token, cfg.vocab_size)
                        emb[wid] = a * rep + b * emb[wid]
            # IDF-style downweighting of function words (see configs).
            for w in STOPWORDS:
                emb[word_id(w, cfg.vocab_size)] *= STOPWORD_SCALE
            params[name] = emb
        elif name.startswith("ln"):
            params[name] = _ones(shape)
        elif name.startswith("b_") or name == "z_mean":
            params[name] = _zeros(shape)
        else:
            fan_in = shape[0]
            params[name] = _normal(tag, shape, 1.0 / np.sqrt(fan_in))
    return params


# ---------------------------------------------------------------------------
# Decoder (Big / Small LLM)
# ---------------------------------------------------------------------------


def decoder_param_specs(cfg: DecoderConfig) -> list[tuple[str, tuple[int, ...]]]:
    d, ff = cfg.d_model, cfg.d_ff
    specs: list[tuple[str, tuple[int, ...]]] = [("tok_emb", (cfg.vocab_size, d))]
    specs.append(("pos_emb", (cfg.max_seq, d)))
    for layer in range(cfg.n_layers):
        p = f"l{layer}."
        specs += [
            (p + "ln1_w", (d,)),
            (p + "w_qkv", (d, 3 * d)),
            (p + "b_qkv", (3 * d,)),
            (p + "w_o", (d, d)),
            (p + "b_o", (d,)),
            (p + "ln2_w", (d,)),
            (p + "w_ff1", (d, ff)),
            (p + "b_ff1", (ff,)),
            (p + "w_ff2", (ff, d)),
            (p + "b_ff2", (d,)),
        ]
    specs.append(("lnf_w", (d,)))
    return specs


def init_decoder(cfg: DecoderConfig) -> dict[str, np.ndarray]:
    d = cfg.d_model
    # Residual-branch outputs scaled down by depth (GPT-2 style) so the
    # logits stay well-conditioned without training.
    resid_scale = 1.0 / np.sqrt(2.0 * cfg.n_layers)
    params: dict[str, np.ndarray] = {}
    for name, shape in decoder_param_specs(cfg):
        tag = f"dec/{cfg.name}/{name}"
        base = name.split(".")[-1]
        if base == "tok_emb":
            params[name] = _normal(tag, shape, 0.02 * np.sqrt(d))
        elif base == "pos_emb":
            params[name] = _normal(tag, shape, 0.01 * np.sqrt(d))
        elif base.startswith("ln"):
            params[name] = _ones(shape)
        elif base.startswith("b_"):
            params[name] = _zeros(shape)
        elif base in ("w_o", "w_ff2"):
            params[name] = _normal(tag, shape, resid_scale / np.sqrt(shape[0]))
        else:
            params[name] = _normal(tag, shape, 1.0 / np.sqrt(shape[0]))
    return params


# ---------------------------------------------------------------------------
# Flatten / export
# ---------------------------------------------------------------------------


def param_names(specs: list[tuple[str, tuple[int, ...]]]) -> list[str]:
    return [name for name, _ in specs]


def flatten(params: dict[str, np.ndarray], specs) -> list[np.ndarray]:
    """Arguments in manifest order -- MUST match aot.py's lowering order."""
    return [params[name] for name, _ in specs]


def export_weights(params: dict[str, np.ndarray], specs, path: str) -> list[dict]:
    """Write raw little-endian f32 concatenation; return the tensor index."""
    index = []
    offset = 0
    with open(path, "wb") as f:
        for name, shape in specs:
            arr = np.ascontiguousarray(params[name], dtype="<f4")
            assert tuple(arr.shape) == tuple(shape), (name, arr.shape, shape)
            f.write(arr.tobytes())
            index.append(
                {
                    "name": name,
                    "shape": list(shape),
                    "offset": offset,
                    "numel": int(arr.size),
                }
            )
            offset += arr.size * 4
    return index
