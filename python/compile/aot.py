"""AOT pipeline: lower every substrate computation to HLO text + export
weights + write the artifact manifest the Rust runtime consumes.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids that the xla crate's XLA
(xla_extension 0.5.1) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifact calling convention (recorded in manifest.json):
  HLO parameters = [<weights in params.py spec order>..., <inputs>...]
  HLO result     = tuple of outputs (lowered with return_tuple=True)

Run via ``make artifacts``; the target is skipped when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model, params
from .kernels.cosine_topk import cosine_scores as kernel_cosine_scores


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    """``return_tuple=False`` (single-output artifacts only) leaves the HLO
    root as the bare output array: PJRT then hands back a plain device
    buffer that the Rust runtime can feed straight into the next call — the
    device-resident decode convention (manifest ``"untupled": true``)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_entry(name, shape, dtype):
    return {"name": name, "shape": [int(x) for x in shape], "dtype": dtype}


def build_artifacts(out_dir: str, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)
    manifest: dict = {
        "format": "hlo-text-v1",
        "seed": configs.RNG_SEED,
        "vocab_size": configs.VOCAB_SIZE,
        "embed_dim": configs.EMBED_OUT_DIM,
        "special_tokens": {
            "pad": configs.PAD_ID,
            "bos": configs.BOS_ID,
            "eos": configs.EOS_ID,
            "sep": configs.SEP_ID,
            "unk": configs.UNK_ID,
            "first_word": configs.FIRST_WORD_ID,
        },
        "models": {},
        "artifacts": [],
    }

    def log(msg):
        if verbose:
            print(f"[aot] {msg}", flush=True)

    # ----- weights ---------------------------------------------------------
    enc_cfg = configs.ENCODER
    enc_specs = params.encoder_param_specs(enc_cfg)
    enc_params = params.init_encoder(enc_cfg)
    enc_names = params.param_names(enc_specs)

    # Compute the mean-centering vector over a probe corpus of random
    # content-word sequences (see params.encoder z_mean docstring).
    import numpy as np

    rng = np.random.default_rng(configs.RNG_SEED)
    probe_z = []
    plist_probe = {k: jnp.asarray(v) for k, v in enc_params.items()}
    for _ in range(192):
        n = int(rng.integers(3, 16))
        toks = np.zeros((enc_cfg.max_seq,), np.int32)
        toks[:n] = rng.integers(configs.FIRST_WORD_ID, enc_cfg.vocab_size, n)
        z = model.embed_prenorm(
            enc_cfg,
            plist_probe,
            jnp.asarray(toks),
            jnp.asarray([n], jnp.int32),
            use_kernels=False,
        )
        probe_z.append(np.asarray(z))
    enc_params["z_mean"] = np.mean(np.stack(probe_z), axis=0).astype(np.float32)
    log(f"z_mean norm: {float(np.linalg.norm(enc_params['z_mean'])):.3f}")
    enc_idx = params.export_weights(
        enc_params, enc_specs, os.path.join(out_dir, "weights", "encoder.bin")
    )
    manifest["models"]["encoder"] = {
        "weights_file": "weights/encoder.bin",
        "tensors": enc_idx,
        "config": {
            "d_model": enc_cfg.d_model,
            "n_heads": enc_cfg.n_heads,
            "d_ff": enc_cfg.d_ff,
            "max_seq": enc_cfg.max_seq,
            "out_dim": enc_cfg.out_dim,
            "mix_alpha": enc_cfg.mix_alpha,
            "proj_beta": enc_cfg.proj_beta,
        },
    }
    log(f"encoder weights: {sum(t['numel'] for t in enc_idx)} params")

    dec_data = {}
    for cfg in (configs.SMALL_LLM, configs.BIG_LLM):
        specs = params.decoder_param_specs(cfg)
        ps = params.init_decoder(cfg)
        names = params.param_names(specs)
        idx = params.export_weights(
            ps, specs, os.path.join(out_dir, "weights", f"{cfg.name}.bin")
        )
        manifest["models"][cfg.name] = {
            "weights_file": f"weights/{cfg.name}.bin",
            "tensors": idx,
            "config": {
                "d_model": cfg.d_model,
                "n_layers": cfg.n_layers,
                "n_heads": cfg.n_heads,
                "d_ff": cfg.d_ff,
                "max_prefill": cfg.max_prefill,
                "max_seq": cfg.max_seq,
                "head_dim": cfg.head_dim,
            },
        }
        dec_data[cfg.name] = (cfg, specs, ps, names)
        log(f"{cfg.name} weights: {sum(t['numel'] for t in idx)} params")

    # ----- lowering helpers -------------------------------------------------
    def lower_artifact(name, fn, weight_specs, input_entries, output_entries, wset):
        t0 = time.time()
        arg_specs = [_spec(tuple(s), jnp.float32) for _, s in weight_specs]
        arg_specs += [
            _spec(tuple(e["shape"]), jnp.dtype(e["dtype"])) for e in input_entries
        ]
        lowered = jax.jit(fn).lower(*arg_specs)
        # Single-output artifacts skip the tuple wrapper so their result is
        # a feed-back-able device buffer (and a single untupled fetch).
        untupled = len(output_entries) == 1
        text = to_hlo_text(lowered, return_tuple=not untupled)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "weight_set": wset,
                "n_weight_args": len(weight_specs),
                "inputs": input_entries,
                "outputs": output_entries,
                "untupled": untupled,
            }
        )
        log(f"lowered {name}: {len(text)} chars in {time.time() - t0:.1f}s")

    # ----- embedder variants ------------------------------------------------
    for b in configs.EMBED_BATCH_SIZES:

        def embed_fn(*args, _b=b):
            plist = list(args[: len(enc_names)])
            tokens, lengths = args[len(enc_names) :]
            return model.embed_batch(enc_cfg, plist, enc_names, tokens, lengths)

        lower_artifact(
            f"embed_b{b}",
            embed_fn,
            enc_specs,
            [
                _io_entry("tokens", (b, enc_cfg.max_seq), "int32"),
                _io_entry("lengths", (b,), "int32"),
            ],
            [_io_entry("embeddings", (b, enc_cfg.out_dim), "float32")],
            "encoder",
        )

    # ----- decoder prefill / decode ----------------------------------------
    for mname, (cfg, specs, _ps, names) in dec_data.items():
        kv_shape = (cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim)

        def prefill_fn(*args, _cfg=cfg, _names=names):
            plist = list(args[: len(_names)])
            tokens, length = args[len(_names) :]
            return model.prefill(_cfg, plist, _names, tokens, length)

        lower_artifact(
            f"{mname}_prefill",
            prefill_fn,
            specs,
            [
                _io_entry("tokens", (cfg.max_prefill,), "int32"),
                _io_entry("length", (1,), "int32"),
            ],
            [
                _io_entry("logits", (cfg.vocab_size,), "float32"),
                _io_entry("k_cache", kv_shape, "float32"),
                _io_entry("v_cache", kv_shape, "float32"),
            ],
            mname,
        )

        def decode_fn(*args, _cfg=cfg, _names=names):
            plist = list(args[: len(_names)])
            token, pos, k_cache, v_cache = args[len(_names) :]
            return model.decode_step(_cfg, plist, _names, token, pos, k_cache, v_cache)

        lower_artifact(
            f"{mname}_decode",
            decode_fn,
            specs,
            [
                _io_entry("token", (1,), "int32"),
                _io_entry("pos", (1,), "int32"),
                _io_entry("k_cache", kv_shape, "float32"),
                _io_entry("v_cache", kv_shape, "float32"),
            ],
            [
                _io_entry("logits", (cfg.vocab_size,), "float32"),
                _io_entry("k_cache", kv_shape, "float32"),
                _io_entry("v_cache", kv_shape, "float32"),
            ],
            mname,
        )

        # Fused multi-step decode (§Perf L2): amortizes the per-call KV
        # transfer by DECODE_SPAN; sampling (top-k 40 + temperature) happens
        # in-graph, driven by uniforms from the Rust PRNG.
        span = configs.DECODE_SPAN

        def span_fn(*args, _cfg=cfg, _names=names):
            plist = list(args[: len(_names)])
            token, pos, k_cache, v_cache, u, temp = args[len(_names) :]
            return model.decode_span(
                _cfg, plist, _names, token, pos, k_cache, v_cache, u, temp
            )

        lower_artifact(
            f"{mname}_decode{span}",
            span_fn,
            specs,
            [
                _io_entry("token", (1,), "int32"),
                _io_entry("pos", (1,), "int32"),
                _io_entry("k_cache", kv_shape, "float32"),
                _io_entry("v_cache", kv_shape, "float32"),
                _io_entry("u", (span,), "float32"),
                _io_entry("temperature", (1,), "float32"),
            ],
            [
                _io_entry("tokens", (span,), "int32"),
                _io_entry("k_cache", kv_shape, "float32"),
                _io_entry("v_cache", kv_shape, "float32"),
            ],
            mname,
        )

        # Device-resident variants (DESIGN.md §Perf L2): the same
        # computations behind the packed single-root convention, plus the
        # weight-free peek slicers. These are what let the Rust runtime keep
        # the KV cache on device across the whole decode loop.
        slen = model.state_len(cfg)

        def prefill_res_fn(*args, _cfg=cfg, _names=names):
            plist = list(args[: len(_names)])
            tokens, length = args[len(_names) :]
            return model.prefill_resident(_cfg, plist, _names, tokens, length)

        lower_artifact(
            f"{mname}_prefill_res",
            prefill_res_fn,
            specs,
            [
                _io_entry("tokens", (cfg.max_prefill,), "int32"),
                _io_entry("length", (1,), "int32"),
            ],
            [_io_entry("state", (slen,), "float32")],
            mname,
        )

        def decode_res_fn(*args, _cfg=cfg, _names=names):
            plist = list(args[: len(_names)])
            token, pos, state = args[len(_names) :]
            return model.decode_step_resident(_cfg, plist, _names, token, pos, state)

        lower_artifact(
            f"{mname}_decode_res",
            decode_res_fn,
            specs,
            [
                _io_entry("token", (1,), "int32"),
                _io_entry("pos", (1,), "int32"),
                _io_entry("state", (slen,), "float32"),
            ],
            [_io_entry("state", (slen,), "float32")],
            mname,
        )

        def span_res_fn(*args, _cfg=cfg, _names=names):
            plist = list(args[: len(_names)])
            token, pos, state, u, temp = args[len(_names) :]
            return model.decode_span_resident(
                _cfg, plist, _names, token, pos, state, u, temp
            )

        lower_artifact(
            f"{mname}_decode{span}_res",
            span_res_fn,
            specs,
            [
                _io_entry("token", (1,), "int32"),
                _io_entry("pos", (1,), "int32"),
                _io_entry("state", (slen,), "float32"),
                _io_entry("u", (span,), "float32"),
                _io_entry("temperature", (1,), "float32"),
            ],
            [_io_entry("state", (slen,), "float32")],
            mname,
        )

        def peek_logits_fn(state, _cfg=cfg):
            return model.peek_logits(_cfg, state)

        lower_artifact(
            f"{mname}_peek_logits",
            peek_logits_fn,
            [],
            [_io_entry("state", (slen,), "float32")],
            [_io_entry("logits", (cfg.vocab_size,), "float32")],
            None,
        )

        def peek_tokens_fn(state, _cfg=cfg, _span=span):
            return model.peek_tokens(_cfg, state, _span)

        lower_artifact(
            f"{mname}_peek_tokens{span}",
            peek_tokens_fn,
            [],
            [_io_entry("state", (slen,), "float32")],
            [_io_entry("tokens", (span,), "int32")],
            None,
        )

        # Resume-capable prefill (cross-request KV prefix reuse): one
        # artifact per static PREFIX_CHUNKS boundary. A cached packed state
        # supplies K/V[:, :P]; only the suffix rows are recomputed. The Rust
        # runtime discovers these by name and falls back to cold prefill in
        # pre-resume artifact dirs.
        for pre in configs.PREFIX_CHUNKS:

            def resume_fn(*args, _cfg=cfg, _names=names, _pre=pre):
                plist = list(args[: len(_names)])
                tokens, length, prefix_state = args[len(_names) :]
                return model.prefill_resume(
                    _cfg, plist, _names, tokens, length, prefix_state, _pre
                )

            lower_artifact(
                f"{mname}_prefill_resume{pre}",
                resume_fn,
                specs,
                [
                    _io_entry("tokens", (cfg.max_prefill,), "int32"),
                    _io_entry("length", (1,), "int32"),
                    _io_entry("prefix_state", (slen,), "float32"),
                ],
                [_io_entry("state", (slen,), "float32")],
                mname,
            )

        # Slot-based batched resident decode: for each compiled slot-count
        # bucket, a prefill-scatter entry point (claim a slot), a batched
        # masked decode step (advance every active slot in ONE call), and a
        # batched logits peek (the only per-round fetch, O(B * vocab)).
        for bsz in configs.DECODE_BATCH_SIZES:
            bslen = model.batch_state_len(cfg, bsz)

            def scatter_fn(*args, _cfg=cfg, _names=names):
                plist = list(args[: len(_names)])
                tokens, length, slot, state = args[len(_names) :]
                return model.prefill_scatter(
                    _cfg, plist, _names, tokens, length, slot, state
                )

            lower_artifact(
                f"{mname}_prefill_scatter{bsz}",
                scatter_fn,
                specs,
                [
                    _io_entry("tokens", (cfg.max_prefill,), "int32"),
                    _io_entry("length", (1,), "int32"),
                    _io_entry("slot", (1,), "int32"),
                    _io_entry("state", (bslen,), "float32"),
                ],
                [_io_entry("state", (bslen,), "float32")],
                mname,
            )

            def batch_fn(*args, _cfg=cfg, _names=names):
                plist = list(args[: len(_names)])
                tokens, pos, active, state = args[len(_names) :]
                return model.decode_batch_resident(
                    _cfg, plist, _names, tokens, pos, active, state
                )

            lower_artifact(
                f"{mname}_decode_batch{bsz}_res",
                batch_fn,
                specs,
                [
                    _io_entry("tokens", (bsz,), "int32"),
                    _io_entry("pos", (bsz,), "int32"),
                    _io_entry("active", (bsz,), "int32"),
                    _io_entry("state", (bslen,), "float32"),
                ],
                [_io_entry("state", (bslen,), "float32")],
                mname,
            )

            # Resume twin of prefill_scatter, per PREFIX_CHUNKS boundary.
            for pre in configs.PREFIX_CHUNKS:

                def scatter_resume_fn(*args, _cfg=cfg, _names=names, _pre=pre):
                    plist = list(args[: len(_names)])
                    tokens, length, slot, prefix_state, state = args[len(_names) :]
                    return model.prefill_scatter_resume(
                        _cfg, plist, _names, tokens, length, slot,
                        prefix_state, state, _pre,
                    )

                lower_artifact(
                    f"{mname}_prefill_scatter_resume{bsz}_{pre}",
                    scatter_resume_fn,
                    specs,
                    [
                        _io_entry("tokens", (cfg.max_prefill,), "int32"),
                        _io_entry("length", (1,), "int32"),
                        _io_entry("slot", (1,), "int32"),
                        _io_entry("prefix_state", (slen,), "float32"),
                        _io_entry("state", (bslen,), "float32"),
                    ],
                    [_io_entry("state", (bslen,), "float32")],
                    mname,
                )

            def peek_batch_fn(state, _cfg=cfg, _bsz=bsz):
                return model.peek_logits_batch(_cfg, state, _bsz)

            lower_artifact(
                f"{mname}_peek_logits_batch{bsz}",
                peek_batch_fn,
                [],
                [_io_entry("state", (bslen,), "float32")],
                [_io_entry("logits", (bsz, cfg.vocab_size), "float32")],
                None,
            )

    # ----- compiled cosine scorer -------------------------------------------
    n_block = configs.COSINE_DB_BLOCK

    def cosine_fn(db, q):
        return (kernel_cosine_scores(db, q),)

    lower_artifact(
        f"cosine_scores_b{n_block}",
        cosine_fn,
        [],
        [
            _io_entry("db", (n_block, configs.EMBED_OUT_DIM), "float32"),
            _io_entry("q", (configs.EMBED_OUT_DIM,), "float32"),
        ],
        [_io_entry("scores", (n_block,), "float32")],
        None,
    )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"manifest: {len(manifest['artifacts'])} artifacts")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args()
    build_artifacts(args.out, verbose=not args.quiet)


if __name__ == "__main__":
    main()
