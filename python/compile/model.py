"""L2: JAX forward passes for the TweakLLM substrate models.

Three computations, all lowered to HLO text by ``aot.py`` and executed from
the Rust runtime (Python is never on the request path):

  * ``embed_batch``  -- MiniLM-style sentence embedder (the paper's
    all-MiniLM-L6-v2 stand-in): token embeddings + one lightly-mixed
    transformer layer, masked mean-pool, projection to 384-d, L2-normalize.
  * ``prefill``      -- decoder-only causal LM prompt pass, returns the
    next-token logits and a dense KV cache for the decode loop.
  * ``decode_step``  -- single-token step that appends to the KV cache and
    returns next-token logits. The Rust generator drives the autoregressive
    loop, feeding the cache buffers back zero-copy (PJRT ``execute_b``).

Every dense/attention op routes through the Pallas kernels in ``kernels/``
(``use_kernels=False`` swaps in the pure-jnp oracle, which tests use to pin
the two implementations together).

Weights arrive as a *list* in ``params.py`` spec order; see manifest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernels
from .configs import DecoderConfig, EncoderConfig
from .kernels import ref


def _ops(use_kernels: bool):
    if use_kernels:
        return kernels.rmsnorm, kernels.matmul_bias, kernels.attention
    # Oracle twins (ref.attention takes a scalar length, kernel takes [1]).
    def rms(x, w):
        return ref.rmsnorm(x, w)

    def mm(x, w, b, activation="none"):
        return ref.matmul_bias(x, w, b, activation)

    def attn(q, k, v, length, causal=True):
        return ref.attention(q, k, v, length[0], causal)

    return rms, mm, attn


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    """[S, D] -> [H, S, hd]"""
    s, d = x.shape
    return x.reshape(s, n_heads, d // n_heads).transpose(1, 0, 2)


def _merge_heads(x: jax.Array) -> jax.Array:
    """[H, S, hd] -> [S, D]"""
    h, s, hd = x.shape
    return x.transpose(1, 0, 2).reshape(s, h * hd)


# ---------------------------------------------------------------------------
# Embedder
# ---------------------------------------------------------------------------


def _encoder_layer(cfg: EncoderConfig, p: dict, e: jax.Array, length, use_kernels):
    """One pre-norm transformer layer with residual branches scaled by
    ``mix_alpha`` so the bag-of-embeddings signal dominates (see configs).

    The contextual branches are additionally scaled per token by the
    embedding-row magnitude: RMSNorm inside the branches would otherwise
    undo the encoder's IDF downweighting (params.py STOPWORD_SCALE) and
    reinject function-word signal at full strength.
    """
    rms, mm, attn = _ops(use_kernels)
    d, h = cfg.d_model, cfg.n_heads
    tok_w = jnp.minimum(
        jnp.linalg.norm(e, axis=-1, keepdims=True), 1.0
    )  # [S, 1]; ~0.22 for downweighted function words, ~1 for content
    en = rms(e, p["ln1_w"])
    qkv = mm(en, p["w_qkv"], p["b_qkv"])
    q, k, v = (_split_heads(t, h) for t in jnp.split(qkv, 3, axis=-1))
    # Scale the *values* by token weight as well: RMSNorm has re-normalized
    # every token, so without this the attention output is dominated by the
    # (shared, template) function words regardless of their tiny embeddings.
    v = v * tok_w[None, :, :]
    a = attn(q, k, v, length, causal=False)
    a = mm(_merge_heads(a), p["w_o"], p["b_o"])
    h1 = e + cfg.mix_alpha * a * tok_w
    hn = rms(h1, p["ln2_w"])
    f = mm(mm(hn, p["w_ff1"], p["b_ff1"], "gelu"), p["w_ff2"], p["b_ff2"])
    return h1 + cfg.mix_alpha * f * tok_w


def embed_prenorm(
    cfg: EncoderConfig,
    p: dict,
    tokens: jax.Array,
    length: jax.Array,
    use_kernels: bool = True,
) -> jax.Array:
    """Pre-normalization sentence vector (used by aot.py to compute the
    mean-centering vector). tokens: [S] int32, length: [1] int32 -> [out_dim]."""
    _, mm, _ = _ops(use_kernels)
    s = cfg.max_seq
    e = p["tok_emb"][tokens]  # [S, d]
    h = _encoder_layer(cfg, p, e, length, use_kernels)
    mask = (jnp.arange(s) < length[0]).astype(h.dtype)[:, None]
    denom = jnp.maximum(length[0].astype(h.dtype), 1.0)
    pooled = jnp.sum(h * mask, axis=0, keepdims=True) / denom  # [1, d]
    lin = pooled @ p["w_proj"]  # cosine-preserving random projection
    nl = mm(
        mm(pooled, p["w_nl1"], p["b_nl1"], "gelu"), p["w_nl2"], p["b_nl2"]
    )
    return (lin + cfg.proj_beta * nl)[0]


def embed_one(
    cfg: EncoderConfig,
    p: dict,
    tokens: jax.Array,
    length: jax.Array,
    use_kernels: bool = True,
) -> jax.Array:
    """tokens: [S] int32, length: [1] int32 -> [out_dim] L2-normalized,
    mean-centered (see params.py z_mean)."""
    z = embed_prenorm(cfg, p, tokens, length, use_kernels) - p["z_mean"]
    return z / jnp.maximum(jnp.linalg.norm(z), 1e-6)


def embed_batch(
    cfg: EncoderConfig,
    plist: list[jax.Array],
    names: list[str],
    tokens: jax.Array,
    lengths: jax.Array,
    use_kernels: bool = True,
) -> jax.Array:
    """tokens: [B, S] int32, lengths: [B] int32 -> [B, out_dim]."""
    p = dict(zip(names, plist))
    outs = [
        embed_one(cfg, p, tokens[b], lengths[b : b + 1], use_kernels)
        for b in range(tokens.shape[0])
    ]
    return jnp.stack(outs)


# ---------------------------------------------------------------------------
# Decoder (Big / Small LLM)
# ---------------------------------------------------------------------------


def _decoder_layer_prefill(cfg, lp, h, length, use_kernels):
    rms, mm, attn = _ops(use_kernels)
    hn = rms(h, lp["ln1_w"])
    qkv = mm(hn, lp["w_qkv"], lp["b_qkv"])
    q, k, v = (_split_heads(t, cfg.n_heads) for t in jnp.split(qkv, 3, axis=-1))
    a = attn(q, k, v, length, causal=True)
    h = h + mm(_merge_heads(a), lp["w_o"], lp["b_o"])
    hn = rms(h, lp["ln2_w"])
    f = mm(mm(hn, lp["w_ff1"], lp["b_ff1"], "gelu"), lp["w_ff2"], lp["b_ff2"])
    return h + f, k, v


def _layer_params(p: dict, layer: int) -> dict:
    pref = f"l{layer}."
    return {k[len(pref) :]: v for k, v in p.items() if k.startswith(pref)}


def prefill(
    cfg: DecoderConfig,
    plist: list[jax.Array],
    names: list[str],
    tokens: jax.Array,
    length: jax.Array,
    use_kernels: bool = True,
):
    """Prompt pass.

    tokens: [max_prefill] int32 (padded), length: [1] int32.
    Returns (logits [vocab], k_cache [L, H, max_seq, hd], v_cache [...]).
    The caches hold the prompt K/V in positions [0, length); positions
    beyond hold pad-token garbage that decode steps overwrite before reading
    (decode masks attention to positions <= pos).
    """
    p = dict(zip(names, plist))
    rms, mm, _ = _ops(use_kernels)
    pmax, smax = cfg.max_prefill, cfg.max_seq
    h = p["tok_emb"][tokens] + p["pos_emb"][:pmax]  # [P, d]
    k_cache = jnp.zeros((cfg.n_layers, cfg.n_heads, smax, cfg.head_dim), h.dtype)
    v_cache = jnp.zeros_like(k_cache)
    for layer in range(cfg.n_layers):
        h, k, v = _decoder_layer_prefill(
            cfg, _layer_params(p, layer), h, length, use_kernels
        )
        k_cache = k_cache.at[layer, :, :pmax, :].set(k)
        v_cache = v_cache.at[layer, :, :pmax, :].set(v)
    hf = rms(h, p["lnf_w"])
    last = jax.lax.dynamic_slice_in_dim(hf, length[0] - 1, 1, axis=0)  # [1, d]
    logits = mm(
        last,
        p["tok_emb"].T,
        jnp.zeros((cfg.vocab_size,), h.dtype),
        block_n=cfg.vocab_size,
    ) if use_kernels else last @ p["tok_emb"].T
    return logits.reshape(cfg.vocab_size), k_cache, v_cache


def decode_step(
    cfg: DecoderConfig,
    plist: list[jax.Array],
    names: list[str],
    token: jax.Array,
    pos: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    use_kernels: bool = True,
):
    """One autoregressive step.

    token: [1] int32 (the token at position ``pos``), pos: [1] int32,
    caches: [L, H, max_seq, hd]. Returns (logits [vocab], k_cache, v_cache)
    with the new K/V written at ``pos``.
    """
    p = dict(zip(names, plist))
    return _decode_step_p(cfg, p, token, pos, k_cache, v_cache, use_kernels)


def _decode_step_p(cfg, p, token, pos, k_cache, v_cache, use_kernels):
    rms, mm, _ = _ops(use_kernels)
    h = p["tok_emb"][token] + jax.lax.dynamic_slice_in_dim(
        p["pos_emb"], pos[0], 1, axis=0
    )  # [1, d]
    hd, nh = cfg.head_dim, cfg.n_heads
    for layer in range(cfg.n_layers):
        lp = _layer_params(p, layer)
        hn = rms(h, lp["ln1_w"])
        qkv = mm(hn, lp["w_qkv"], lp["b_qkv"])  # [1, 3d]
        q, k, v = (t.reshape(nh, hd) for t in jnp.split(qkv[0], 3))
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k[None, :, None, :], (layer, 0, pos[0], 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v[None, :, None, :], (layer, 0, pos[0], 0)
        )
        if use_kernels:
            a = kernels.decode_attention(q, k_cache[layer], v_cache[layer], pos)
        else:
            a = ref.decode_attention(q, k_cache[layer], v_cache[layer], pos[0])
        h = h + mm(a.reshape(1, cfg.d_model), lp["w_o"], lp["b_o"])
        hn = rms(h, lp["ln2_w"])
        f = mm(mm(hn, lp["w_ff1"], lp["b_ff1"], "gelu"), lp["w_ff2"], lp["b_ff2"])
        h = h + f
    hf = rms(h, p["lnf_w"])
    logits = mm(
        hf,
        p["tok_emb"].T,
        jnp.zeros((cfg.vocab_size,), h.dtype),
        block_n=cfg.vocab_size,
    ) if use_kernels else hf @ p["tok_emb"].T
    return logits.reshape(cfg.vocab_size), k_cache, v_cache


# ---------------------------------------------------------------------------
# Fused multi-step decode (§Perf L2): one executable runs SPAN autoregressive
# steps with in-graph top-k sampling, amortizing the per-call PJRT transfer
# of the KV caches (the dominant single-step cost on this testbed) by SPAN.
# ---------------------------------------------------------------------------

SPAN_TOP_K = 40  # static: matches SamplingParams::default() on the Rust side


def _sample_topk(logits: jax.Array, u: jax.Array, temperature: jax.Array):
    """In-graph top-k temperature sampling.

    ``u`` is a uniform [0,1) scalar supplied by the Rust PRNG (keeps runs
    deterministic and seed-driven from the coordinator). ``temperature`` ~ 0
    degenerates to argmax (probability mass collapses onto the top logit).

    Implemented as sort + threshold + inverse-CDF over the vocab axis (NOT
    ``lax.top_k``): the modern ``topk`` HLO op is rejected by xla_extension
    0.5.1's text parser, while ``sort``/``cumsum`` round-trip fine.
    """
    v = logits.shape[0]
    kth = jnp.sort(logits)[v - SPAN_TOP_K]  # k-th largest as threshold
    masked = jnp.where(logits >= kth, logits, -1e30)
    probs = jax.nn.softmax(masked / jnp.maximum(temperature, 1e-4))
    c = jnp.cumsum(probs)
    j = jnp.sum((c < u).astype(jnp.int32))
    return jnp.clip(j, 0, v - 1)


def decode_span(
    cfg: DecoderConfig,
    plist: list[jax.Array],
    names: list[str],
    token: jax.Array,
    pos: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    u: jax.Array,
    temperature: jax.Array,
    use_kernels: bool = True,
):
    """Run ``len(u)`` fused decode steps.

    token: [1] int32 (first input token, at position ``pos``); u: [SPAN]
    float32 uniforms (one per sampled token); temperature: [1] float32.
    Returns (tokens [SPAN] int32 — the sampled continuation, k_cache,
    v_cache). The Rust generator truncates at EOS.
    """
    p = dict(zip(names, plist))
    span = u.shape[0]
    tokens = []
    tok = token
    for i in range(span):
        logits, k_cache, v_cache = _decode_step_p(
            cfg, p, tok, pos + i, k_cache, v_cache, use_kernels
        )
        nxt = _sample_topk(logits, u[i], temperature[0])
        tokens.append(nxt)
        tok = nxt[None]
    return jnp.stack(tokens), k_cache, v_cache


# ---------------------------------------------------------------------------
# Device-resident decode (DESIGN.md §Perf L2): the same computations with a
# *packed single-root* calling convention. All decode state — both KV caches
# plus a vocab-wide "tail" carrying the step's logits (or the span's sampled
# token ids) — is one flat f32 array, and each executable returns exactly one
# array (lowered with return_tuple=False, manifest "untupled": true). A
# single-root output comes back from PJRT as a plain device buffer that the
# Rust runtime feeds straight into the next step (`ExecArg::Device`), so the
# KV cache never crosses the host boundary; tiny `peek_*` executables slice
# out the logits / token ids, making the per-step fetch O(vocab) / O(span).
#
# The packing is pure reshape/concat/slice around the UNCHANGED step
# functions above, so the resident and literal transports compute the same
# math — the Rust integration test gates bit-identical token streams.
# ---------------------------------------------------------------------------


def _kv_numel(cfg: DecoderConfig) -> int:
    return cfg.n_layers * cfg.n_heads * cfg.max_seq * cfg.head_dim


def state_len(cfg: DecoderConfig) -> int:
    """Packed decode-state width: k_cache ‖ v_cache ‖ tail[vocab_size]."""
    return 2 * _kv_numel(cfg) + cfg.vocab_size


def _pack_state(cfg: DecoderConfig, k_cache, v_cache, tail):
    pad = cfg.vocab_size - tail.shape[0]
    if pad:
        tail = jnp.concatenate([tail, jnp.zeros((pad,), tail.dtype)])
    return jnp.concatenate([k_cache.reshape(-1), v_cache.reshape(-1), tail])


def _unpack_kv(cfg: DecoderConfig, state):
    n = _kv_numel(cfg)
    shape = (cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    return state[:n].reshape(shape), state[n : 2 * n].reshape(shape)


def prefill_resident(cfg, plist, names, tokens, length, use_kernels=True):
    """``prefill`` with the packed convention: -> state [state_len]."""
    logits, k, v = prefill(cfg, plist, names, tokens, length, use_kernels)
    return _pack_state(cfg, k, v, logits)


def decode_step_resident(cfg, plist, names, token, pos, state, use_kernels=True):
    """``decode_step`` with the packed convention: state -> state'."""
    k, v = _unpack_kv(cfg, state)
    logits, k, v = decode_step(cfg, plist, names, token, pos, k, v, use_kernels)
    return _pack_state(cfg, k, v, logits)


def decode_span_resident(
    cfg, plist, names, token, pos, state, u, temperature, use_kernels=True
):
    """``decode_span`` with the packed convention: the sampled ids ride in
    the tail as exact small-integer f32s (vocab_size << 2**24)."""
    k, v = _unpack_kv(cfg, state)
    tokens, k, v = decode_span(
        cfg, plist, names, token, pos, k, v, u, temperature, use_kernels
    )
    return _pack_state(cfg, k, v, tokens.astype(jnp.float32))


def peek_logits(cfg: DecoderConfig, state):
    """Slice the logits tail out of a packed state: -> [vocab_size]."""
    return state[2 * _kv_numel(cfg) :]


def peek_tokens(cfg: DecoderConfig, state, span: int):
    """Slice the span's sampled token ids out of a packed state: -> [span]."""
    off = 2 * _kv_numel(cfg)
    return jnp.round(state[off : off + span]).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Slot-based batched resident decode (vLLM/Orca-style continuous batching,
# adapted to the packed-state convention above). A batched state is simply B
# packed slot states laid out back to back: state[B * state_len]. Sessions
# claim a slot at prefill time (``prefill_scatter`` writes one packed
# k ‖ v ‖ tail into its slot), and ONE ``decode_batch_resident`` call per
# fairness round advances every *active* slot together — per-slot
# ``tokens[B]`` / ``pos[B]`` inputs plus an ``active[B]`` mask that passes
# inactive slots through untouched. The per-slot math is literally
# ``decode_step_resident`` applied to that slot's sub-state, so a batched
# step is bit-identical to B independent single steps (test-gated below and
# on the Rust side).
# ---------------------------------------------------------------------------


def batch_state_len(cfg: DecoderConfig, batch: int) -> int:
    """Packed batched decode-state width: ``batch`` back-to-back slots."""
    return batch * state_len(cfg)


def prefill_scatter(
    cfg, plist, names, tokens, length, slot, batch_state, use_kernels=True
):
    """``prefill_resident`` scattered into slot ``slot`` of a batched state.

    tokens: [max_prefill] int32 (one prompt), length: [1] int32,
    slot: [1] int32, batch_state: [B * state_len]. Returns the batched state
    with the slot's sub-state replaced; every other slot is untouched.
    """
    one = prefill_resident(cfg, plist, names, tokens, length, use_kernels)
    off = slot[0] * state_len(cfg)
    return jax.lax.dynamic_update_slice(batch_state, one, (off,))


def decode_batch_resident(
    cfg, plist, names, tokens, pos, active, batch_state, use_kernels=True
):
    """One decode step for every active slot, in one executable call.

    tokens: [B] int32 (per-slot input token), pos: [B] int32 (per-slot write
    position), active: [B] int32 (1 = advance, 0 = pass through),
    batch_state: [B * state_len]. Inactive slots still compute a (masked
    out) step — the batch shape is static — but their state rides through
    unchanged, so freed/unclaimed slots can hold garbage safely.
    """
    sl = state_len(cfg)
    batch = tokens.shape[0]
    outs = []
    for b in range(batch):
        st = batch_state[b * sl : (b + 1) * sl]
        new = decode_step_resident(
            cfg, plist, names, tokens[b : b + 1], pos[b : b + 1], st, use_kernels
        )
        outs.append(jnp.where(active[b] > 0, new, st))
    return jnp.concatenate(outs)


# ---------------------------------------------------------------------------
# Resume-capable prefill (cross-request KV prefix reuse). A cached packed
# state from *any* earlier prefill whose first ``prefix_len`` tokens match
# this prompt supplies K/V[:, :prefix_len]; only the suffix rows are
# recomputed. ``prefix_len`` is a static chunk boundary (configs.
# PREFIX_CHUNKS) baked into the artifact name, because XLA shapes are static.
#
# Bit-identity argument (test-gated below and in tests/test_resume.py):
# causal masking makes every K/V row at position p a function of tokens
# [0, p] only, and the ``kpos < length`` mask term is redundant for rows
# below ``length`` (causality already excludes those keys), so cached prefix
# rows are independent of the *donor* prompt's suffix and total length.
# Suffix hidden states are recomputed with the same per-row math as the cold
# prefill: the q/k/v projections, norms, and FFN run on suffix rows only
# (the savings), while attention runs at the cold prefill's full
# [H, max_prefill, hd] shape — cached K/V fill the prefix key rows and the
# prefix *query* rows are zero padding whose output is discarded. Attention
# output rows are independent of other query rows, so the suffix rows come
# out bitwise equal to the cold pass at identical tile shapes.
# ---------------------------------------------------------------------------


def prefill_resume(
    cfg: DecoderConfig,
    plist,
    names,
    tokens: jax.Array,
    length: jax.Array,
    prefix_state: jax.Array,
    prefix_len: int,
    use_kernels: bool = True,
):
    """Prompt pass resumed from a cached packed prefix state.

    tokens: [max_prefill] int32 — the FULL prompt (prefix included), padded;
    length: [1] int32, with length[0] > prefix_len;
    prefix_state: [state_len] — packed ``k ‖ v ‖ tail`` from a prior prefill
    of any prompt sharing the first ``prefix_len`` tokens (the tail and the
    positions >= prefix_len are ignored); prefix_len: static Python int.
    Returns a packed state [state_len] bitwise equal to a cold
    ``prefill_resident`` over the same tokens/length.
    """
    p = dict(zip(names, plist))
    rms, mm, attn = _ops(use_kernels)
    pmax, smax = cfg.max_prefill, cfg.max_seq
    pre = prefix_len
    if not 0 < pre < pmax:
        raise ValueError(f"prefix_len {pre} outside (0, {pmax})")
    ck, cv = _unpack_kv(cfg, prefix_state)
    # Suffix hidden states only: [S, d] with S = pmax - prefix_len.
    h = p["tok_emb"][tokens[pre:]] + p["pos_emb"][pre:pmax]
    k_cache = jnp.zeros((cfg.n_layers, cfg.n_heads, smax, cfg.head_dim), h.dtype)
    v_cache = jnp.zeros_like(k_cache)
    for layer in range(cfg.n_layers):
        lp = _layer_params(p, layer)
        hn = rms(h, lp["ln1_w"])
        qkv = mm(hn, lp["w_qkv"], lp["b_qkv"])  # [S, 3d]
        q, k, v = (
            _split_heads(t, cfg.n_heads) for t in jnp.split(qkv, 3, axis=-1)
        )  # [H, S, hd]
        # Full-width K/V: cached prefix rows ‖ recomputed suffix rows.
        k_full = jnp.concatenate([ck[layer, :, :pre, :], k], axis=1)
        v_full = jnp.concatenate([cv[layer, :, :pre, :], v], axis=1)
        # Zero-pad the prefix query rows so attention runs at the cold
        # prefill's exact [H, pmax, hd] shape; their output is discarded.
        q_full = jnp.concatenate(
            [jnp.zeros((cfg.n_heads, pre, cfg.head_dim), h.dtype), q], axis=1
        )
        a = attn(q_full, k_full, v_full, length, causal=True)
        h = h + mm(_merge_heads(a)[pre:, :], lp["w_o"], lp["b_o"])
        hn = rms(h, lp["ln2_w"])
        f = mm(mm(hn, lp["w_ff1"], lp["b_ff1"], "gelu"), lp["w_ff2"], lp["b_ff2"])
        h = h + f
        k_cache = k_cache.at[layer, :, :pmax, :].set(k_full)
        v_cache = v_cache.at[layer, :, :pmax, :].set(v_full)
    hf = rms(h, p["lnf_w"])
    # length[0] - 1 indexes the full prompt; the suffix array starts at pre.
    last = jax.lax.dynamic_slice_in_dim(hf, length[0] - 1 - pre, 1, axis=0)
    logits = mm(
        last,
        p["tok_emb"].T,
        jnp.zeros((cfg.vocab_size,), h.dtype),
        block_n=cfg.vocab_size,
    ) if use_kernels else last @ p["tok_emb"].T
    return _pack_state(cfg, k_cache, v_cache, logits.reshape(cfg.vocab_size))


def prefill_scatter_resume(
    cfg,
    plist,
    names,
    tokens,
    length,
    slot,
    prefix_state,
    batch_state,
    prefix_len: int,
    use_kernels: bool = True,
):
    """``prefill_resume`` scattered into slot ``slot`` of a batched state
    (the resume twin of ``prefill_scatter``)."""
    one = prefill_resume(
        cfg, plist, names, tokens, length, prefix_state, prefix_len, use_kernels
    )
    off = slot[0] * state_len(cfg)
    return jax.lax.dynamic_update_slice(batch_state, one, (off,))


def peek_logits_batch(cfg: DecoderConfig, batch_state, batch: int):
    """Slice every slot's logits tail out of a batched state: -> [B, vocab].

    The only per-round fetch of the batched decode loop: O(B * vocab)
    regardless of the KV bytes resident on device.
    """
    sl = state_len(cfg)
    off = 2 * _kv_numel(cfg)
    rows = [
        batch_state[b * sl + off : b * sl + off + cfg.vocab_size]
        for b in range(batch)
    ]
    return jnp.stack(rows)
