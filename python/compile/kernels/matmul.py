"""Pallas fused matmul + bias + activation kernel (the FFN/projection hot path).

Tiled over (M/block_m, N/block_n); the full K dimension of each operand tile
is resident in VMEM (K <= 1024 here, so a [128, 1024] f32 tile is 512 KiB).
Each tile issues one [block_m, K] x [K, block_n] contraction -- MXU-shaped
work (block sizes are multiples of the 128-lane systolic array on real TPU;
on this CPU testbed the same structure runs under interpret=True).

Bias add and GELU fuse into the epilogue so the activation never round-trips
to HBM -- this is the kernel-level analogue of XLA's fusion the paper's
serving stack relies on.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]
    if activation == "gelu":
        acc = jax.nn.gelu(acc, approximate=True)
    o_ref[...] = acc.astype(o_ref.dtype)


def matmul_bias(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str = "none",
    block_m: int = 64,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """x @ w + b (+ optional GELU). x: [M, K], w: [K, N], b: [N] -> [M, N]."""
    if activation not in ("none", "gelu"):
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    if m % block_m != 0:
        block_m = m
    if n % block_n != 0:
        block_n = n
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, w, b)
