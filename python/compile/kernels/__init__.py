"""L1: Pallas kernels for the TweakLLM substrate models."""
from .attention import attention
from .cosine_topk import cosine_scores, cosine_topk
from .decode_attention import decode_attention
from .matmul import matmul_bias
from .rmsnorm import rmsnorm
