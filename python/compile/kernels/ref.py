"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: each Pallas kernel in this directory
must match its oracle to float32 tolerance across the shape/dtype sweeps in
``python/tests/test_kernels.py`` (hypothesis drives the sweeps).

Keep these boring and obviously-correct: no tiling, no tricks.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last axis. x: [..., D], weight: [D]."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * weight


def matmul_bias(
    x: jax.Array, w: jax.Array, b: jax.Array, activation: str = "none"
) -> jax.Array:
    """x @ w + b with optional fused activation. x: [M, K], w: [K, N], b: [N]."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if activation == "gelu":
        y = jax.nn.gelu(y, approximate=True)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    length: jax.Array,
    causal: bool,
    sm_scale: float | None = None,
) -> jax.Array:
    """Multi-head attention over one (padded) sequence.

    q, k, v: [H, S, hd]; length: scalar int32 (#valid positions, rest pad).
    Key positions >= length are masked; ``causal`` adds the autoregressive
    mask. Returns [H, S, hd]; query rows >= length are meaningless (they
    attend only within the valid prefix) and are excluded from comparisons.
    """
    h, s, hd = q.shape
    scale = sm_scale if sm_scale is not None else 1.0 / float(hd) ** 0.5
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    kpos = jnp.arange(s)
    mask = jnp.broadcast_to(kpos[None, None, :] < length, (h, s, s))
    if causal:
        qpos = jnp.arange(s)
        mask = mask & (kpos[None, None, :] <= qpos[None, :, None])
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    sm_scale: float | None = None,
) -> jax.Array:
    """Single-token attention over a KV cache.

    q: [H, hd]; k_cache, v_cache: [H, S, hd]; pos: scalar int32, index of the
    current token (attends to cache positions 0..=pos). Returns [H, hd].
    """
    h, s, hd = k_cache.shape
    scale = sm_scale if sm_scale is not None else 1.0 / float(hd) ** 0.5
    logits = jnp.einsum("hd,hkd->hk", q, k_cache) * scale
    mask = jnp.arange(s)[None, :] <= pos
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hk,hkd->hd", probs, v_cache)


def cosine_scores(db: jax.Array, q: jax.Array) -> jax.Array:
    """Cosine scores of one L2-normalized query against an L2-normalized DB.

    db: [N, D] (rows normalized), q: [D] (normalized). Returns [N].
    """
    return db @ q
