"""Pallas single-token (decode-step) attention over a KV cache.

Grid = (heads,). Each grid step holds one query row [hd] plus the head's
full [S, hd] K and V cache tile in VMEM and computes a masked softmax over
cache positions 0..=pos. S = 256 and hd = 32 here, so the working set is
64 KiB/head -- the decode step is memory-bound (one MXU-shaped [1, hd] x
[hd, S] product), which matches the serving-paper roofline expectation that
decode attention streams the KV cache.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_attention_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, *, sm_scale):
    q = q_ref[0, :] * sm_scale  # [hd]
    k = k_ref[0, :, :]  # [S, hd]
    v = v_ref[0, :, :]  # [S, hd]
    pos = pos_ref[0]
    s = k.shape[0]
    logits = jnp.dot(k, q, preferred_element_type=jnp.float32)  # [S]
    kpos = jax.lax.broadcasted_iota(jnp.int32, (s, 1), 0)[:, 0]
    logits = jnp.where(kpos <= pos, logits, NEG_INF)
    m = jnp.max(logits)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p)
    o_ref[0, :] = (jnp.dot(p, v, preferred_element_type=jnp.float32) / denom).astype(
        o_ref.dtype
    )


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    sm_scale: float | None = None,
    interpret: bool = True,
) -> jax.Array:
    """q: [H, hd]; caches: [H, S, hd]; pos: [1] int32 -> [H, hd]."""
    h, s, hd = k_cache.shape
    scale = sm_scale if sm_scale is not None else 1.0 / float(hd) ** 0.5
    kernel = functools.partial(_decode_attention_kernel, sm_scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, hd), lambda hi: (hi, 0)),
            pl.BlockSpec((1, s, hd), lambda hi: (hi, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda hi: (hi, 0, 0)),
            pl.BlockSpec((1,), lambda hi: (0,)),
        ],
        out_specs=pl.BlockSpec((1, hd), lambda hi: (hi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, hd), q.dtype),
        interpret=interpret,
    )(q, k_cache, v_cache, pos)
