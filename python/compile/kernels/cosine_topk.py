"""Pallas cosine-similarity scoring kernel for the semantic cache lookup.

The vector store's ANN hot loop: score one L2-normalized query against a
block of L2-normalized DB rows. Grid = (N / block_rows,); each step streams a
[block_rows, D] tile of the DB matrix through VMEM and issues one
[block_rows, D] x [D] product (D = 384: a 4096-row block is 6 MiB, sized so
two blocks double-buffer inside VMEM).

Top-k selection happens outside the kernel (jax.lax.top_k over the scores) --
selection is control-flow-heavy and VPU-bound, while the scoring is the
MXU-shaped 99% of the FLOPs.

At runtime the Rust vector store uses its own native scan for flexibility
(incremental inserts); this artifact exists to (a) validate the L1/L2/L3
path on the exact cache-lookup computation and (b) benchmark the compiled
scorer against the native one (`cargo bench --bench vector_index`).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cosine_kernel(db_ref, q_ref, o_ref):
    o_ref[...] = jnp.dot(
        db_ref[...], q_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def cosine_scores(
    db: jax.Array,
    q: jax.Array,
    block_rows: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """db: [N, D] row-normalized, q: [D] normalized -> scores [N]."""
    n, d = db.shape
    if n % block_rows != 0:
        block_rows = n
    return pl.pallas_call(
        _cosine_kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(db, q)


def cosine_topk(
    db: jax.Array, q: jax.Array, k: int, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """Top-k (scores, indices) of cosine similarity. db: [N, D], q: [D]."""
    scores = cosine_scores(db, q, interpret=interpret)
    return jax.lax.top_k(scores, k)
