"""Pallas RMSNorm kernel.

Row-blocked: each grid step normalizes a [block_rows, D] tile held in VMEM.
D is the model width (128/256 here) so a tile is at most 256 rows x 256 cols
x 4 B = 256 KiB -- comfortably inside a TPU core's ~16 MiB VMEM with room for
double-buffering. The reduction runs on the VPU; there is no MXU work.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * w_ref[...]


def rmsnorm(
    x: jax.Array,
    weight: jax.Array,
    eps: float = 1e-6,
    block_rows: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """RMSNorm over the last axis. x: [rows, D], weight: [D] -> [rows, D]."""
    rows, d = x.shape
    if rows % block_rows != 0:
        block_rows = rows  # fall back to a single tile for ragged shapes
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, weight)
