"""Pallas fused multi-head attention (prefill path), flash-style.

Grid = (heads, query blocks). Each grid step holds one [block_q, hd] query
tile plus the head's full [S, hd] K and V in VMEM (S <= 256, hd = 32 here:
K+V = 64 KiB/head) and walks the key axis in block_kv chunks with an online
softmax (running max / running sum), exactly the FlashAttention recurrence.

TPU adaptation note (DESIGN.md #Hardware-Adaptation): the CUDA formulation
assigns a threadblock per query tile and stages K/V through shared memory;
here the BlockSpec index maps express the same HBM->VMEM schedule and the
per-chunk [block_q, hd] x [hd, block_kv] product is MXU-shaped. On this CPU
testbed the kernel runs under interpret=True (Mosaic custom-calls cannot
execute on the CPU PJRT plugin).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attention_kernel(
    q_ref,
    k_ref,
    v_ref,
    len_ref,
    o_ref,
    *,
    sm_scale: float,
    causal: bool,
    block_q: int,
    block_kv: int,
    seq_len: int,
):
    h_i = pl.program_id(0)
    q_i = pl.program_id(1)
    del h_i  # blocking already selects the head; only q_i is needed below

    q = q_ref[0, :, :] * sm_scale  # [block_q, hd]
    length = len_ref[0]
    q_offset = q_i * block_q
    q_pos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)

    num_kv_blocks = seq_len // block_kv

    def body(kv_i, carry):
        acc, m_prev, l_prev = carry
        kv_offset = kv_i * block_kv
        k = jax.lax.dynamic_slice_in_dim(k_ref[0, :, :], kv_offset, block_kv, axis=0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0, :, :], kv_offset, block_kv, axis=0)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bkv]

        k_pos = kv_offset + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1
        )
        mask = k_pos < length
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_cur = jnp.max(s, axis=-1)  # [bq]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)  # rescale of old accumulator
        p = jnp.exp(s - m_new[:, None])  # [bq, bkv]
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return acc, m_new, l_new

    hd = q.shape[-1]
    init = (
        jnp.zeros((block_q, hd), jnp.float32),
        jnp.full((block_q,), NEG_INF, jnp.float32),
        jnp.zeros((block_q,), jnp.float32),
    )
    acc, _m, l = jax.lax.fori_loop(0, num_kv_blocks, body, init)
    # Fully-masked query rows (padding) have l == 0; guard the divide.
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0, :, :] = (acc / l[:, None]).astype(o_ref.dtype)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    length: jax.Array,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 64,
    block_kv: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """Fused MHA over one padded sequence.

    q, k, v: [H, S, hd]; length: [1] int32 (valid prefix length).
    Returns [H, S, hd]; rows >= length are garbage (masked upstream).
    """
    h, s, hd = q.shape
    if s % block_q != 0:
        block_q = s
    if s % block_kv != 0:
        block_kv = s
    scale = sm_scale if sm_scale is not None else 1.0 / float(hd) ** 0.5
    grid = (h, s // block_q)
    kernel = functools.partial(
        _attention_kernel,
        sm_scale=scale,
        causal=causal,
        block_q=block_q,
        block_kv=block_kv,
        seq_len=s,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((1, s, hd), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((1,), lambda hi, qi: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda hi, qi: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, hd), q.dtype),
        interpret=interpret,
    )(q, k, v, length)
