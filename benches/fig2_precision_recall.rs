//! Figure 2: precision/recall of traditional (GPTCache-style) semantic
//! caching on the Question Pairs dataset, swept over the vector-DB cosine
//! threshold with two cross-encoder re-rankers.
//!
//! Paper shape to reproduce: precision ≈ 0.9 at τ=0.70 (≈10% wrong cached
//! answers even on a curated near-duplicate dataset), rising to ≈0.97 at
//! τ=0.97 — while recall collapses (≈0.2 with the albert re-ranker).
//!
//! `cargo bench --bench fig2_precision_recall [-- --pairs 600]`

use tweakllm::baselines::{AlbertLike, CrossEncoder, DistilRobertaLike};
use tweakllm::bench::{bench_args, load_embedder, Table};
use tweakllm::datasets::QuestionPairDataset;
use tweakllm::eval::precision_recall::{paper_thresholds, sweep};

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let n_pairs = args.usize("pairs", 600)?;
    let seed = args.u64("seed", 20250923)?;

    eprintln!("[fig2] loading artifacts + embedding model...");
    let (_rt, embedder) = load_embedder()?;
    let ds = QuestionPairDataset::generate(n_pairs, seed);
    eprintln!("[fig2] {} labeled pairs generated", ds.len());

    let thresholds = paper_thresholds();
    type MakeRerank = Box<dyn Fn() -> Box<dyn CrossEncoder>>;
    let rerankers: Vec<(&str, MakeRerank)> = vec![
        (
            "albert-duplicate(proxy)",
            Box::new(|| Box::new(AlbertLike::default()) as Box<dyn CrossEncoder>),
        ),
        (
            "quora-distilroberta(proxy)",
            Box::new(|| Box::new(DistilRobertaLike::default()) as Box<dyn CrossEncoder>),
        ),
    ];

    let mut table = Table::new(
        "Fig 2 — precision/recall vs cosine threshold (GPTCache architecture)",
        &["reranker", "threshold", "precision", "recall", "hits"],
    );
    for (name, make) in &rerankers {
        let points = sweep(&ds.pairs, &embedder, make, &thresholds)?;
        for p in &points {
            table.push(vec![
                name.to_string(),
                format!("{:.2}", p.threshold),
                format!("{:.3}", p.counts.precision()),
                format!("{:.3}", p.counts.recall()),
                p.hits.to_string(),
            ]);
        }
        let lo = &points[0];
        let hi = points.iter().find(|p| p.threshold >= 0.96).unwrap_or(lo);
        eprintln!(
            "[fig2] {name}: precision {:.3}@{:.2} -> {:.3}@{:.2}; recall {:.3} -> {:.3} (paper: ~0.90 -> ~0.97 with recall collapse)",
            lo.counts.precision(),
            lo.threshold,
            hi.counts.precision(),
            hi.threshold,
            lo.counts.recall(),
            hi.counts.recall(),
        );
    }
    println!("{}", table.render());
    Ok(())
}
