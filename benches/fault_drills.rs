//! Fault drill: a mid-run tweak-LLM outage against the full mock engine.
//!
//! Three measured phases — before (healthy), during (the Small-LLM backend
//! hard-errors via its `FaultSwitch`), after (healed, breaker cool-down
//! elapsed) — each under concurrent client threads. The drill asserts the
//! availability contract of the degradation ladder: every request is
//! answered in every phase (degraded tweak-hits serve the raw cached
//! response, tagged `degraded_hit`), nothing hangs, nothing fails.
//!
//! A second A/B pass runs the same healthy workload with `[faults]` enabled
//! vs disabled and gates the fault layer's p50 overhead at ≤ 2%.
//!
//! Results land in `BENCH_fault_drills.json` (uploaded from CI).
//!
//! `cargo bench --bench fault_drills [-- --requests 240 --threads 4]`

use std::time::{Duration, Instant};

use tweakllm::baselines::MockLlm;
use tweakllm::bench::{bench_args, Table};
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Engine, EngineHandle, Pathway, Router};
use tweakllm::faults::{FaultMode, FaultSwitch, FaultyLlm};
use tweakllm::llm::LanguageModel;
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::util::{Json, Rng, Summary};

const TOPICS: usize = 8;

/// Engine with the Small (tweak) LLM behind a `FaultSwitch` the drill flips
/// mid-run. Decode pacing is millisecond-scale so phase p50s sit well above
/// scheduler jitter and the ≤2% overhead gate is meaningful.
fn drill_engine(faults_on: bool) -> anyhow::Result<(Engine, EngineHandle, FaultSwitch)> {
    let mut cfg = Config::paper();
    cfg.index.kind = IndexKindConfig::Flat;
    cfg.exact_match_fast_path = true;
    cfg.scheduler.enabled = true;
    cfg.faults.enabled = faults_on;
    // Backstop reaper + a short breaker cool-down so the "after" phase can
    // observe the half-open -> closed recovery inside the drill window.
    cfg.faults.tweak_timeout_ms = 250;
    cfg.faults.breaker_open_ms = 100;
    let switch = FaultSwitch::healthy();
    let s = switch.clone();
    let (engine, handle) = Engine::start(move || {
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        let mut big = MockLlm::new("big");
        big.steps = 16;
        big.step_delay = Duration::from_millis(1);
        let mut small = MockLlm::new("small");
        small.steps = 8;
        small.step_delay = Duration::from_millis(1);
        let small: Box<dyn LanguageModel> = Box::new(FaultyLlm::new(Box::new(small), s));
        Ok(Router::with_models(embedder, Box::new(big), small, cfg))
    })?;
    Ok((engine, handle, switch))
}

fn prime(handle: &EngineHandle) -> anyhow::Result<()> {
    for i in 0..TOPICS {
        handle.request(&format!("mix{i}a mix{i}b mix{i}c mix{i}d mix{i}e mix{i}f"))?;
    }
    Ok(())
}

struct PhaseResult {
    name: &'static str,
    n: usize,
    ok: usize,
    degraded: usize,
    tweak_hits: usize,
    lat_ms: Vec<f64>,
    wall: Duration,
}

impl PhaseResult {
    fn availability(&self) -> f64 {
        self.ok as f64 / self.n.max(1) as f64
    }

    fn row(&self) -> Vec<String> {
        let s = Summary::of(&self.lat_ms);
        vec![
            self.name.to_string(),
            self.n.to_string(),
            format!("{:.1}%", 100.0 * self.availability()),
            self.degraded.to_string(),
            self.tweak_hits.to_string(),
            format!("{:.2}", s.p50),
            format!("{:.2}", s.p99),
        ]
    }

    fn json(&self) -> Json {
        let s = Summary::of(&self.lat_ms);
        Json::obj_from(vec![
            ("phase", Json::s(self.name)),
            ("n", Json::num(self.n as f64)),
            ("availability", Json::num(self.availability())),
            ("degraded_hits", Json::num(self.degraded as f64)),
            ("tweak_hits", Json::num(self.tweak_hits as f64)),
            ("p50_ms", Json::num(s.p50)),
            ("p99_ms", Json::num(s.p99)),
            ("qps", Json::num(self.n as f64 / self.wall.as_secs_f64().max(1e-9))),
        ])
    }
}

/// One measured phase: a deterministic ~70% paraphrase / ~30% fresh-miss
/// mix over `threads` concurrent clients. Every outcome is recorded —
/// errors count against availability instead of aborting the drill.
fn run_phase(
    handle: &EngineHandle,
    name: &'static str,
    phase: usize,
    n: usize,
    threads: usize,
) -> PhaseResult {
    let mut rng = Rng::new(42 + phase as u64);
    let queries: Vec<String> = (0..n)
        .map(|j| {
            let i = rng.range(0, TOPICS);
            match rng.range(0, 10) {
                0..=6 => format!("mix{i}a mix{i}b mix{i}c mix{i}d mix{i}e ph{phase}v{j}"),
                _ => format!("fr{phase}q{j}a fr{phase}q{j}b fr{phase}q{j}c fr{phase}q{j}d"),
            }
        })
        .collect();
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for t in 0..threads {
        let h = handle.clone();
        let chunk: Vec<String> = queries.iter().skip(t).step_by(threads).cloned().collect();
        joins.push(std::thread::spawn(move || {
            let mut out = Vec::with_capacity(chunk.len());
            for q in &chunk {
                out.push(h.request(q).map(|r| (r.pathway, r.total_micros)));
            }
            out
        }));
    }
    let mut result = PhaseResult {
        name,
        n,
        ok: 0,
        degraded: 0,
        tweak_hits: 0,
        lat_ms: Vec::with_capacity(n),
        wall: Duration::ZERO,
    };
    for j in joins {
        for r in j.join().expect("client thread panicked") {
            if let Ok((pathway, us)) = r {
                result.ok += 1;
                result.lat_ms.push(us as f64 / 1000.0);
                match pathway {
                    Pathway::DegradedHit => result.degraded += 1,
                    Pathway::TweakHit => result.tweak_hits += 1,
                    _ => {}
                }
            }
        }
    }
    result.wall = t0.elapsed();
    result
}

/// Healthy-workload pass for the overhead A/B: same engine, same mix, no
/// injection — only `cfg.faults.enabled` differs between the two runs.
fn run_ab(faults_on: bool, n: usize, threads: usize) -> anyhow::Result<PhaseResult> {
    let (engine, handle, _switch) = drill_engine(faults_on)?;
    prime(&handle)?;
    let name = if faults_on { "faults_on" } else { "faults_off" };
    let result = run_phase(&handle, name, 0, n, threads);
    engine.shutdown();
    Ok(result)
}

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let n_requests = args.usize("requests", 240)?;
    let threads = args.usize("threads", 4)?.max(1);
    let per_phase = (n_requests / 3).max(8);

    // ---- the drill: tweak-LLM outage mid-run ----
    eprintln!("[faults] drill: {per_phase} requests/phase × 3 phases, {threads} threads...");
    let (engine, handle, switch) = drill_engine(true)?;
    prime(&handle)?;

    let before = run_phase(&handle, "before", 0, per_phase, threads);
    switch.set(FaultMode::Error);
    let during = run_phase(&handle, "during", 1, per_phase, threads);
    switch.set(FaultMode::Healthy);
    // Let the small-LLM breaker cool down so "after" measures recovery, not
    // the tail of the open window.
    std::thread::sleep(Duration::from_millis(150));
    let after = run_phase(&handle, "after", 2, per_phase, threads);
    let stats = handle.stats()?;
    engine.shutdown();

    let mut table = Table::new(
        "Fault drill: tweak-LLM outage (mock engine) — per-phase availability",
        &["phase", "n", "avail", "degraded", "tweak_hits", "p50_ms", "p99_ms"],
    );
    for p in [&before, &during, &after] {
        table.push(p.row());
    }
    println!("{}", table.render());
    println!(
        "drill: {} degraded hits, {} breaker trips, small breaker now '{}'",
        stats.degraded_hits, stats.breaker_trips, stats.breaker_small
    );

    // The availability contract, enforced: every request answered in every
    // phase, the outage is absorbed by the degraded rung, and the ladder
    // steps back up once the backend heals.
    for p in [&before, &during, &after] {
        assert_eq!(p.ok, p.n, "phase '{}': every request must be answered", p.name);
        assert!(p.wall < Duration::from_secs(120), "phase '{}' stalled", p.name);
    }
    assert_eq!(before.degraded, 0, "healthy phase must not degrade");
    assert!(during.degraded > 0, "outage phase must exercise the degraded rung");
    assert!(after.tweak_hits > 0, "tweak pathway must recover after the outage");
    assert_eq!(stats.failed, 0, "no request may fail terminally in this drill");
    assert_eq!(stats.shed, 0, "no deadline is set; nothing may be shed");

    // ---- overhead A/B: the fault layer itself must be ~free ----
    eprintln!("[faults] overhead A/B: {n_requests} healthy requests, faults on vs off...");
    let on = run_ab(true, n_requests, threads)?;
    let off = run_ab(false, n_requests, threads)?;
    let (p50_on, p50_off) = (Summary::of(&on.lat_ms).p50, Summary::of(&off.lat_ms).p50);
    let overhead_pct = 100.0 * (p50_on - p50_off) / p50_off.max(1e-9);
    println!(
        "overhead: p50 {p50_on:.3}ms (faults on) vs {p50_off:.3}ms (off) -> {overhead_pct:+.2}%"
    );
    assert!(
        overhead_pct <= 2.0,
        "fault layer p50 overhead must stay within 2%: got {overhead_pct:+.2}%"
    );

    // ---- BENCH_fault_drills.json ----
    let top = vec![
        ("bench", Json::s("fault_drills")),
        ("requests", Json::num(n_requests as f64)),
        ("threads", Json::num(threads as f64)),
        ("per_phase", Json::num(per_phase as f64)),
        ("phases", Json::Arr(vec![before.json(), during.json(), after.json()])),
        ("degraded_hits", Json::num(stats.degraded_hits as f64)),
        ("breaker_trips", Json::num(stats.breaker_trips as f64)),
        (
            "overhead",
            Json::obj_from(vec![
                ("p50_on_ms", Json::num(p50_on)),
                ("p50_off_ms", Json::num(p50_off)),
                ("overhead_pct", Json::num(overhead_pct)),
            ]),
        ),
    ];
    std::fs::write("BENCH_fault_drills.json", Json::obj_from(top).to_string())?;
    eprintln!("[faults] wrote BENCH_fault_drills.json");
    Ok(())
}
