//! Figures 5, 6, 7: multi-agent LLM-as-evaluator debate verdicts.
//!
//! * Fig 5 — Big direct vs Small **tweaked**, Question Pairs dataset.
//! * Fig 6 — Big direct vs Small **direct** (control validating the
//!   method: small must be clearly inferior everywhere).
//! * Fig 7 — Big direct vs Small tweaked, LMSYS-like dataset (half the
//!   trace inserted, the rest queried; paper scale 248,808/82,700 is run
//!   scaled down by --scale, default 20x smaller, same protocol).
//!
//! Paper shape: "tweaked better-or-on-par" grows with the similarity band —
//! QP: 32.9% / 40.1% / 46.1%; LMSYS: 27.5% / 37.7% / 47.9%.
//!
//! `cargo bench --bench fig5_6_7_debate [-- --pairs 2000 --lmsys-n 16000]`

use tweakllm::bench::{bench_args, load_embedder, Table};
use tweakllm::cache::{FlatIndex, VectorIndex};
use tweakllm::datasets::{ChatTrace, IntentKey, QuestionPairDataset, TraceProfile};
use tweakllm::eval::debate::{debate, default_personas, DebateConfig, VerdictCounts};
use tweakllm::eval::quality::QualityModel;
use tweakllm::eval::Band;
use tweakllm::runtime::TextEmbedder;
use tweakllm::util::Rng;

/// A cache hit ready for judging: (band, similarity, new intent, cached intent).
struct Hit {
    band: Band,
    sim: f32,
    new_intent: IntentKey,
    cached_intent: IntentKey,
}

fn collect_hits(
    inserted: &[(String, IntentKey)],
    queried: &[(String, IntentKey)],
    embedder: &dyn TextEmbedder,
) -> anyhow::Result<Vec<Hit>> {
    let ins_texts: Vec<&str> = inserted.iter().map(|(t, _)| t.as_str()).collect();
    let q_texts: Vec<&str> = queried.iter().map(|(t, _)| t.as_str()).collect();
    let mut index = FlatIndex::new(embedder.out_dim());
    for e in embedder.embed_batch(&ins_texts)? {
        index.insert(&e);
    }
    let mut hits = Vec::new();
    for (qi, e) in embedder.embed_batch(&q_texts)?.iter().enumerate() {
        if let Some(h) = index.search(e, 1).first() {
            if let Some(band) = Band::of(h.score) {
                hits.push(Hit {
                    band,
                    sim: h.score,
                    new_intent: queried[qi].1,
                    cached_intent: inserted[h.id].1,
                });
            }
        }
    }
    Ok(hits)
}

fn judge(
    hits: &[Hit],
    tweaked: bool, // false => small-direct control (Fig 6)
    seed: u64,
    tag: &str,
) -> Vec<(Band, VerdictCounts)> {
    let personas = default_personas();
    let cfg = DebateConfig::default();
    let mut qm = QualityModel::new(seed ^ 0xD0D0);
    let mut rng = Rng::substream(seed, tag);
    let mut per_band: std::collections::HashMap<Band, VerdictCounts> = Default::default();
    for h in hits {
        let big = qm.big_direct();
        let small = if tweaked {
            qm.small_tweaked(h.sim, Some((&h.new_intent, &h.cached_intent)))
        } else {
            qm.small_direct()
        };
        // A = Big direct, B = Small (paper's labeling convention)
        let outcome = debate(&big, &small, &personas, &cfg, &mut rng);
        per_band.entry(h.band).or_default().push(outcome.verdict);
    }
    Band::ALL
        .iter()
        .map(|b| (*b, per_band.get(b).copied().unwrap_or_default()))
        .collect()
}

fn render(title: &str, rows: &[(Band, VerdictCounts)], paper: [f64; 3]) -> Table {
    let mut t = Table::new(
        title,
        &["band", "n", "Big", "Small", "AB", "Small-or-AB %", "paper %"],
    );
    for ((band, c), p) in rows.iter().zip(paper) {
        t.push(vec![
            band.label().to_string(),
            c.total().to_string(),
            c.a.to_string(),
            c.b.to_string(),
            c.ab.to_string(),
            format!("{:.1}", 100.0 * c.frac_b_or_draw()),
            format!("{p:.1}"),
        ]);
    }
    t
}

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let n_pairs = args.usize("pairs", 2000)?;
    let lmsys_n = args.usize("lmsys-n", 16_000)?;
    let seed = args.u64("seed", 20250923)?;

    eprintln!("[fig5-7] loading artifacts + embedding model...");
    let (_rt, embedder) = load_embedder()?;

    // ---------- Question Pairs: Figs 5 & 6 ----------
    let ds = QuestionPairDataset::generate(n_pairs, seed);
    let inserted: Vec<(String, IntentKey)> =
        ds.pairs.iter().map(|p| (p.q1.text.clone(), p.q1.intent)).collect();
    let queried: Vec<(String, IntentKey)> =
        ds.pairs.iter().map(|p| (p.q2.text.clone(), p.q2.intent)).collect();
    eprintln!("[fig5-7] embedding {} + {} question-pair queries...", inserted.len(), queried.len());
    let qp_hits = collect_hits(&inserted, &queried, &embedder)?;
    eprintln!("[fig5-7] question-pairs cache hits: {}", qp_hits.len());

    let fig5 = judge(&qp_hits, true, seed, "fig5");
    println!("{}", render(
        "Fig 5 — debate: Big vs Small-Tweaked (Question Pairs)",
        &fig5,
        [32.9, 40.1, 46.1],
    ).render());

    let fig6 = judge(&qp_hits, false, seed, "fig6");
    println!("{}", render(
        "Fig 6 — debate control: Big vs Small-Direct (Question Pairs)",
        &fig6,
        [10.0, 10.0, 10.0], // paper: clearly inferior across the board
    ).render());

    // control sanity: small-direct must lose much more often than tweaked
    for ((_, t5), (_, t6)) in fig5.iter().zip(&fig6) {
        if t5.total() > 20 && t6.total() > 20 {
            assert!(
                t6.frac_b_or_draw() < t5.frac_b_or_draw(),
                "control violated: direct {:.2} !< tweaked {:.2}",
                t6.frac_b_or_draw(),
                t5.frac_b_or_draw()
            );
        }
    }

    // ---------- LMSYS-like: Fig 7 ----------
    let trace = ChatTrace::generate(TraceProfile::lmsys(), lmsys_n, seed);
    let (first, second) = trace.halves();
    let inserted: Vec<(String, IntentKey)> =
        first.iter().map(|q| (q.text.clone(), q.intent)).collect();
    let queried: Vec<(String, IntentKey)> =
        second.iter().map(|q| (q.text.clone(), q.intent)).collect();
    eprintln!(
        "[fig5-7] embedding LMSYS-like trace: insert {} / query {} (paper: 248,808/82,700 scaled)",
        inserted.len(),
        queried.len()
    );
    let lmsys_hits = collect_hits(&inserted, &queried, &embedder)?;
    eprintln!("[fig5-7] lmsys hits: {}", lmsys_hits.len());
    let fig7 = judge(&lmsys_hits, true, seed, "fig7");
    println!("{}", render(
        "Fig 7 — debate: Big vs Small-Tweaked (LMSYS-like)",
        &fig7,
        [27.5, 37.7, 47.9],
    ).render());

    // monotonicity: the paper's central trend
    for rows in [&fig5, &fig7] {
        let fracs: Vec<f64> = rows.iter().map(|(_, c)| c.frac_b_or_draw()).collect();
        if rows.iter().all(|(_, c)| c.total() > 20) {
            assert!(
                fracs[0] < fracs[2],
                "trend violated: band 0.7-0.8 ({:.2}) should trail 0.9-1.0 ({:.2})",
                fracs[0],
                fracs[2]
            );
        }
    }
    Ok(())
}
