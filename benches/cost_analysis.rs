//! §5.2.3 cost analysis: expected inference-cost reduction from routing
//! cache hits to the Small LLM, given the measured hit-rate curves and the
//! 25x per-token price ratio (Table 1).
//!
//! Paper: WildChat → 61% of the original cost; LMSYS → 35%.
//!
//! Two estimates are reported:
//! * analytic — from the hit-rate at τ (the paper's method);
//! * measured — replaying the second half of the trace through the actual
//!   router with a live, growing cache and real token accounting (mock
//!   generation so the run is token-count-faithful but fast).
//!
//! `cargo bench --bench cost_analysis [-- --n 12000]`

use tweakllm::bench::{bench_args, load_embedder, Table};
use tweakllm::datasets::{ChatTrace, TraceProfile};
use tweakllm::eval::hit_rate::run;

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let n = args.usize("n", 12_000)?;
    let seed = args.u64("seed", 20250923)?;
    let price_ratio = args.f64("price-ratio", 25.0)?;

    eprintln!("[cost] loading artifacts + embedding model...");
    let (_rt, embedder) = load_embedder()?;

    let mut table = Table::new(
        "§5.2.3 — cost as % of no-cache (all-Big) baseline, 25x price ratio",
        &["dataset", "τ", "hit rate %", "cost %", "paper %"],
    );
    for (profile, paper_pct) in [
        (TraceProfile::lmsys(), 35.0),
        (TraceProfile::wildchat(), 61.0),
    ] {
        let trace = ChatTrace::generate(profile, n, seed);
        let (a, b) = trace.halves();
        eprintln!("[cost] {}: embedding {} + {}...", profile.name, a.len(), b.len());
        let curve = run(a, b, &embedder)?;
        for tau in [0.7f32, 0.8, 0.9] {
            let hr = curve.hit_rate_at(tau);
            let cost = curve.cost_ratio(tau, price_ratio);
            table.push(vec![
                profile.name.to_string(),
                format!("{tau:.1}"),
                format!("{:.1}", hr * 100.0),
                format!("{:.1}", cost * 100.0),
                if (tau - 0.8).abs() < 1e-6 {
                    format!("{paper_pct:.0}")
                } else {
                    "-".to_string()
                },
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "note: the paper computes savings from the τ=0.8 hit mass and the 25x \
         API price ratio; the analytic rows use the same formula on our measured curves."
    );
    Ok(())
}
