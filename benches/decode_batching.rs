//! Slot-batched decode benchmark: aggregate decode throughput and
//! per-session latency as active-session count grows, batched (collective
//! slot pool, one paced dispatch per fairness round) vs per-session
//! dispatch (one paced unit per session per round).
//!
//! Mock tier (always runs, incl. CI): the scheduler drives S concurrent
//! Big-LLM miss generations over `MockLlm` paced at `--delay-us` per
//! dispatch. Per-session mode pays the delay once per session per round —
//! aggregate tok/s stays flat as S grows. Batched mode pays it once per
//! ROUND regardless of S — aggregate tok/s scales with S. That is exactly
//! the hardware economics the `{m}_decode_batch{B}_res` artifacts buy on
//! the substrate (one kernel launch amortized over B slots), modeled with
//! sleeps so the trajectory is CI-measurable without artifacts.
//!
//! Results land in `BENCH_decode_batching.json` (uploaded from CI).
//!
//! `cargo bench --bench decode_batching [-- --steps 32 --delay-us 500 --iters 3]`

use std::time::Instant;

use tweakllm::baselines::MockLlm;
use tweakllm::bench::{bench_args, Table};
use tweakllm::cache::query_key;
use tweakllm::config::{Config, IndexKindConfig, SchedulerConfig};
use tweakllm::coordinator::{Job, JobKind, Pathway, RouteDecision, Router, Scheduler};
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::trace::TraceBuilder;
use tweakllm::util::{Json, Summary};

const SESSIONS: [usize; 4] = [1, 2, 4, 8];

struct Cell {
    mode: &'static str,
    sessions: usize,
    tok_per_sec: f64,
    p50_ms: f64,
    p99_ms: f64,
    batched_steps: u64,
    mean_active: f64,
}

/// One measured run: S concurrent misses driven to completion by the
/// scheduler's fairness rounds. Returns (wall seconds, per-session latency
/// samples in ms, pool dispatches, mean occupancy).
fn run_once(
    batched: bool,
    sessions: usize,
    steps: usize,
    delay: std::time::Duration,
    iter: usize,
) -> anyhow::Result<(f64, Vec<f64>, u64, f64)> {
    let mut cfg = Config::paper();
    cfg.index.kind = IndexKindConfig::Flat;
    cfg.exact_match_fast_path = true;
    cfg.scheduler = SchedulerConfig {
        enabled: true,
        max_concurrent_sessions: sessions.max(1),
        fairness_steps: 1,
        decode_batch: if batched { 8 } else { 0 },
    };
    let mut big = MockLlm::new("big").with_pace(steps, delay);
    if batched {
        big = big.with_batch(8);
    }
    let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
    let mut router =
        Router::with_models(embedder, Box::new(big), Box::new(MockLlm::new("small")), cfg);
    let mut sched = Scheduler::new(router.config.scheduler);

    let mut rxs = Vec::with_capacity(sessions);
    let t0 = Instant::now();
    for i in 0..sessions {
        // disjoint word sets: every query is a fresh miss
        let q = format!("s{iter}x{i}a s{iter}x{i}b s{iter}x{i}c s{iter}x{i}d");
        let (tx, rx) = std::sync::mpsc::channel();
        let emb = router.embedder().embed(&q)?;
        match router.route(&q, emb, Instant::now(), &mut TraceBuilder::disabled()) {
            RouteDecision::Miss(m) => {
                let key = query_key(&m.query);
                let job = Job::new(JobKind::Miss { job: m, key }, tx, Instant::now());
                sched.submit(job, &mut router);
            }
            _ => anyhow::bail!("bench queries must be misses"),
        }
        rxs.push(rx);
    }
    sched.drain(&mut router);
    let wall = t0.elapsed().as_secs_f64();
    let mut lat = Vec::with_capacity(sessions);
    for rx in rxs {
        let r = rx.recv()??;
        assert_eq!(r.pathway, Pathway::Miss);
        lat.push(r.total_micros as f64 / 1000.0);
    }
    let (dispatches, mean_active) = router
        .batch_stats()
        .map(|b| {
            let mean = if b.dispatches == 0 {
                0.0
            } else {
                b.active_slot_sum as f64 / b.dispatches as f64
            };
            (b.dispatches, mean)
        })
        .unwrap_or((0, 0.0));
    Ok((wall, lat, dispatches, mean_active))
}

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let steps = args.usize("steps", 32)?;
    let delay_us = args.u64("delay-us", 500)?;
    let iters = args.usize("iters", 3)?.max(1);
    let delay = std::time::Duration::from_micros(delay_us);

    let mut cells: Vec<Cell> = Vec::new();
    for &mode in &["per_session", "batched"] {
        let batched = mode == "batched";
        for &s in &SESSIONS {
            let mut walls = Vec::new();
            let mut lat = Vec::new();
            let mut dispatches = 0u64;
            let mut mean_active = 0.0;
            for iter in 0..iters {
                let (w, mut l, d, m) = run_once(batched, s, steps, delay, iter)?;
                walls.push(w);
                lat.append(&mut l);
                dispatches += d;
                mean_active += m / iters as f64;
            }
            let mean_wall = walls.iter().sum::<f64>() / walls.len() as f64;
            let summary = Summary::of(&lat);
            cells.push(Cell {
                mode,
                sessions: s,
                tok_per_sec: (s * steps) as f64 / mean_wall.max(1e-12),
                p50_ms: summary.p50,
                p99_ms: summary.p99,
                batched_steps: dispatches,
                mean_active,
            });
        }
    }

    let mut table = Table::new(
        "Decode batching (mock tier) — aggregate tok/s and per-session latency",
        &["mode", "sessions", "tok/s", "p50 ms", "p99 ms", "dispatches", "occupancy"],
    );
    for c in &cells {
        table.push(vec![
            c.mode.to_string(),
            c.sessions.to_string(),
            format!("{:.0}", c.tok_per_sec),
            format!("{:.2}", c.p50_ms),
            format!("{:.2}", c.p99_ms),
            c.batched_steps.to_string(),
            format!("{:.2}", c.mean_active),
        ]);
    }
    println!("{}", table.render());

    let get = |mode: &str, s: usize| -> &Cell {
        cells
            .iter()
            .find(|c| c.mode == mode && c.sessions == s)
            .expect("cell")
    };
    let b1 = get("batched", 1).tok_per_sec;
    let b8 = get("batched", 8).tok_per_sec;
    let p8 = get("per_session", 8).tok_per_sec;
    println!(
        "batched 8-session aggregate: {:.0} tok/s vs {:.0} at 1 session ({:.1}x) \
         and {:.0} per-session-dispatch ({:.1}x)",
        b8,
        b1,
        b8 / b1.max(1e-9),
        p8,
        b8 / p8.max(1e-9)
    );
    // The acceptance gates: batching must scale aggregate throughput with
    // concurrency while per-session dispatch stays flat.
    assert!(
        b8 > 2.0 * b1,
        "batched aggregate must grow with sessions: 8s {b8:.0} vs 1s {b1:.0} tok/s"
    );
    assert!(
        b8 > 2.0 * p8,
        "batched must beat per-session dispatch at 8 sessions: {b8:.0} vs {p8:.0} tok/s"
    );

    let rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj_from(vec![
                ("mode", Json::s(c.mode)),
                ("sessions", Json::num(c.sessions as f64)),
                ("tok_per_sec", Json::num(c.tok_per_sec)),
                ("p50_ms", Json::num(c.p50_ms)),
                ("p99_ms", Json::num(c.p99_ms)),
                ("batched_steps", Json::num(c.batched_steps as f64)),
                ("mean_active_slots", Json::num(c.mean_active)),
            ])
        })
        .collect();
    let top = vec![
        ("bench", Json::s("decode_batching")),
        ("steps", Json::num(steps as f64)),
        ("delay_us", Json::num(delay_us as f64)),
        ("iters", Json::num(iters as f64)),
        ("rows", Json::Arr(rows)),
    ];
    std::fs::write("BENCH_decode_batching.json", Json::obj_from(top).to_string())?;
    eprintln!("[decode_batching] wrote BENCH_decode_batching.json");
    Ok(())
}
