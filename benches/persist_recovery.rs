//! Persistence bench (DESIGN.md §Persistence): snapshot-write, WAL-append,
//! and recovery (snapshot load + WAL replay) throughput at cache sizes up
//! to 100k entries — the warm-restart path a production cache-serving
//! stack takes on every deploy.
//!
//! `cargo bench --bench persist_recovery [-- --n 100000 --dim 64]`
//!
//! No artifacts needed: entries are synthetic unit vectors. Dim defaults to
//! 64 (not the embedder's 384) to keep the default run I/O-bound on record
//! framing rather than raw byte volume; pass `--dim 384` for paper-scale
//! vectors.

use std::time::Instant;

use tweakllm::bench::bench_args;
use tweakllm::cache::{EvictionPolicy, IndexKind, PersistConfig, SemanticCache};
use tweakllm::util::{normalize, Rng};

fn rand_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    normalize(&mut v);
    v
}

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let n = args.usize("n", 100_000)?;
    let dim = args.usize("dim", 64)?;

    let dir = std::env::temp_dir().join(format!(
        "tweakllm-bench-persist-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = PersistConfig {
        data_dir: dir.to_string_lossy().to_string(),
        wal_fsync: false,
        compact_bytes: u64::MAX, // explicit compaction only: we time it
        fsync_batch_ms: 0,
    };

    println!("\n=== Cache persistence — {n} entries, dim {dim} ===");
    let mut rng = Rng::new(20260728);
    let queries: Vec<String> = (0..n)
        .map(|i| format!("synthetic query number {i} about topic {}", i % 997))
        .collect();
    let vectors: Vec<Vec<f32>> = (0..n).map(|_| rand_unit(&mut rng, dim)).collect();

    // ---- WAL append throughput (journaled inserts) ----
    let (mut cache, _) = SemanticCache::open_persistent(
        dim,
        IndexKind::Flat,
        EvictionPolicy::None,
        usize::MAX,
        true,
        &cfg,
    )?;
    let t = Instant::now();
    for (q, v) in queries.iter().zip(&vectors) {
        cache.insert(q, "cached response body (short)", v.clone());
    }
    let wal_s = t.elapsed().as_secs_f64();
    let wal_bytes = cache.persist_status().unwrap().wal_bytes;
    println!(
        "WAL append      : {:>9.0} inserts/s   ({:.2} s, {:.1} MiB, {:.1} MiB/s)",
        n as f64 / wal_s,
        wal_s,
        wal_bytes as f64 / (1024.0 * 1024.0),
        wal_bytes as f64 / (1024.0 * 1024.0) / wal_s
    );

    // ---- WAL replay throughput (crash recovery path) ----
    drop(cache); // no snapshot: the WAL is the only durable state
    let t = Instant::now();
    let (mut cache, report) = SemanticCache::open_persistent(
        dim,
        IndexKind::Flat,
        EvictionPolicy::None,
        usize::MAX,
        true,
        &cfg,
    )?;
    let replay_s = t.elapsed().as_secs_f64();
    assert_eq!(report.recovered_entries as usize, n);
    assert_eq!(report.replayed_ops as usize, n);
    println!(
        "WAL replay      : {:>9.0} ops/s       ({:.2} s for {} ops)",
        n as f64 / replay_s,
        replay_s,
        report.replayed_ops
    );

    // ---- snapshot write (compaction) ----
    let t = Instant::now();
    let generation = cache.compact_now()?.unwrap();
    let snap_s = t.elapsed().as_secs_f64();
    let snap_path = std::fs::read_dir(&dir)?
        .map(|e| e.unwrap().path())
        .find(|p| p.to_string_lossy().ends_with(".snap"))
        .expect("snapshot file");
    let snap_bytes = std::fs::metadata(&snap_path)?.len();
    println!(
        "snapshot write  : {:>9.0} entries/s   ({:.2} s, {:.1} MiB, {:.1} MiB/s, gen {generation})",
        n as f64 / snap_s,
        snap_s,
        snap_bytes as f64 / (1024.0 * 1024.0),
        snap_bytes as f64 / (1024.0 * 1024.0) / snap_s
    );

    // ---- snapshot load (warm restart after graceful shutdown) ----
    drop(cache);
    let t = Instant::now();
    let (cache, report) = SemanticCache::open_persistent(
        dim,
        IndexKind::Flat,
        EvictionPolicy::None,
        usize::MAX,
        true,
        &cfg,
    )?;
    let load_s = t.elapsed().as_secs_f64();
    assert_eq!(report.recovered_entries as usize, n);
    assert_eq!(report.replayed_ops, 0);
    println!(
        "snapshot load   : {:>9.0} entries/s   ({:.2} s)",
        n as f64 / load_s,
        load_s
    );

    // Sanity: the recovered cache answers (spot-check one self-query).
    let hits = {
        let mut c = cache;
        c.search(&vectors[n / 2], 1)
    };
    assert_eq!(hits[0].id, n / 2);

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
