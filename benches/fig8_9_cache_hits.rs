//! Figures 8 & 9: cache-hit similarity distributions on the LMSYS-like and
//! WildChat-like traces (insert half, query half).
//!
//! Paper shape: 68% of LMSYS queries and 40% of WildChat queries land at
//! cosine ≥ 0.8 against the cache.
//!
//! `cargo bench --bench fig8_9_cache_hits [-- --n 20000]`

use tweakllm::bench::{bench_args, load_embedder, Table};
use tweakllm::datasets::{ChatTrace, TraceProfile};
use tweakllm::eval::hit_rate::run;

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let n = args.usize("n", 20_000)?;
    let seed = args.u64("seed", 20250923)?;

    eprintln!("[fig8-9] loading artifacts + embedding model...");
    let (_rt, embedder) = load_embedder()?;

    for (fig, profile, paper_at_08) in [
        ("Fig 8", TraceProfile::lmsys(), 0.68),
        ("Fig 9", TraceProfile::wildchat(), 0.40),
    ] {
        let trace = ChatTrace::generate(profile, n, seed);
        let (a, b) = trace.halves();
        eprintln!(
            "[fig8-9] {fig} ({}): embedding insert {} / query {}...",
            profile.name,
            a.len(),
            b.len()
        );
        let t0 = std::time::Instant::now();
        let curve = run(a, b, &embedder)?;
        eprintln!("[fig8-9] embedded + searched in {:?}", t0.elapsed());

        let mut table = Table::new(
            &format!("{fig} — {} cache hits by top-1 cosine similarity", profile.name),
            &["bucket", "count", "% of queries"],
        );
        for (lo, hi, count) in curve.histogram(0.5, 10) {
            table.push(vec![
                format!("{lo:.2}-{hi:.2}"),
                count.to_string(),
                format!("{:.1}", 100.0 * count as f64 / curve.queried as f64),
            ]);
        }
        println!("{}", table.render());

        let mut sweep = Table::new(
            &format!("{fig} — hit rate vs threshold"),
            &["threshold", "hit rate %"],
        );
        for t in [0.5f32, 0.6, 0.7, 0.75, 0.8, 0.85, 0.9, 0.95, 0.99] {
            sweep.push(vec![
                format!("{t:.2}"),
                format!("{:.1}", 100.0 * curve.hit_rate_at(t)),
            ]);
        }
        println!("{}", sweep.render());
        let measured = curve.hit_rate_at(0.8);
        println!(
            "hit rate @0.8: measured {:.1}%  (paper: {:.0}%)\n",
            measured * 100.0,
            paper_at_08 * 100.0
        );
    }
    Ok(())
}
