//! Cluster drill: shard-count throughput scaling and availability through
//! a mid-run owner kill.
//!
//! Tier 1 — **scaling**: the same all-miss workload is pushed through a
//! cluster router fronting 1, 2, then 4 in-process shard owners (mock
//! models, millisecond-paced decode, scheduler off so each engine decodes
//! serially). With the per-shard engine as the bottleneck, QPS must rise
//! with the node count; the drill gates 4 nodes at >= 1.5x the single-node
//! QPS and 2 nodes strictly above it.
//!
//! Tier 2 — **availability**: a two-shard cluster with WAL-shipped
//! replicas takes a mixed repeat/fresh workload while shard 0's owner
//! front end is killed about a third of the way in. The contract is the
//! paper appendix's failover rule made measurable: every request gets
//! exactly one non-error reply (availability == 100%), one finished trace
//! per request, and post-kill reads come from the replica under the
//! bounded-staleness rule.
//!
//! Results land in `BENCH_cluster_failover.json` (uploaded from CI).
//!
//! `cargo bench --bench cluster_failover [-- --requests 120 --threads 8]`

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use tweakllm::baselines::MockLlm;
use tweakllm::bench::{bench_args, Table};
use tweakllm::cache::query_key;
use tweakllm::cluster::ring::ShardRing;
use tweakllm::cluster::{ClusterServer, HealthState, ReplicaListener, ShardSpec, Shipper, Topology};
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Engine, EngineHandle, Router};
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::server::{Client, Server, Shutdown};
use tweakllm::util::{Json, Summary};

const VNODES: usize = 64;
const WAIT: Duration = Duration::from_secs(10);

fn wait_for(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        if t0.elapsed() > WAIT {
            panic!("timed out waiting for {what}");
        }
        thread::sleep(Duration::from_millis(20));
    }
}

/// One shard node: engine + TCP front end on an ephemeral port. Decode is
/// millisecond-paced and the interleaving scheduler is off, so a node's
/// miss throughput is engine-bound — the quantity the scaling tier divides
/// across shards.
struct Node {
    engine: Engine,
    handle: EngineHandle,
    addr: String,
    stop: Shutdown,
    join: Option<thread::JoinHandle<()>>,
}

impl Node {
    fn kill_front_end(&mut self) {
        self.stop.signal();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }

    fn shutdown(mut self) {
        self.kill_front_end();
        self.engine.shutdown();
    }
}

fn start_node(role: &str, data_dir: Option<PathBuf>) -> anyhow::Result<(Node, HealthState)> {
    let health = HealthState::new(role);
    let (engine, handle) = Engine::start(move || {
        let mut cfg = Config::paper();
        cfg.index.kind = IndexKindConfig::Flat;
        cfg.exact_match_fast_path = true;
        cfg.scheduler.enabled = false;
        if let Some(dir) = &data_dir {
            cfg.persist.data_dir = dir.to_string_lossy().into_owned();
            cfg.persist.wal_fsync = false;
        }
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        let mut big = MockLlm::new("big");
        big.steps = 16;
        big.step_delay = Duration::from_millis(1);
        let mut small = MockLlm::new("small");
        small.steps = 8;
        small.step_delay = Duration::from_millis(1);
        let mut r = Router::with_models(embedder, Box::new(big), Box::new(small), cfg);
        r.enable_persistence()?;
        Ok(r)
    })?;
    let server = Server::bind("127.0.0.1:0", handle.clone())?.with_health(health.extra());
    let addr = server.local_addr()?.to_string();
    let stop = server.shutdown_handle()?;
    let join = thread::spawn(move || {
        let _ = server.serve();
    });
    Ok((Node { engine, handle, addr, stop, join: Some(join) }, health))
}

fn start_router(topology: Topology) -> anyhow::Result<(String, Shutdown, thread::JoinHandle<()>)> {
    let cluster = ClusterServer::bind("127.0.0.1:0", topology, &Config::paper())?;
    let addr = cluster.local_addr()?.to_string();
    let stop = cluster.shutdown_handle()?;
    let join = thread::spawn(move || {
        let _ = cluster.serve();
    });
    Ok((addr, stop, join))
}

/// A query of six unique words: guaranteed mutual misses under the
/// bag-of-words embedder, so every request costs one paced generation.
fn fresh_query(tag: &str, j: usize) -> String {
    format!("{tag}{j}a {tag}{j}b {tag}{j}c {tag}{j}d {tag}{j}e {tag}{j}f")
}

struct LoadResult {
    answered: usize,
    errors: usize,
    lat_ms: Vec<f64>,
    served_by: BTreeMap<String, usize>,
    wall: Duration,
}

impl LoadResult {
    fn qps(&self) -> f64 {
        self.answered as f64 / self.wall.as_secs_f64()
    }
}

/// Drive `queries` through the router from `threads` client connections
/// (strided split, preserving per-thread order). A reply counts as
/// answered only if it carries no `error` field; `progress` ticks once per
/// completed request so a killer thread can fire mid-run.
fn run_load(
    addr: &str,
    queries: &[String],
    threads: usize,
    progress: Option<Arc<AtomicUsize>>,
) -> LoadResult {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let chunk: Vec<String> = queries.iter().skip(t).step_by(threads).cloned().collect();
            let addr = addr.to_string();
            let progress = progress.clone();
            thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("connect to cluster router");
                let mut out = Vec::with_capacity(chunk.len());
                for q in &chunk {
                    let t1 = Instant::now();
                    let reply = c.query(q);
                    out.push((reply, t1.elapsed().as_secs_f64() * 1000.0));
                    if let Some(p) = &progress {
                        p.fetch_add(1, Ordering::Relaxed);
                    }
                }
                out
            })
        })
        .collect();
    let mut res = LoadResult {
        answered: 0,
        errors: 0,
        lat_ms: Vec::new(),
        served_by: BTreeMap::new(),
        wall: Duration::ZERO,
    };
    for h in handles {
        for (reply, ms) in h.join().expect("load thread panicked") {
            match reply {
                Ok(r) if r.opt("error").is_none() => {
                    res.answered += 1;
                    res.lat_ms.push(ms);
                    let by = r
                        .opt("served_by")
                        .and_then(|s| s.str().ok())
                        .unwrap_or("unknown")
                        .to_string();
                    *res.served_by.entry(by).or_insert(0) += 1;
                }
                _ => res.errors += 1,
            }
        }
    }
    res.wall = t0.elapsed();
    res
}

/// Tier 1: the same all-miss workload against 1 / 2 / 4 shard owners.
fn scaling_tier(requests: usize, threads: usize) -> anyhow::Result<(Vec<Json>, Vec<f64>)> {
    let mut rows = Vec::new();
    let mut qps = Vec::new();
    let mut table = Table::new(
        "QPS scaling across shard owners (all-miss workload)",
        &["nodes", "requests", "wall_s", "qps", "p50_ms", "p99_ms"],
    );
    for &nodes in &[1usize, 2, 4] {
        let mut owners = Vec::new();
        for _ in 0..nodes {
            owners.push(start_node("owner", None)?.0);
        }
        let topology = Topology {
            max_staleness_ms: 10_000,
            epoch: 1,
            vnodes: VNODES,
            shards: owners
                .iter()
                .map(|o| ShardSpec { owner: o.addr.clone(), replica: None })
                .collect(),
        };
        let (raddr, rstop, rjoin) = start_router(topology)?;
        let tag = format!("s{nodes}x");
        let queries: Vec<String> = (0..requests).map(|j| fresh_query(&tag, j)).collect();
        let res = run_load(&raddr, &queries, threads, None);
        assert_eq!(
            res.answered, requests,
            "scaling tier ({nodes} nodes): every request must be answered"
        );
        assert_eq!(res.errors, 0, "scaling tier ({nodes} nodes): no errors allowed");
        let s = Summary::of(&res.lat_ms);
        table.push(vec![
            nodes.to_string(),
            requests.to_string(),
            format!("{:.2}", res.wall.as_secs_f64()),
            format!("{:.1}", res.qps()),
            format!("{:.2}", s.p50),
            format!("{:.2}", s.p99),
        ]);
        rows.push(Json::obj_from(vec![
            ("nodes", Json::num(nodes as f64)),
            ("requests", Json::num(requests as f64)),
            ("wall_s", Json::num(res.wall.as_secs_f64())),
            ("qps", Json::num(res.qps())),
            ("p50_ms", Json::num(s.p50)),
            ("p99_ms", Json::num(s.p99)),
        ]));
        qps.push(res.qps());
        rstop.signal();
        let _ = rjoin.join();
        for o in owners {
            o.shutdown();
        }
    }
    println!("{}", table.render());
    Ok((rows, qps))
}

/// One shard's owner/replica pair: a durable owner whose WAL is shipped to
/// an in-memory replica applying it through the recovery path.
struct Pair {
    owner: Node,
    replica: Node,
    _listener: ReplicaListener,
    _shipper: Shipper,
    dir: PathBuf,
}

fn replicated_pair(tag: &str) -> anyhow::Result<Pair> {
    let dir = std::env::temp_dir()
        .join(format!("tweakllm-bench-cluster-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (owner, owner_health) = start_node("owner", Some(dir.clone()))?;
    let (replica, replica_health) = start_node("replica", None)?;
    let listener = ReplicaListener::start("127.0.0.1:0", replica.handle.clone(), replica_health)?;
    let shipper = Shipper::start(dir.clone(), &listener.local_addr().to_string(), owner_health);
    Ok(Pair { owner, replica, _listener: listener, _shipper: shipper, dir })
}

/// Tier 2: kill shard 0's owner a third of the way into a mixed workload
/// and require 100% availability plus one finished trace per request.
fn availability_tier(requests: usize, threads: usize) -> anyhow::Result<Json> {
    let mut pairs = vec![replicated_pair("a")?, replicated_pair("b")?];
    let topology = Topology {
        max_staleness_ms: 10_000,
        epoch: 1,
        vnodes: VNODES,
        shards: pairs
            .iter()
            .map(|p| ShardSpec {
                owner: p.owner.addr.clone(),
                replica: Some(p.replica.addr.clone()),
            })
            .collect(),
    };
    let ring = ShardRing::new(pairs.len(), VNODES);
    let (raddr, rstop, rjoin) = start_router(topology)?;

    // Prime the cluster, then wait for both replicas to converge so the
    // post-kill repeats have something to hit.
    let prime_n = requests / 4;
    let primes: Vec<String> = (0..prime_n).map(|j| fresh_query("k", j)).collect();
    let warm = run_load(&raddr, &primes, threads.min(4), None);
    assert_eq!(warm.answered, prime_n, "priming: every request must be answered");
    let mut expect = vec![0usize; pairs.len()];
    for q in &primes {
        expect[ring.route(query_key(q))] += 1;
    }
    for (i, p) in pairs.iter().enumerate() {
        let want = expect[i];
        wait_for(&format!("replica {i} to apply {want} shipped entries"), || {
            p.replica.handle.stats().is_ok_and(|s| s.cache_size == want)
        });
    }

    // Mixed measured phase: 2/3 repeats of the primed set, 1/3 fresh
    // misses, with shard 0's owner front end killed once a third of the
    // requests have completed.
    let measured: Vec<String> = (0..requests)
        .map(|j| if j % 3 == 2 { fresh_query("f", j) } else { primes[j % prime_n].clone() })
        .collect();
    let progress = Arc::new(AtomicUsize::new(0));
    let kill_at = requests / 3;
    let kill_stop = pairs[0].owner.stop.clone();
    let watched = Arc::clone(&progress);
    let killer = thread::spawn(move || {
        while watched.load(Ordering::Relaxed) < kill_at {
            thread::sleep(Duration::from_millis(2));
        }
        kill_stop.signal();
    });
    let res = run_load(&raddr, &measured, threads, Some(progress));
    killer.join().expect("killer thread panicked");
    pairs[0].owner.kill_front_end();

    assert_eq!(
        res.answered, requests,
        "availability drill: every request must be answered through the kill"
    );
    assert_eq!(res.errors, 0, "availability drill: no error replies allowed");

    // One reply, one trace — the router's own ledger must agree.
    let mut c = Client::connect(&raddr)?;
    let stats = c.stats()?;
    let total = (prime_n + requests) as f64;
    assert_eq!(stats.get("requests")?.f64()?, total, "router request count");
    assert_eq!(stats.get("traces_finished")?.f64()?, total, "one reply, one trace");
    assert_eq!(stats.get("errors")?.f64()?, 0.0, "router must record zero errors");
    let failovers = stats.get("failovers")?.f64()?;
    let replica_served = stats.get("replica_served")?.f64()?;
    assert!(failovers >= 1.0, "the kill must force at least one failover");
    assert!(replica_served >= 1.0, "post-kill reads must come from the replica");
    drop(c);

    let s = Summary::of(&res.lat_ms);
    let mut table = Table::new(
        "Availability through a mid-run owner kill (2 shards, replicas)",
        &["requests", "answered", "availability", "failovers", "replica_served", "p99_ms"],
    );
    table.push(vec![
        requests.to_string(),
        res.answered.to_string(),
        "100%".to_string(),
        format!("{failovers:.0}"),
        format!("{replica_served:.0}"),
        format!("{:.2}", s.p99),
    ]);
    println!("{}", table.render());

    let served: Vec<(&str, Json)> = res
        .served_by
        .iter()
        .map(|(k, v)| (k.as_str(), Json::num(*v as f64)))
        .collect();
    let row = Json::obj_from(vec![
        ("requests", Json::num(requests as f64)),
        ("answered", Json::num(res.answered as f64)),
        ("availability", Json::num(res.answered as f64 / requests as f64)),
        ("killed_shard", Json::num(0.0)),
        ("kill_after_requests", Json::num(kill_at as f64)),
        ("failovers", Json::num(failovers)),
        ("replica_served", Json::num(replica_served)),
        ("bypass_served", stats.get("bypass_served")?.clone()),
        ("traces_finished", Json::num(total)),
        ("served_by", Json::obj_from(served)),
        ("p50_ms", Json::num(s.p50)),
        ("p99_ms", Json::num(s.p99)),
    ]);

    rstop.signal();
    let _ = rjoin.join();
    for p in pairs {
        let dir = p.dir.clone();
        p.owner.shutdown();
        p.replica.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
    Ok(row)
}

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let requests = args.usize("requests", 120)?;
    let threads = args.usize("threads", 8)?;

    println!("== cluster failover drill: {requests} requests, {threads} client threads ==\n");
    let (scaling, qps) = scaling_tier(requests, threads)?;
    // The scaling gate: with serial per-shard decode, more owners must
    // mean more throughput. Thresholds leave room for shard imbalance.
    assert!(
        qps[1] > qps[0] * 1.1,
        "2 nodes must out-serve 1 node (got {:.1} vs {:.1} qps)",
        qps[1],
        qps[0]
    );
    assert!(
        qps[2] > qps[0] * 1.5,
        "4 nodes must reach >= 1.5x single-node QPS (got {:.1} vs {:.1} qps)",
        qps[2],
        qps[0]
    );

    let availability = availability_tier(requests.max(48), threads)?;

    let top = vec![
        ("bench", Json::s("cluster_failover")),
        ("requests", Json::num(requests as f64)),
        ("threads", Json::num(threads as f64)),
        ("scaling", Json::Arr(scaling)),
        ("availability", availability),
    ];
    std::fs::write("BENCH_cluster_failover.json", Json::obj_from(top).to_string())?;
    println!("wrote BENCH_cluster_failover.json");
    Ok(())
}
