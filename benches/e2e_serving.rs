//! End-to-end serving benchmark, two tiers:
//!
//! * **Mock tier** (always runs, incl. CI): the full engine — dynamic
//!   batcher + router + vector DB — under concurrent client threads, with
//!   `NativeBowEmbedder` + `MockLlm` standing in for the compiled models.
//!   Measures per-pathway latency (from each request's enqueue instant),
//!   throughput, and batching effectiveness.
//! * **Substrate tier** (when `artifacts/` exists): the compiled stack —
//!   embedder + Big/Small decoders — serving a trace through the router,
//!   plus decode tokens/sec for the literal vs device-resident transports.
//!
//! Results land in `BENCH_e2e_serving.json` (uploaded from CI) so the repo
//! has an end-to-end serving trajectory alongside BENCH_vector_index.json.
//!
//! The mock tier additionally runs a **mixed hit/miss concurrent workload**
//! twice — decode scheduler on vs off — over a slow mock Big LLM, reporting
//! per-pathway p50/p99 for both. With the scheduler off every tweak-hit
//! queues behind in-flight Big-LLM generations (head-of-line blocking);
//! with it on, tweak sessions interleave and overtake. The run asserts the
//! tweak-hit p99 drops.
//!
//! A **TTFT tier** measures submit → first non-empty token delta per
//! pathway over the streaming transport (`request_streaming`), paced so the
//! model tier dominates: the run asserts the tweak-hit p50 TTFT beats the
//! miss p50 TTFT (the streaming payoff of serving from cache).
//!
//! `cargo bench --bench e2e_serving [-- --requests 256 --threads 4 --max-new 16]`

use std::time::{Duration, Instant};

use tweakllm::baselines::MockLlm;
use tweakllm::bench::{bench_args, load_runtime, Table};
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Engine, Pathway, Router, StreamEvent};
use tweakllm::datasets::{ChatTrace, TraceProfile};
use tweakllm::runtime::{Generator, NativeBowEmbedder, SamplingParams, TextEmbedder};
use tweakllm::server::pathway_str;
use tweakllm::util::{Json, Rng, Summary};

/// Render + serialize one per-pathway latency table (samples in ms).
fn pathway_report(
    title: &str,
    lat_by_path: &std::collections::HashMap<&'static str, Vec<f64>>,
) -> (Table, Vec<Json>) {
    let mut table = Table::new(title, &["pathway", "n", "mean", "p50", "p99"]);
    let mut rows = Vec::new();
    for path in ["exact_hit", "tweak_hit", "miss"] {
        if let Some(samples) = lat_by_path.get(path) {
            let s = Summary::of(samples);
            table.push(vec![
                path.to_string(),
                s.n.to_string(),
                format!("{:.2}", s.mean),
                format!("{:.2}", s.p50),
                format!("{:.2}", s.p99),
            ]);
            rows.push(Json::obj_from(vec![
                ("pathway", Json::s(path)),
                ("n", Json::num(s.n as f64)),
                ("mean_ms", Json::num(s.mean)),
                ("p50_ms", Json::num(s.p50)),
                ("p99_ms", Json::num(s.p99)),
            ]));
        }
    }
    (table, rows)
}

/// Mixed workload, one engine run: sequential primes, then `n_requests`
/// concurrent requests (~50% tweak-hit paraphrases, ~20% exact repeats,
/// ~30% fresh misses) against a slow mock Big LLM (16 × 1ms decode units —
/// wide enough that run-to-completion head-of-line blocking dominates any
/// CI scheduling noise) and a fast Small LLM. Returns per-pathway latency
/// samples (ms) + qps.
fn run_mixed(
    scheduler_on: bool,
    n_requests: usize,
    threads: usize,
) -> anyhow::Result<(std::collections::HashMap<&'static str, Vec<f64>>, f64)> {
    let mut cfg = Config::paper();
    cfg.index.kind = IndexKindConfig::Flat;
    cfg.exact_match_fast_path = true;
    cfg.scheduler.enabled = scheduler_on;
    let cfg_engine = cfg.clone();
    let (engine, handle) = Engine::start(move || {
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        let mut big = MockLlm::new("big");
        big.steps = 16;
        big.step_delay = Duration::from_millis(1);
        let mut small = MockLlm::new("small");
        small.step_delay = Duration::from_micros(100);
        Ok(Router::with_models(embedder, Box::new(big), Box::new(small), cfg_engine))
    })?;
    // Primes: one cache entry per topic; topic word-sets are disjoint so
    // entries never tweak each other.
    let topics = 8;
    for i in 0..topics {
        handle.request(&format!("mix{i}a mix{i}b mix{i}c mix{i}d mix{i}e mix{i}f"))?;
    }
    // Deterministic mixed trace (same for the on and off runs).
    let mut rng = Rng::new(42);
    let queries: Vec<String> = (0..n_requests)
        .map(|j| {
            let i = rng.range(0, topics);
            match rng.range(0, 10) {
                0..=4 => {
                    // paraphrase: 5/6 words shared with its prime -> tweak
                    format!("mix{i}a mix{i}b mix{i}c mix{i}d mix{i}e vary{j}")
                }
                5..=6 => format!("mix{i}a mix{i}b mix{i}c mix{i}d mix{i}e mix{i}f"),
                _ => format!("fresh{j}a fresh{j}b fresh{j}c fresh{j}d fresh{j}e"),
            }
        })
        .collect();
    let t_all = Instant::now();
    let mut joins = Vec::new();
    for t in 0..threads {
        let h = handle.clone();
        let chunk: Vec<String> = queries.iter().skip(t).step_by(threads).cloned().collect();
        joins.push(std::thread::spawn(move || -> anyhow::Result<Vec<(Pathway, u128)>> {
            let mut out = Vec::with_capacity(chunk.len());
            for q in &chunk {
                let r = h.request(q)?;
                out.push((r.pathway, r.total_micros));
            }
            Ok(out)
        }));
    }
    let mut lat_by_path: std::collections::HashMap<&'static str, Vec<f64>> =
        Default::default();
    for j in joins {
        for (p, us) in j.join().expect("client thread panicked")? {
            lat_by_path.entry(pathway_str(p)).or_default().push(us as f64 / 1000.0);
        }
    }
    let qps = n_requests as f64 / t_all.elapsed().as_secs_f64();
    engine.shutdown();
    Ok((lat_by_path, qps))
}

/// Time-to-first-token per pathway over the streaming transport: submit →
/// first non-empty delta, sequential requests against paced mocks (big
/// 3ms/step, small 500µs/step) so the model tier — not queueing — sets the
/// first-token latency. Returns TTFT samples (ms) keyed by pathway.
fn run_ttft(
    n_per_path: usize,
) -> anyhow::Result<std::collections::HashMap<&'static str, Vec<f64>>> {
    let mut cfg = Config::paper();
    cfg.index.kind = IndexKindConfig::Flat;
    cfg.exact_match_fast_path = true;
    let cfg_engine = cfg.clone();
    let (engine, handle) = Engine::start(move || {
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        let mut big = MockLlm::new("big");
        big.steps = 16;
        big.step_delay = Duration::from_millis(3);
        let mut small = MockLlm::new("small");
        small.steps = 8;
        small.step_delay = Duration::from_micros(500);
        Ok(Router::with_models(embedder, Box::new(big), Box::new(small), cfg_engine))
    })?;
    // Primes: one cache entry per topic (disjoint word sets, as in the
    // mixed workload) so the measured paraphrases tweak their own prime.
    for i in 0..n_per_path {
        handle.request(&format!("ttft{i}a ttft{i}b ttft{i}c ttft{i}d ttft{i}e ttft{i}f"))?;
    }
    let mut queries = Vec::new();
    for i in 0..n_per_path {
        // paraphrase (5/6 shared words) → tweak, repeat → exact, cold → miss
        queries.push(format!("ttft{i}a ttft{i}b ttft{i}c ttft{i}d ttft{i}e vary{i}"));
        queries.push(format!("ttft{i}a ttft{i}b ttft{i}c ttft{i}d ttft{i}e ttft{i}f"));
        queries.push(format!("cold{i}a cold{i}b cold{i}c cold{i}d cold{i}e"));
    }
    let mut ttft_by_path: std::collections::HashMap<&'static str, Vec<f64>> =
        Default::default();
    for q in &queries {
        let t0 = Instant::now();
        let rx = handle.request_streaming(q)?;
        let mut first = None;
        let mut pathway = None;
        for ev in rx.iter() {
            match ev {
                StreamEvent::Delta(d) => {
                    if !d.is_empty() && first.is_none() {
                        first = Some(t0.elapsed());
                    }
                }
                StreamEvent::Done(r) => {
                    pathway = Some(r.pathway);
                    break;
                }
                StreamEvent::Error(m) => anyhow::bail!("ttft stream error: {m}"),
            }
        }
        let (Some(first), Some(p)) = (first, pathway) else {
            anyhow::bail!("stream for {q:?} ended without text or completion");
        };
        ttft_by_path.entry(pathway_str(p)).or_default().push(first.as_secs_f64() * 1e3);
    }
    engine.shutdown();
    Ok(ttft_by_path)
}

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let n_requests = args.usize("requests", 256)?;
    let threads = args.usize("threads", 4)?.max(1);
    let max_new = args.usize("max-new", 16)?;
    let threshold = args.f64("threshold", 0.7)? as f32;

    let trace = ChatTrace::generate(TraceProfile::lmsys(), n_requests, 20250923);
    let texts: Vec<String> = trace.queries.iter().map(|q| q.text.clone()).collect();

    // ---- mock tier: engine + batcher under concurrent clients ----
    eprintln!("[e2e] mock tier: {n_requests} requests over {threads} client threads...");
    let mut cfg = Config::paper();
    cfg.index.kind = IndexKindConfig::Flat;
    cfg.similarity_threshold = threshold;
    cfg.exact_match_fast_path = true;
    let cfg_engine = cfg.clone();
    let (engine, handle) = Engine::start(move || {
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        Ok(Router::with_models(
            embedder,
            Box::new(MockLlm::new("big")),
            Box::new(MockLlm::new("small")),
            cfg_engine,
        ))
    })?;
    let t_all = Instant::now();
    let mut joins = Vec::new();
    for t in 0..threads {
        let h = handle.clone();
        let chunk: Vec<String> = texts.iter().skip(t).step_by(threads).cloned().collect();
        joins.push(std::thread::spawn(move || -> anyhow::Result<Vec<(Pathway, u128)>> {
            let mut out = Vec::with_capacity(chunk.len());
            for q in &chunk {
                let r = h.request(q)?;
                out.push((r.pathway, r.total_micros));
            }
            Ok(out)
        }));
    }
    let mut lat_by_path: std::collections::HashMap<&'static str, Vec<f64>> =
        Default::default();
    for j in joins {
        for (p, us) in j.join().expect("client thread panicked")? {
            lat_by_path.entry(pathway_str(p)).or_default().push(us as f64 / 1000.0);
        }
    }
    let wall = t_all.elapsed();
    let stats = handle.stats()?;
    engine.shutdown();
    let qps = n_requests as f64 / wall.as_secs_f64();

    let (table, mock_rows) = pathway_report(
        "E2E serving, mock tier (engine + batcher) — per-pathway latency (ms)",
        &lat_by_path,
    );
    println!("{}", table.render());
    println!(
        "mock tier: {qps:.1} req/s  |  mean batch size: {:.2}",
        stats.mean_batch_size
    );

    // ---- mixed hit/miss workload: decode scheduler on vs off ----
    eprintln!("[e2e] mixed workload: {n_requests} requests, scheduler on vs off...");
    let (mixed_on, qps_on) = run_mixed(true, n_requests, threads)?;
    let (mixed_off, qps_off) = run_mixed(false, n_requests, threads)?;
    let (table_on, rows_on) = pathway_report(
        "Mixed workload, scheduler ON (interleaved decode) — latency (ms)",
        &mixed_on,
    );
    let (table_off, rows_off) = pathway_report(
        "Mixed workload, scheduler OFF (run-to-completion) — latency (ms)",
        &mixed_off,
    );
    println!("{}", table_on.render());
    println!("{}", table_off.render());
    println!("mixed: {qps_on:.1} req/s (scheduler on)  vs  {qps_off:.1} req/s (off)");
    let tweak_p99_on = mixed_on.get("tweak_hit").map(|v| Summary::of(v).p99);
    let tweak_p99_off = mixed_off.get("tweak_hit").map(|v| Summary::of(v).p99);
    if let (Some(on), Some(off)) = (tweak_p99_on, tweak_p99_off) {
        println!(
            "tweak-hit p99: {on:.2}ms (scheduler on) vs {off:.2}ms (off)  ->  {:.1}x",
            off / on.max(1e-9)
        );
        // The acceptance gate: interleaving removes head-of-line blocking,
        // so hit latency must drop under mixed concurrent load.
        assert!(on < off, "scheduler must cut tweak-hit p99: on {on:.2}ms vs off {off:.2}ms");
    }
    let on_obj =
        Json::obj_from(vec![("qps", Json::num(qps_on)), ("pathways", Json::Arr(rows_on))]);
    let off_obj =
        Json::obj_from(vec![("qps", Json::num(qps_off)), ("pathways", Json::Arr(rows_off))]);
    let mixed_json = Json::obj_from(vec![("scheduler_on", on_obj), ("scheduler_off", off_obj)]);

    // ---- TTFT per pathway over the streaming transport ----
    let ttft_n = args.usize("ttft", 32)?.max(1);
    eprintln!("[e2e] ttft: {ttft_n} streamed requests per pathway...");
    let ttft_by_path = run_ttft(ttft_n)?;
    let mut ttft_table = Table::new(
        "Streaming TTFT (submit → first token) — per-pathway (ms)",
        &["pathway", "n", "ttft_p50", "ttft_p99"],
    );
    let mut ttft_rows = Vec::new();
    for path in ["exact_hit", "tweak_hit", "miss"] {
        if let Some(samples) = ttft_by_path.get(path) {
            let s = Summary::of(samples);
            ttft_table.push(vec![
                path.to_string(),
                s.n.to_string(),
                format!("{:.2}", s.p50),
                format!("{:.2}", s.p99),
            ]);
            ttft_rows.push(Json::obj_from(vec![
                ("pathway", Json::s(path)),
                ("n", Json::num(s.n as f64)),
                ("ttft_p50_ms", Json::num(s.p50)),
                ("ttft_p99_ms", Json::num(s.p99)),
            ]));
        }
    }
    println!("{}", ttft_table.render());
    let tweak_ttft = ttft_by_path.get("tweak_hit").map(|v| Summary::of(v).p50);
    let miss_ttft = ttft_by_path.get("miss").map(|v| Summary::of(v).p50);
    if let (Some(t), Some(m)) = (tweak_ttft, miss_ttft) {
        println!(
            "ttft p50: tweak {t:.2}ms vs miss {m:.2}ms  ->  {:.1}x",
            m / t.max(1e-9)
        );
        // The streaming payoff of serving from cache: first token from the
        // Small-LLM tweak must beat the Big-LLM miss.
        assert!(
            t < m,
            "hit pathway must reach first token sooner: tweak {t:.2}ms vs miss {m:.2}ms"
        );
    }

    // ---- substrate tier: compiled artifacts (skipped when absent) ----
    let mut substrate_json: Option<Json> = None;
    match load_runtime() {
        Ok(rt) => {
            eprintln!("[e2e] substrate tier: serving {n_requests} requests...");
            let mut cfg = Config::paper();
            cfg.similarity_threshold = threshold;
            cfg.big_llm.max_new_tokens = max_new;
            cfg.small_llm.max_new_tokens = max_new;
            cfg.exact_match_fast_path = true;
            let mut router = Router::from_runtime(&rt, cfg)?;
            let mut lat: std::collections::HashMap<&'static str, Vec<f64>> =
                Default::default();
            let t_sub = Instant::now();
            for q in &texts {
                let r = router.handle(q)?;
                lat.entry(pathway_str(r.pathway))
                    .or_default()
                    .push(r.total_micros as f64 / 1000.0);
            }
            let sub_wall = t_sub.elapsed();
            let (table, sub_rows) = pathway_report(
                "E2E serving, substrate tier (compiled models) — per-pathway latency (ms)",
                &lat,
            );
            println!("{}", table.render());
            let cost = router.ledger.dollars(&router.config.cost);
            let base = router.ledger.baseline_dollars(&router.config.cost);
            println!(
                "substrate tier: {:.2} req/s  |  hit rate: {:.1}%  |  cache: {} entries",
                n_requests as f64 / sub_wall.as_secs_f64(),
                router.hit_rate() * 100.0,
                router.cache().len(),
            );
            println!(
                "cost: ${cost:.6} vs all-big ${base:.6}  ->  {:.1}% of baseline",
                100.0 * cost / base.max(1e-12)
            );
            println!("\nstage latency:\n{}", router.latency.table());

            // paper's qualitative claims, enforced on the real stack
            let tweak_mean = lat.get("tweak_hit").map(|v| Summary::of(v).mean);
            let miss_mean = lat.get("miss").map(|v| Summary::of(v).mean);
            if let (Some(t), Some(m)) = (tweak_mean, miss_mean) {
                assert!(
                    t < m,
                    "hit pathway must be faster than miss: tweak {t:.1}ms vs miss {m:.1}ms"
                );
            }
            if base > 0.0 {
                assert!(cost < base, "caching must reduce cost");
            }

            // decode transports: literal vs device-resident tokens/sec
            let mut decode_rows = Vec::new();
            for model in ["small", "big"] {
                let g = Generator::new(&rt, model)?;
                for (label, resident) in [("literal", false), ("resident", true)] {
                    if resident && !g.resident_available() {
                        eprintln!("[e2e] {model}: no resident artifacts, skipping");
                        continue;
                    }
                    let params =
                        SamplingParams { max_new_tokens: max_new, ..Default::default() };
                    let mut rng = Rng::new(1);
                    // warmup, then a timed run on the same token stream
                    g.generate_on(&["warm the caches up"], &params, &mut rng, resident)?;
                    let mut rng = Rng::new(1);
                    let gen = g.generate_on(
                        &["profile this prompt please"],
                        &params,
                        &mut rng,
                        resident,
                    )?;
                    let decode_s = gen.stats.decode_micros as f64 / 1e6;
                    let tok_per_s = if decode_s > 0.0 {
                        gen.stats.generated_tokens as f64 / decode_s
                    } else {
                        0.0
                    };
                    println!("decode {model} [{label}]: {tok_per_s:.1} tok/s");
                    decode_rows.push(Json::obj_from(vec![
                        ("model", Json::s(model)),
                        ("path", Json::s(label)),
                        ("tok_per_sec", Json::num(tok_per_s)),
                        ("decode_micros", Json::num(gen.stats.decode_micros as f64)),
                        ("tokens", Json::num(gen.stats.generated_tokens as f64)),
                    ]));
                }
            }
            substrate_json = Some(Json::obj_from(vec![
                ("qps", Json::num(n_requests as f64 / sub_wall.as_secs_f64())),
                ("pathways", Json::Arr(sub_rows)),
                ("decode", Json::Arr(decode_rows)),
            ]));
        }
        Err(e) => eprintln!("[e2e] substrate tier skipped (no artifacts): {e}"),
    }

    // ---- BENCH_e2e_serving.json ----
    let mut top = vec![
        ("bench", Json::s("e2e_serving")),
        ("requests", Json::num(n_requests as f64)),
        ("threads", Json::num(threads as f64)),
        ("max_new", Json::num(max_new as f64)),
        ("qps_mock", Json::num(qps)),
        ("mean_batch_size", Json::num(stats.mean_batch_size)),
        ("pathways_mock", Json::Arr(mock_rows)),
        ("mixed", mixed_json),
        ("ttft", Json::Arr(ttft_rows)),
    ];
    if let Some(s) = substrate_json {
        top.push(("substrate", s));
    }
    std::fs::write("BENCH_e2e_serving.json", Json::obj_from(top).to_string())?;
    eprintln!("[e2e] wrote BENCH_e2e_serving.json");
    Ok(())
}
