//! End-to-end serving benchmark: the full three-layer stack under load —
//! compiled embedder + vector DB + threshold routing + compiled Big/Small
//! decoders — measuring latency and throughput per pathway and the live
//! cost ratio. This is the paper's system running for real, not an
//! analytic model.
//!
//! `cargo bench --bench e2e_serving [-- --requests 48 --max-new 16]`

use tweakllm::bench::{bench_args, load_runtime, Table};
use tweakllm::config::Config;
use tweakllm::coordinator::{Pathway, Router};
use tweakllm::datasets::{ChatTrace, TraceProfile};
use tweakllm::util::Summary;

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let n_requests = args.usize("requests", 48)?;
    let max_new = args.usize("max-new", 16)?;
    let threshold = args.f64("threshold", 0.7)? as f32;

    eprintln!("[e2e] loading artifacts (all models)...");
    let rt = load_runtime()?;
    let mut cfg = Config::paper();
    cfg.similarity_threshold = threshold;
    cfg.big_llm.max_new_tokens = max_new;
    cfg.small_llm.max_new_tokens = max_new;
    cfg.exact_match_fast_path = true;
    let mut router = Router::from_runtime(&rt, cfg)?;

    let trace = ChatTrace::generate(TraceProfile::lmsys(), n_requests, 20250923);
    eprintln!("[e2e] serving {} requests (max_new={})...", n_requests, max_new);

    let mut lat_by_path: std::collections::HashMap<&'static str, Vec<f64>> =
        Default::default();
    let t_all = std::time::Instant::now();
    for q in &trace.queries {
        let r = router.handle(&q.text)?;
        let path = match r.pathway {
            Pathway::ExactHit => "exact_hit",
            Pathway::TweakHit => "tweak_hit",
            Pathway::Miss => "miss",
        };
        lat_by_path.entry(path).or_default().push(r.total_micros as f64 / 1000.0);
    }
    let wall = t_all.elapsed();

    let mut table = Table::new(
        "E2E serving — per-pathway latency (ms)",
        &["pathway", "n", "mean", "p50", "p99"],
    );
    for path in ["exact_hit", "tweak_hit", "miss"] {
        if let Some(samples) = lat_by_path.get(path) {
            let s = Summary::of(samples);
            table.push(vec![
                path.to_string(),
                s.n.to_string(),
                format!("{:.1}", s.mean),
                format!("{:.1}", s.p50),
                format!("{:.1}", s.p99),
            ]);
        }
    }
    println!("{}", table.render());

    let cost = router.ledger.dollars(&router.config.cost);
    let base = router.ledger.baseline_dollars(&router.config.cost);
    println!(
        "throughput: {:.2} req/s  |  hit rate: {:.1}%  |  cache: {} entries",
        n_requests as f64 / wall.as_secs_f64(),
        router.hit_rate() * 100.0,
        router.cache().len(),
    );
    println!(
        "cost: ${:.6} vs all-big ${:.6}  ->  {:.1}% of baseline",
        cost,
        base,
        100.0 * cost / base.max(1e-12)
    );
    println!("\nstage latency:\n{}", router.latency.table());

    // paper's qualitative claims, enforced
    let tweak_mean = lat_by_path.get("tweak_hit").map(|v| Summary::of(v).mean);
    let miss_mean = lat_by_path.get("miss").map(|v| Summary::of(v).mean);
    if let (Some(t), Some(m)) = (tweak_mean, miss_mean) {
        assert!(
            t < m,
            "hit pathway must be faster than miss pathway: tweak {t:.1}ms vs miss {m:.1}ms"
        );
    }
    if base > 0.0 {
        assert!(cost < base, "caching must reduce cost");
    }
    Ok(())
}
