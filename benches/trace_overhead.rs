//! Span-tracing overhead benchmark (mock tier, always runs, incl. CI).
//!
//! Runs the same mixed hit/tweak/miss concurrent workload as `e2e_serving`'s
//! mixed tier — full engine, dynamic batcher, decode scheduler, paced
//! `MockLlm`s — three times: tracing off, tracing on, tracing on with JSONL
//! export. Reports per-pathway p50/p99 for each mode plus the on-vs-off
//! deltas, and asserts the tracing-on pooled p50 overhead stays within the
//! budget (≤ 2%, plus a small absolute floor for CI scheduling noise —
//! the pacing sleeps dominate, so a real regression shows up clearly).
//!
//! Results land in `BENCH_trace_overhead.json` (uploaded from CI).
//!
//! `cargo bench --bench trace_overhead [-- --requests 192 --threads 4]`

use std::time::{Duration, Instant};

use tweakllm::baselines::MockLlm;
use tweakllm::bench::{bench_args, Table};
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Engine, Pathway, Router};
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::server::pathway_str;
use tweakllm::util::{Json, Rng, Summary};

/// Tracing-on p50 overhead budget vs tracing-off, as a fraction.
const P50_BUDGET: f64 = 0.02;
/// Absolute slack (ms) absorbing CI scheduling noise on top of the budget.
const NOISE_FLOOR_MS: f64 = 0.25;

struct ModeResult {
    lat_by_path: std::collections::HashMap<&'static str, Vec<f64>>,
    pooled: Vec<f64>,
    qps: f64,
}

/// One engine run of the mixed workload (identical trace across modes).
fn run_mode(
    trace_on: bool,
    export_dir: Option<&str>,
    n_requests: usize,
    threads: usize,
) -> anyhow::Result<ModeResult> {
    let mut cfg = Config::paper();
    cfg.index.kind = IndexKindConfig::Flat;
    cfg.exact_match_fast_path = true;
    cfg.scheduler.enabled = true;
    cfg.trace.enabled = trace_on;
    if let Some(dir) = export_dir {
        cfg.trace.export_dir = dir.to_string();
    }
    let cfg_engine = cfg.clone();
    let (engine, handle) = Engine::start(move || {
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        let mut big = MockLlm::new("big");
        big.steps = 16;
        big.step_delay = Duration::from_millis(1);
        let mut small = MockLlm::new("small");
        small.step_delay = Duration::from_micros(100);
        Ok(Router::with_models(embedder, Box::new(big), Box::new(small), cfg_engine))
    })?;
    let topics = 8;
    for i in 0..topics {
        handle.request(&format!("mix{i}a mix{i}b mix{i}c mix{i}d mix{i}e mix{i}f"))?;
    }
    // Same deterministic mix as e2e_serving: ~50% paraphrase (tweak), ~20%
    // exact repeat, ~30% fresh miss.
    let mut rng = Rng::new(42);
    let queries: Vec<String> = (0..n_requests)
        .map(|j| {
            let i = rng.range(0, topics);
            match rng.range(0, 10) {
                0..=4 => format!("mix{i}a mix{i}b mix{i}c mix{i}d mix{i}e vary{j}"),
                5..=6 => format!("mix{i}a mix{i}b mix{i}c mix{i}d mix{i}e mix{i}f"),
                _ => format!("fresh{j}a fresh{j}b fresh{j}c fresh{j}d fresh{j}e"),
            }
        })
        .collect();
    let t_all = Instant::now();
    let mut joins = Vec::new();
    for t in 0..threads {
        let h = handle.clone();
        let chunk: Vec<String> = queries.iter().skip(t).step_by(threads).cloned().collect();
        joins.push(std::thread::spawn(move || -> anyhow::Result<Vec<(Pathway, u128)>> {
            let mut out = Vec::with_capacity(chunk.len());
            for q in &chunk {
                let r = h.request(q)?;
                out.push((r.pathway, r.total_micros));
            }
            Ok(out)
        }));
    }
    let mut lat_by_path: std::collections::HashMap<&'static str, Vec<f64>> = Default::default();
    let mut pooled = Vec::with_capacity(n_requests);
    for j in joins {
        for (p, us) in j.join().expect("client thread panicked")? {
            let ms = us as f64 / 1000.0;
            lat_by_path.entry(pathway_str(p)).or_default().push(ms);
            pooled.push(ms);
        }
    }
    let qps = n_requests as f64 / t_all.elapsed().as_secs_f64();
    if trace_on {
        // sanity: every request (and the primes) must have finished a trace
        let stats = handle.stats()?;
        assert!(
            stats.traces_finished >= (n_requests + topics) as u64,
            "tracing on but only {} traces finished for {} requests",
            stats.traces_finished,
            n_requests + topics
        );
    }
    engine.shutdown();
    Ok(ModeResult { lat_by_path, pooled, qps })
}

fn pathway_rows(m: &ModeResult) -> Vec<Json> {
    let mut rows = Vec::new();
    for path in ["exact_hit", "tweak_hit", "miss"] {
        if let Some(samples) = m.lat_by_path.get(path) {
            let s = Summary::of(samples);
            rows.push(Json::obj_from(vec![
                ("pathway", Json::s(path)),
                ("n", Json::num(s.n as f64)),
                ("mean_ms", Json::num(s.mean)),
                ("p50_ms", Json::num(s.p50)),
                ("p99_ms", Json::num(s.p99)),
            ]));
        }
    }
    rows
}

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let n_requests = args.usize("requests", 192)?;
    let threads = args.usize("threads", 4)?.max(1);

    let export_dir =
        std::env::temp_dir().join(format!("tweakllm_trace_overhead_{}", std::process::id()));
    let export_str = export_dir.to_string_lossy().into_owned();

    eprintln!("[trace_overhead] {n_requests} requests x {threads} threads, tracing off...");
    let off = run_mode(false, None, n_requests, threads)?;
    eprintln!("[trace_overhead] tracing on...");
    let on = run_mode(true, None, n_requests, threads)?;
    eprintln!("[trace_overhead] tracing on + JSONL export...");
    let export = run_mode(true, Some(&export_str), n_requests, threads)?;
    let exported_lines = std::fs::read_to_string(export_dir.join("traces.jsonl"))
        .map(|t| t.lines().count())
        .unwrap_or(0);
    let _ = std::fs::remove_dir_all(&export_dir);
    assert!(
        exported_lines >= n_requests,
        "export mode wrote {exported_lines} JSONL lines for {n_requests} requests"
    );

    let mut table = Table::new(
        "Span-tracing overhead — mixed workload latency (ms)",
        &["mode", "pathway", "n", "p50", "p99"],
    );
    for (mode, m) in [("off", &off), ("on", &on), ("on+export", &export)] {
        for path in ["exact_hit", "tweak_hit", "miss"] {
            if let Some(samples) = m.lat_by_path.get(path) {
                let s = Summary::of(samples);
                table.push(vec![
                    mode.to_string(),
                    path.to_string(),
                    s.n.to_string(),
                    format!("{:.3}", s.p50),
                    format!("{:.3}", s.p99),
                ]);
            }
        }
    }
    println!("{}", table.render());

    let off_s = Summary::of(&off.pooled);
    let on_s = Summary::of(&on.pooled);
    let export_s = Summary::of(&export.pooled);
    let pct = |a: f64, b: f64| if b > 0.0 { 100.0 * (a - b) / b } else { 0.0 };
    println!(
        "pooled p50: off {:.3}ms  on {:.3}ms ({:+.2}%)  on+export {:.3}ms ({:+.2}%)",
        off_s.p50,
        on_s.p50,
        pct(on_s.p50, off_s.p50),
        export_s.p50,
        pct(export_s.p50, off_s.p50),
    );
    println!(
        "pooled p99: off {:.3}ms  on {:.3}ms ({:+.2}%)",
        off_s.p99,
        on_s.p99,
        pct(on_s.p99, off_s.p99),
    );
    println!("qps: off {:.1}  on {:.1}  on+export {:.1}", off.qps, on.qps, export.qps);

    // The overhead budget gate (DESIGN.md "Observability").
    let ceiling = off_s.p50 * (1.0 + P50_BUDGET) + NOISE_FLOOR_MS;
    assert!(
        on_s.p50 <= ceiling,
        "tracing-on pooled p50 {:.3}ms exceeds budget {:.3}ms (off p50 {:.3}ms)",
        on_s.p50,
        ceiling,
        off_s.p50
    );

    let mode_json = |m: &ModeResult, s: &Summary| {
        Json::obj_from(vec![
            ("qps", Json::num(m.qps)),
            ("pooled_p50_ms", Json::num(s.p50)),
            ("pooled_p99_ms", Json::num(s.p99)),
            ("pathways", Json::Arr(pathway_rows(m))),
        ])
    };
    let report = Json::obj_from(vec![
        ("bench", Json::s("trace_overhead")),
        ("requests", Json::num(n_requests as f64)),
        ("threads", Json::num(threads as f64)),
        ("off", mode_json(&off, &off_s)),
        ("on", mode_json(&on, &on_s)),
        ("on_export", mode_json(&export, &export_s)),
        ("p50_overhead_pct", Json::num(pct(on_s.p50, off_s.p50))),
        ("p99_overhead_pct", Json::num(pct(on_s.p99, off_s.p99))),
        ("export_overhead_pct", Json::num(pct(export_s.p50, off_s.p50))),
        ("p50_budget_pct", Json::num(100.0 * P50_BUDGET)),
        ("exported_lines", Json::num(exported_lines as f64)),
    ]);
    std::fs::write("BENCH_trace_overhead.json", report.to_string())?;
    eprintln!("[trace_overhead] wrote BENCH_trace_overhead.json");
    Ok(())
}
