//! Vector-index ablation bench (DESIGN.md ablations): FLAT vs IVF_FLAT
//! latency + recall at store sizes, nprobe sweep, eviction policy
//! throughput, and native-Rust scan vs the compiled `cosine_scores_b4096`
//! Pallas artifact (the L1/L3 crossover).
//!
//! `cargo bench --bench vector_index [-- --n 50000]`

use tweakllm::bench::{bench_args, load_runtime, measure, row, Table};
use tweakllm::cache::{EvictionPolicy, FlatIndex, IvfFlatIndex, SemanticCache, VectorIndex};
use tweakllm::cache::store::IndexKind;
use tweakllm::runtime::HostTensor;
use tweakllm::util::{normalize, Rng};

fn rand_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    normalize(&mut v);
    v
}

fn clustered(rng: &mut Rng, n: usize, dim: usize, clusters: usize) -> Vec<Vec<f32>> {
    let centers: Vec<Vec<f32>> = (0..clusters).map(|_| rand_unit(rng, dim)).collect();
    (0..n)
        .map(|i| {
            let mut v: Vec<f32> = centers[i % clusters]
                .iter()
                .map(|x| x + 0.3 * rng.normal() as f32)
                .collect();
            normalize(&mut v);
            v
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let n = args.usize("n", 50_000)?;
    let dim = 384usize;
    let mut rng = Rng::new(99);
    let data = clustered(&mut rng, n, dim, 64);
    let queries: Vec<Vec<f32>> = (0..64).map(|i| data[i * (n / 64)].clone()).collect();

    // ---- FLAT vs IVF_FLAT search latency + recall ----
    let mut flat = FlatIndex::new(dim);
    for v in &data {
        flat.insert(v);
    }
    let mut table = Table::new(
        "Vector index — search latency & recall@1 vs FLAT (N vectors)",
        &["index", "N", "nprobe", "mean us/query", "recall@1 %"],
    );
    let flat_lat = {
        let mut qi = 0;
        measure(3, 30, || {
            let _ = flat.search(&queries[qi % queries.len()], 1);
            qi += 1;
        })
    };
    table.push(vec![
        "FLAT".into(),
        n.to_string(),
        "-".into(),
        format!("{:.1}", flat_lat.mean),
        "100.0".into(),
    ]);

    for nprobe in [1usize, 4, 8, 16] {
        let mut ivf = IvfFlatIndex::new(dim, 64, nprobe);
        for v in &data {
            ivf.insert(v);
        }
        let mut hits = 0;
        for q in &queries {
            let a = ivf.search(q, 1);
            let b = flat.search(q, 1);
            if a.first().map(|h| h.id) == b.first().map(|h| h.id) {
                hits += 1;
            }
        }
        let lat = {
            let mut qi = 0;
            measure(3, 30, || {
                let _ = ivf.search(&queries[qi % queries.len()], 1);
                qi += 1;
            })
        };
        table.push(vec![
            "IVF_FLAT".into(),
            n.to_string(),
            nprobe.to_string(),
            format!("{:.1}", lat.mean),
            format!("{:.1}", 100.0 * hits as f64 / queries.len() as f64),
        ]);
    }
    println!("{}", table.render());

    // ---- eviction policy throughput at capacity ----
    let mut evict_table = Table::new(
        "Eviction ablation — bounded cache (capacity 4096), insert+search mix",
        &["policy", "us/op", "evictions"],
    );
    for policy in [
        EvictionPolicy::None,
        EvictionPolicy::Fifo,
        EvictionPolicy::Lru,
        EvictionPolicy::Lfu,
    ] {
        let mut cache = SemanticCache::new(64, IndexKind::Flat)
            .with_eviction(policy, 4096);
        let vecs: Vec<Vec<f32>> = (0..6000).map(|_| rand_unit(&mut rng, 64)).collect();
        let t = std::time::Instant::now();
        for (i, v) in vecs.iter().enumerate() {
            cache.insert(&format!("q{i}"), "r", v.clone());
            if i % 4 == 0 {
                let _ = cache.search(v, 1);
            }
        }
        let us = t.elapsed().as_micros() as f64 / vecs.len() as f64;
        evict_table.push(vec![
            format!("{policy:?}"),
            format!("{us:.1}"),
            cache.stats().evictions.to_string(),
        ]);
    }
    println!("{}", evict_table.render());

    // ---- native scan vs compiled Pallas cosine artifact ----
    eprintln!("[vector_index] loading cosine_scores artifact...");
    match load_runtime() {
        Ok(rt) => {
            let exe = rt.executable("cosine_scores_b4096")?;
            let block = 4096usize;
            let db: Vec<f32> = data.iter().take(block).flatten().copied().collect();
            let q = &queries[0];
            let db_t = HostTensor::f32(db.clone(), &[block, dim]);
            let q_t = HostTensor::f32(q.clone(), &[dim]);
            let compiled = measure(2, 20, || {
                let _ = exe.run(&[db_t.clone(), q_t.clone()]).unwrap();
            });
            let mut flat4k = FlatIndex::new(dim);
            for v in data.iter().take(block) {
                flat4k.insert(v);
            }
            let native = measure(2, 20, || {
                let _ = flat4k.search(q, 1);
            });
            println!("{}", row("native scan (4096x384)", &native));
            println!("{}", row("compiled pallas cosine (4096x384)", &compiled));
        }
        Err(e) => eprintln!("[vector_index] skipping compiled comparison: {e}"),
    }
    Ok(())
}
