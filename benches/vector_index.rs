//! Vector-index ablation bench (DESIGN.md ablations): the sharded/SQ8 scan
//! matrix (f32 vs SQ8 × 1/4/8 shards at 10k/100k entries — written to
//! `BENCH_vector_index.json` for the perf trajectory), FLAT vs IVF_FLAT
//! latency + recall, nprobe sweep, eviction policy throughput, and
//! native-Rust scan vs the compiled `cosine_scores_b4096` Pallas artifact
//! (the L1/L3 crossover).
//!
//! `cargo bench --bench vector_index [-- --n 50000 --quick]`

use std::sync::Arc;

use tweakllm::bench::{bench_args, load_runtime, measure, row, Table};
use tweakllm::cache::{
    EvictionPolicy, FlatIndex, IndexOpts, IvfFlatIndex, Quantization, SemanticCache, VectorIndex,
};
use tweakllm::cache::store::IndexKind;
use tweakllm::runtime::HostTensor;
use tweakllm::util::{normalize, Json, Rng, ThreadPool};

fn rand_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    normalize(&mut v);
    v
}

fn clustered(rng: &mut Rng, n: usize, dim: usize, clusters: usize) -> Vec<Vec<f32>> {
    let centers: Vec<Vec<f32>> = (0..clusters).map(|_| rand_unit(rng, dim)).collect();
    (0..n)
        .map(|i| {
            let mut v: Vec<f32> = centers[i % clusters]
                .iter()
                .map(|x| x + 0.3 * rng.normal() as f32)
                .collect();
            normalize(&mut v);
            v
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let n = args.usize("n", 50_000)?;
    let quick = args.has("quick");
    let dim = 384usize;
    let mut rng = Rng::new(99);

    // ---- sharded / quantized scan matrix → BENCH_vector_index.json ----
    // rows/sec + p50/p99 per (entries, storage mode, shard count); recall@1
    // of SQ8 is measured against the exact f32 scan on the same data.
    let matrix_sizes: &[usize] = if quick { &[10_000] } else { &[10_000, 100_000] };
    let max_n = *matrix_sizes.iter().max().unwrap();
    eprintln!("[vector_index] generating {max_n} x {dim} clustered vectors...");
    let all_data = clustered(&mut rng, max_n, dim, 64);
    let shard_counts = [1usize, 4, 8];
    // Smaller-than-default segments so even the 10k tier has enough sealed
    // segments (9) for the 8-shard rows to mean what they claim.
    let matrix_segment_rows = 1024usize;
    let mut matrix = Table::new(
        "Sharded scan matrix — per-query latency & throughput (64 queries)",
        &["entries", "storage", "shards", "mean us", "p50 us", "p99 us", "Mrows/s", "recall@1 %"],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    for &size in matrix_sizes {
        let data = &all_data[..size];
        let queries: Vec<Vec<f32>> =
            (0..64).map(|i| data[(i * (size / 64)) % size].clone()).collect();
        // Exact reference for recall: the f32 index at 1 shard.
        let mut exact_top1: Vec<usize> = Vec::new();
        for quant in [Quantization::None, Quantization::Sq8] {
            let opts = IndexOpts {
                quantization: quant,
                segment_rows: matrix_segment_rows,
                ..IndexOpts::default()
            };
            let mut idx = FlatIndex::with_opts(dim, opts);
            for v in data {
                idx.insert(v);
            }
            if quant == Quantization::None {
                exact_top1 = queries.iter().map(|q| idx.search(q, 1)[0].id).collect();
            }
            let recall = {
                let got: Vec<usize> = queries.iter().map(|q| idx.search(q, 1)[0].id).collect();
                let agree = got.iter().zip(&exact_top1).filter(|(a, b)| a == b).count();
                agree as f64 / queries.len() as f64
            };
            for &shards in &shard_counts {
                if shards > 1 {
                    idx.set_pool(Arc::new(ThreadPool::new(shards)), shards);
                } else {
                    // shards == 1: scan on the calling thread
                    idx.set_pool(Arc::new(ThreadPool::new(1)), 1);
                }
                let lat = {
                    let mut qi = 0;
                    let iters = if size >= 100_000 { 20 } else { 40 };
                    measure(3, iters, || {
                        let _ = idx.search(&queries[qi % queries.len()], 1);
                        qi += 1;
                    })
                };
                let rows_per_sec = size as f64 / (lat.mean * 1e-6);
                let storage = match quant {
                    Quantization::None => "f32",
                    Quantization::Sq8 => "sq8",
                };
                matrix.push(vec![
                    size.to_string(),
                    storage.into(),
                    shards.to_string(),
                    format!("{:.1}", lat.mean),
                    format!("{:.1}", lat.p50),
                    format!("{:.1}", lat.p99),
                    format!("{:.2}", rows_per_sec / 1e6),
                    format!("{:.1}", 100.0 * recall),
                ]);
                json_rows.push(Json::obj_from(vec![
                    ("entries", Json::num(size as f64)),
                    ("storage", Json::s(storage)),
                    ("shards", Json::num(shards as f64)),
                    ("mean_us", Json::num(lat.mean)),
                    ("p50_us", Json::num(lat.p50)),
                    ("p99_us", Json::num(lat.p99)),
                    ("rows_per_sec", Json::num(rows_per_sec)),
                    ("recall_at_1", Json::num(recall)),
                ]));
            }
        }
    }
    println!("{}", matrix.render());
    let report = Json::obj_from(vec![
        ("bench", Json::s("vector_index")),
        ("dim", Json::num(dim as f64)),
        ("queries", Json::num(64.0)),
        ("segment_rows", Json::num(matrix_segment_rows as f64)),
        ("results", Json::Arr(json_rows)),
    ]);
    std::fs::write("BENCH_vector_index.json", report.to_string())?;
    eprintln!("[vector_index] wrote BENCH_vector_index.json");

    // ---- FLAT vs IVF_FLAT search latency + recall ----
    let data = &all_data[..n.min(max_n)];
    let n = data.len();
    let queries: Vec<Vec<f32>> = (0..64).map(|i| data[(i * (n / 64)) % n].clone()).collect();
    let mut flat = FlatIndex::new(dim);
    for v in data {
        flat.insert(v);
    }
    let mut table = Table::new(
        "Vector index — search latency & recall@1 vs FLAT (N vectors)",
        &["index", "N", "nprobe", "mean us/query", "recall@1 %"],
    );
    let flat_lat = {
        let mut qi = 0;
        measure(3, 30, || {
            let _ = flat.search(&queries[qi % queries.len()], 1);
            qi += 1;
        })
    };
    table.push(vec![
        "FLAT".into(),
        n.to_string(),
        "-".into(),
        format!("{:.1}", flat_lat.mean),
        "100.0".into(),
    ]);

    for nprobe in [1usize, 4, 8, 16] {
        let mut ivf = IvfFlatIndex::new(dim, 64, nprobe);
        for v in data {
            ivf.insert(v);
        }
        let mut hits = 0;
        for q in &queries {
            let a = ivf.search(q, 1);
            let b = flat.search(q, 1);
            if a.first().map(|h| h.id) == b.first().map(|h| h.id) {
                hits += 1;
            }
        }
        let lat = {
            let mut qi = 0;
            measure(3, 30, || {
                let _ = ivf.search(&queries[qi % queries.len()], 1);
                qi += 1;
            })
        };
        table.push(vec![
            "IVF_FLAT".into(),
            n.to_string(),
            nprobe.to_string(),
            format!("{:.1}", lat.mean),
            format!("{:.1}", 100.0 * hits as f64 / queries.len() as f64),
        ]);
    }
    println!("{}", table.render());

    // ---- eviction policy throughput at capacity ----
    let mut evict_table = Table::new(
        "Eviction ablation — bounded cache (capacity 4096), insert+search mix",
        &["policy", "us/op", "evictions"],
    );
    for policy in [
        EvictionPolicy::None,
        EvictionPolicy::Fifo,
        EvictionPolicy::Lru,
        EvictionPolicy::Lfu,
    ] {
        let mut cache = SemanticCache::new(64, IndexKind::Flat)
            .with_eviction(policy, 4096);
        let vecs: Vec<Vec<f32>> = (0..6000).map(|_| rand_unit(&mut rng, 64)).collect();
        let t = std::time::Instant::now();
        for (i, v) in vecs.iter().enumerate() {
            cache.insert(&format!("q{i}"), "r", v.clone());
            if i % 4 == 0 {
                let _ = cache.search(v, 1);
            }
        }
        let us = t.elapsed().as_micros() as f64 / vecs.len() as f64;
        evict_table.push(vec![
            format!("{policy:?}"),
            format!("{us:.1}"),
            cache.stats().evictions.to_string(),
        ]);
    }
    println!("{}", evict_table.render());

    // ---- native scan vs compiled Pallas cosine artifact ----
    eprintln!("[vector_index] loading cosine_scores artifact...");
    match load_runtime() {
        Ok(rt) => {
            let exe = rt.executable("cosine_scores_b4096")?;
            let block = 4096usize;
            let db: Vec<f32> = data.iter().take(block).flatten().copied().collect();
            let q = &queries[0];
            let db_t = HostTensor::f32(db.clone(), &[block, dim]);
            let q_t = HostTensor::f32(q.clone(), &[dim]);
            let compiled = measure(2, 20, || {
                let _ = exe.run(&[db_t.clone(), q_t.clone()]).unwrap();
            });
            let mut flat4k = FlatIndex::new(dim);
            for v in data.iter().take(block) {
                flat4k.insert(v);
            }
            let native = measure(2, 20, || {
                let _ = flat4k.search(q, 1);
            });
            println!("{}", row("native scan (4096x384)", &native));
            println!("{}", row("compiled pallas cosine (4096x384)", &compiled));
        }
        Err(e) => eprintln!("[vector_index] skipping compiled comparison: {e}"),
    }
    Ok(())
}
