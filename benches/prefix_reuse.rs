//! KV prefix-cache benchmark: tweak-path prefill cost with cross-request
//! prefix reuse on vs off, as the number of distinct cached answers grows.
//!
//! Mock tier (always runs, incl. CI): `MockLlm::with_prefix_reuse` prices
//! prefill at `--delay-us` per token actually recomputed, over the same
//! suffixed tweak encoding the substrate uses (static template + cached
//! pair as the stable prefix, new query as the suffix). Reuse-on probes a
//! chunk-keyed LRU before paying; reuse-off runs the identical cost model
//! with an empty chunk set, so every prefill is cold. With D distinct
//! cached answers round-robined over N requests, reuse-on pays the full
//! prompt D times and the suffix N-D times — that is the hot-path
//! economics the `{m}_prefill_resume{P}` artifacts buy on the substrate.
//!
//! Gates: reuse-on tweak p50 <= reuse-off at every D, and reuse-on must
//! recompute strictly fewer tokens than it was asked to prefill.
//!
//! Results land in `BENCH_prefix_reuse.json` (uploaded from CI).
//!
//! `cargo bench --bench prefix_reuse [-- --requests 256 --delay-us 200]`

use std::time::{Duration, Instant};

use tweakllm::baselines::MockLlm;
use tweakllm::bench::{bench_args, Table};
use tweakllm::llm::{LanguageModel, TweakPrompt};
use tweakllm::util::{Json, Summary};

/// Distinct cached answers the tweak stream round-robins over.
const DISTINCT: [usize; 3] = [1, 8, 64];
/// Chunk depths the mock snapshots at (the substrate's PREFIX_CHUNKS twin,
/// scaled to the mock's shorter prompts).
const CHUNKS: [usize; 2] = [32, 64];

struct Cell {
    mode: &'static str,
    distinct: usize,
    tok_per_sec: f64,
    p50_ms: f64,
    p99_ms: f64,
    total_tokens: u64,
    recomputed_tokens: u64,
    hits: u64,
    misses: u64,
}

/// A cached (query, response) pair long enough that the stable prefix
/// crosses every chunk depth in `CHUNKS`.
fn cached_pair(d: usize) -> (String, String) {
    let q = format!("topic {d} cached question about subject number {d}");
    let resp: Vec<String> = (0..40).map(|w| format!("a{d}w{w}")).collect();
    (q, resp.join(" "))
}

fn run_once(reuse: bool, distinct: usize, requests: usize, delay: Duration) -> Cell {
    let chunks: &[usize] = if reuse { &CHUNKS } else { &[] };
    let mut llm = MockLlm::new("small").with_prefix_reuse(chunks, 1024, delay);
    let pairs: Vec<(String, String)> = (0..distinct).map(cached_pair).collect();

    let mut lat = Vec::with_capacity(requests);
    let mut total_tokens = 0u64;
    let mut recomputed_tokens = 0u64;
    let t0 = Instant::now();
    for i in 0..requests {
        let (cq, cr) = &pairs[i % distinct];
        let prompt = TweakPrompt {
            new_query: format!("please rephrase item {i} for me"),
            cached_query: cq.clone(),
            cached_response: cr.clone(),
        };
        let t = Instant::now();
        let r = llm.tweak(&prompt).expect("mock tweak");
        lat.push(t.elapsed().as_secs_f64() * 1000.0);
        total_tokens += r.usage.input_tokens as u64;
        recomputed_tokens += (r.usage.input_tokens - r.restored_tokens) as u64;
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = llm.prefix_stats().expect("prefix sim enabled");
    let summary = Summary::of(&lat);
    Cell {
        mode: if reuse { "reuse_on" } else { "reuse_off" },
        distinct,
        tok_per_sec: total_tokens as f64 / wall.max(1e-12),
        p50_ms: summary.p50,
        p99_ms: summary.p99,
        total_tokens,
        recomputed_tokens,
        hits: stats.hits,
        misses: stats.misses,
    }
}

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let requests = args.usize("requests", 256)?.max(DISTINCT[DISTINCT.len() - 1]);
    let delay_us = args.u64("delay-us", 200)?;
    let delay = Duration::from_micros(delay_us);

    let mut cells: Vec<Cell> = Vec::new();
    for &reuse in &[false, true] {
        for &d in &DISTINCT {
            cells.push(run_once(reuse, d, requests, delay));
        }
    }

    let mut table = Table::new(
        "KV prefix reuse (mock tier) — tweak prefill cost vs distinct cached answers",
        &["mode", "distinct", "tok/s", "p50 ms", "p99 ms", "recomputed", "total", "hits"],
    );
    for c in &cells {
        table.push(vec![
            c.mode.to_string(),
            c.distinct.to_string(),
            format!("{:.0}", c.tok_per_sec),
            format!("{:.2}", c.p50_ms),
            format!("{:.2}", c.p99_ms),
            c.recomputed_tokens.to_string(),
            c.total_tokens.to_string(),
            c.hits.to_string(),
        ]);
    }
    println!("{}", table.render());

    let get = |mode: &str, d: usize| -> &Cell {
        cells.iter().find(|c| c.mode == mode && c.distinct == d).expect("cell")
    };
    for &d in &DISTINCT {
        let on = get("reuse_on", d);
        let off = get("reuse_off", d);
        println!(
            "distinct={d}: p50 {:.2} ms on vs {:.2} ms off ({:.1}x), \
             recomputed {}/{} tokens",
            on.p50_ms,
            off.p50_ms,
            off.p50_ms / on.p50_ms.max(1e-9),
            on.recomputed_tokens,
            on.total_tokens
        );
        // The acceptance gates: reuse must never slow the tweak path down,
        // and must strictly cut the prefill work actually performed.
        assert!(
            on.p50_ms <= off.p50_ms,
            "distinct={d}: reuse-on p50 {:.2} ms exceeds reuse-off {:.2} ms",
            on.p50_ms,
            off.p50_ms
        );
        assert!(
            on.recomputed_tokens < on.total_tokens,
            "distinct={d}: reuse-on recomputed every token ({} of {})",
            on.recomputed_tokens,
            on.total_tokens
        );
        // Round-robin over D pairs: exactly the first touch per pair seeds.
        assert_eq!(on.misses, d as u64, "distinct={d}: one cold prefill per pair");
        assert_eq!(on.hits, (requests - d) as u64, "distinct={d}: the rest restore");
        assert_eq!(off.recomputed_tokens, off.total_tokens, "off must run cold");
    }

    let rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj_from(vec![
                ("mode", Json::s(c.mode)),
                ("distinct", Json::num(c.distinct as f64)),
                ("tok_per_sec", Json::num(c.tok_per_sec)),
                ("p50_ms", Json::num(c.p50_ms)),
                ("p99_ms", Json::num(c.p99_ms)),
                ("total_tokens", Json::num(c.total_tokens as f64)),
                ("recomputed_tokens", Json::num(c.recomputed_tokens as f64)),
                ("hits", Json::num(c.hits as f64)),
                ("misses", Json::num(c.misses as f64)),
            ])
        })
        .collect();
    let top = vec![
        ("bench", Json::s("prefix_reuse")),
        ("requests", Json::num(requests as f64)),
        ("delay_us", Json::num(delay_us as f64)),
        ("rows", Json::Arr(rows)),
    ];
    std::fs::write("BENCH_prefix_reuse.json", Json::obj_from(top).to_string())?;
    eprintln!("[prefix_reuse] wrote BENCH_prefix_reuse.json");
    Ok(())
}
