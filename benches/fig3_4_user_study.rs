//! Figures 3 & 4: the user study (simulated respondent population, see
//! DESIGN.md "Substitutions") on the Question Pairs dataset.
//!
//! Protocol (paper §4.2.2): insert the first question of each pair into the
//! vector DB, query with the second, keep cache hits (sim ≥ 0.7), select
//! 120 queries — 40 per cosine band — and run the survey: 194 collected
//! responses, 175 valid after the minimum-time filter; each respondent
//! casts 3 side-by-side votes and 6 binary satisfaction votes.
//!
//! Paper shape: satisfaction of Small-Tweaked ≈ Big across bands, Tweaked >
//! Big in 0.9–1.0 (82.6% vs 77.4%); side-by-side Draw+Small (274) > Big (213).
//!
//! `cargo bench --bench fig3_4_user_study [-- --pairs 2000]`

use tweakllm::bench::{bench_args, load_embedder, Table};
use tweakllm::cache::{FlatIndex, VectorIndex};
use tweakllm::datasets::QuestionPairDataset;
use tweakllm::eval::quality::QualityModel;
use tweakllm::eval::survey::{run_survey, SurveyConfig, SurveyItem};
use tweakllm::eval::Band;
use tweakllm::runtime::TextEmbedder;
use tweakllm::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = bench_args();
    let n_pairs = args.usize("pairs", 2000)?;
    let per_band = args.usize("per-band", 40)?;
    let seed = args.u64("seed", 20250923)?;

    eprintln!("[fig3-4] loading artifacts + embedding model...");
    let (_rt, embedder) = load_embedder()?;
    let ds = QuestionPairDataset::generate(n_pairs, seed);

    // --- populate cache with first questions (batched embeds) ---
    eprintln!("[fig3-4] embedding {} cached + {} incoming queries...", ds.len(), ds.len());
    let q1s: Vec<&str> = ds.pairs.iter().map(|p| p.q1.text.as_str()).collect();
    let q2s: Vec<&str> = ds.pairs.iter().map(|p| p.q2.text.as_str()).collect();
    let e1 = embedder.embed_batch(&q1s)?;
    let e2 = embedder.embed_batch(&q2s)?;
    let mut index = FlatIndex::new(embedder.out_dim());
    for e in &e1 {
        index.insert(e);
    }

    // --- route second questions; keep hits per band ---
    let mut by_band: std::collections::HashMap<Band, Vec<(usize, usize, f32)>> =
        Default::default();
    for (qi, e) in e2.iter().enumerate() {
        let hits = index.search(e, 1);
        if let Some(h) = hits.first() {
            if let Some(band) = Band::of(h.score) {
                by_band.entry(band).or_default().push((qi, h.id, h.score));
            }
        }
    }
    for band in Band::ALL {
        eprintln!(
            "[fig3-4] band {}: {} cache hits",
            band.label(),
            by_band.get(&band).map(|v| v.len()).unwrap_or(0)
        );
    }

    // --- select 40 per band, build survey items via the quality model ---
    let mut rng = Rng::substream(seed, "fig34/select");
    let mut qm = QualityModel::new(seed);
    let mut items = Vec::new();
    for band in Band::ALL {
        let pool = by_band.remove(&band).unwrap_or_default();
        if pool.is_empty() {
            eprintln!("[fig3-4] WARNING: no hits in band {}", band.label());
            continue;
        }
        let picks = {
            let mut r = rng.sample_indices(pool.len(), per_band.min(pool.len()));
            // if a band is short, reuse with replacement to keep 40
            while r.len() < per_band {
                r.push(rng.usize(pool.len()));
            }
            r
        };
        for pi in picks {
            let (qi, cached_id, sim) = pool[pi];
            let new_intent = ds.pairs[qi].q2.intent;
            let cached_intent = ds.pairs[cached_id].q1.intent;
            items.push(SurveyItem {
                band,
                big: qm.big_direct(),
                tweaked: qm.small_tweaked(sim, Some((&new_intent, &cached_intent))),
            });
        }
    }
    eprintln!("[fig3-4] {} survey items selected", items.len());

    // --- run the survey population ---
    let result = run_survey(&items, &SurveyConfig::default(), seed);
    eprintln!(
        "[fig3-4] respondents: {} valid ({} excluded by time filter; paper: 175/19)",
        result.respondents, result.excluded
    );

    let mut fig3 = Table::new(
        "Fig 3 — satisfaction rating (%) by cosine band",
        &["band", "Big LLM", "Small LLM Tweaked", "paper Big", "paper Tweaked"],
    );
    let paper3 = [("0.7-0.8", 76.0, 73.0), ("0.8-0.9", 75.0, 74.0), ("0.9-1.0", 77.4, 82.6)];
    for ((band, big, tweaked), (pl, pb, pt)) in result.satisfaction.iter().zip(paper3) {
        assert_eq!(band.label(), pl);
        fig3.push(vec![
            band.label().to_string(),
            format!("{:.1}", big.rate()),
            format!("{:.1}", tweaked.rate()),
            format!("{pb:.1}"),
            format!("{pt:.1}"),
        ]);
    }
    println!("{}", fig3.render());

    let mut fig4 = Table::new(
        "Fig 4 — side-by-side votes by cosine band",
        &["band", "Big", "Small(Tweaked)", "Draw", "Small+Draw %"],
    );
    let mut tot_big = 0;
    let mut tot_rest = 0;
    for (band, c) in &result.side_by_side {
        tot_big += c.big;
        tot_rest += c.small + c.draw;
        let pct = 100.0 * (c.small + c.draw) as f64 / c.total().max(1) as f64;
        fig4.push(vec![
            band.label().to_string(),
            c.big.to_string(),
            c.small.to_string(),
            c.draw.to_string(),
            format!("{pct:.1}"),
        ]);
    }
    println!("{}", fig4.render());
    println!(
        "overall: Big={tot_big}  Small+Draw={tot_rest}   (paper: Big=213, Small+Draw=274)"
    );
    assert!(
        tot_rest > tot_big,
        "Fig 4 headline failed: Small+Draw ({tot_rest}) must exceed Big ({tot_big})"
    );
    Ok(())
}
