//! FLAT index: exact brute-force cosine scan.
//!
//! Vectors live in one contiguous row-major matrix so the scan is a single
//! sequential sweep (cache-line friendly, no pointer chasing). The inner
//! loop is a 4-way unrolled dot product — the L3 §Perf hot path; see
//! EXPERIMENTS.md §Perf for the before/after of the unroll.

use super::{SearchHit, TopK, VectorIndex};

pub struct FlatIndex {
    dim: usize,
    data: Vec<f32>,
    removed: Vec<bool>,
}

impl FlatIndex {
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0);
        FlatIndex { dim, data: Vec::new(), removed: Vec::new() }
    }

    #[inline]
    pub fn row(&self, id: usize) -> &[f32] {
        &self.data[id * self.dim..(id + 1) * self.dim]
    }

    /// Vectorization-friendly dot product: `chunks_exact(8)` gives the
    /// compiler bounds-check-free, fixed-width blocks that auto-vectorize
    /// to AVX f32x8 under `-C target-cpu=native` (see EXPERIMENTS.md §Perf:
    /// this form + the target-cpu flag took the 50k-row scan from ~14 ms to
    /// sub-ms). Eight independent accumulators hide FMA latency.
    #[inline]
    pub fn dot_unrolled(a: &[f32], b: &[f32]) -> f32 {
        let mut acc = [0.0f32; 8];
        let ca = a.chunks_exact(8);
        let cb = b.chunks_exact(8);
        let (ra, rb) = (ca.remainder(), cb.remainder());
        for (xa, xb) in ca.zip(cb) {
            for k in 0..8 {
                acc[k] += xa[k] * xb[k];
            }
        }
        let mut tail = 0.0f32;
        for (xa, xb) in ra.iter().zip(rb) {
            tail += xa * xb;
        }
        acc.iter().sum::<f32>() + tail
    }
}

impl VectorIndex for FlatIndex {
    fn insert(&mut self, v: &[f32]) -> usize {
        assert_eq!(v.len(), self.dim, "dimension mismatch");
        let id = self.removed.len();
        self.data.extend_from_slice(v);
        self.removed.push(false);
        id
    }

    fn search(&self, q: &[f32], k: usize) -> Vec<SearchHit> {
        assert_eq!(q.len(), self.dim, "dimension mismatch");
        let mut top = TopK::new(k);
        for id in 0..self.removed.len() {
            if self.removed[id] {
                continue;
            }
            let score = Self::dot_unrolled(self.row(id), q);
            top.push(SearchHit { id, score });
        }
        top.into_vec()
    }

    fn len(&self) -> usize {
        self.removed.len()
    }

    fn remove(&mut self, id: usize) {
        if id < self.removed.len() {
            self.removed[id] = true;
        }
    }

    fn dim(&self) -> usize {
        self.dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{normalize, Rng};

    fn rand_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        normalize(&mut v);
        v
    }

    #[test]
    fn self_is_top_hit() {
        let mut idx = FlatIndex::new(64);
        let mut rng = Rng::new(1);
        let vs: Vec<Vec<f32>> = (0..100).map(|_| rand_unit(&mut rng, 64)).collect();
        for v in &vs {
            idx.insert(v);
        }
        for (i, v) in vs.iter().enumerate() {
            let hits = idx.search(v, 3);
            assert_eq!(hits[0].id, i);
            assert!((hits[0].score - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn removed_never_matches() {
        let mut idx = FlatIndex::new(16);
        let mut rng = Rng::new(2);
        let v = rand_unit(&mut rng, 16);
        let id = idx.insert(&v);
        idx.insert(&rand_unit(&mut rng, 16));
        idx.remove(id);
        let hits = idx.search(&v, 2);
        assert!(hits.iter().all(|h| h.id != id));
    }

    #[test]
    fn results_sorted_desc() {
        let mut idx = FlatIndex::new(32);
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let v = rand_unit(&mut rng, 32);
            idx.insert(&v);
        }
        let q = rand_unit(&mut rng, 32);
        let hits = idx.search(&q, 10);
        for w in hits.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn unrolled_dot_matches_naive() {
        let mut rng = Rng::new(4);
        for n in [1, 7, 8, 15, 64, 384, 385] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let fast = FlatIndex::dot_unrolled(&a, &b);
            assert!((naive - fast).abs() < 1e-3, "n={n}: {naive} vs {fast}");
        }
    }

    #[test]
    fn k_larger_than_len() {
        let mut idx = FlatIndex::new(8);
        let mut rng = Rng::new(5);
        idx.insert(&rand_unit(&mut rng, 8));
        idx.insert(&rand_unit(&mut rng, 8));
        assert_eq!(idx.search(&rand_unit(&mut rng, 8), 10).len(), 2);
    }
}
