//! FLAT index: exact brute-force cosine scan over segmented row storage.
//!
//! Rows live in fixed-size segments (`cache::segment`) so the scan can fan
//! out across the shared threadpool (one `TopK` per shard, deterministic
//! merge) and tombstoned rows are compacted away instead of being scanned
//! forever. With `Quantization::Sq8` the sealed segments are scanned as u8
//! codes (~4× less memory bandwidth) and the top candidates re-ranked
//! exactly — results remain sorted, deterministic, and shard-invariant.

use std::sync::Arc;

use super::segment::{dot_f32, IndexOpts, SegmentedStore, Sq8Params};
use super::{SearchHit, VectorIndex};
use crate::util::ThreadPool;

pub struct FlatIndex {
    store: SegmentedStore,
}

impl FlatIndex {
    pub fn new(dim: usize) -> Self {
        Self::with_opts(dim, IndexOpts::default())
    }

    pub fn with_opts(dim: usize, opts: IndexOpts) -> Self {
        FlatIndex { store: SegmentedStore::new(dim, opts) }
    }

    /// Exact row of a live id. Panics on tombstoned/unknown ids.
    #[inline]
    pub fn row(&self, id: usize) -> &[f32] {
        self.store.row(id).expect("row(): tombstoned or unknown id")
    }

    /// The scan's dot product (see `segment::dot_f32`); kept here because
    /// callers historically reached it as `FlatIndex::dot_unrolled`.
    #[inline]
    pub fn dot_unrolled(a: &[f32], b: &[f32]) -> f32 {
        dot_f32(a, b)
    }

    pub fn store(&self) -> &SegmentedStore {
        &self.store
    }
}

impl VectorIndex for FlatIndex {
    fn insert(&mut self, v: &[f32]) -> usize {
        self.store.insert(v)
    }

    fn search(&self, q: &[f32], k: usize) -> Vec<SearchHit> {
        self.store.search(q, k)
    }

    fn len(&self) -> usize {
        self.store.len()
    }

    fn remove(&mut self, id: usize) {
        self.store.remove(id);
    }

    fn dim(&self) -> usize {
        self.store.dim()
    }

    fn insert_tombstone(&mut self) -> usize {
        self.store.insert_tombstone()
    }

    fn live_len(&self) -> usize {
        self.store.live_len()
    }

    fn set_pool(&mut self, pool: Arc<ThreadPool>, shards: usize) {
        self.store.set_pool(pool, shards);
    }

    fn quant_params(&self) -> Option<Sq8Params> {
        self.store.quant_params()
    }

    fn set_quant_params(&mut self, p: Sq8Params) {
        self.store.set_quant_params(p);
    }
}

#[cfg(test)]
mod tests {
    use super::super::segment::Quantization;
    use super::*;
    use crate::util::{normalize, Rng};

    fn rand_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        normalize(&mut v);
        v
    }

    #[test]
    fn self_is_top_hit() {
        let mut idx = FlatIndex::new(64);
        let mut rng = Rng::new(1);
        let vs: Vec<Vec<f32>> = (0..100).map(|_| rand_unit(&mut rng, 64)).collect();
        for v in &vs {
            idx.insert(v);
        }
        for (i, v) in vs.iter().enumerate() {
            let hits = idx.search(v, 3);
            assert_eq!(hits[0].id, i);
            assert!((hits[0].score - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn removed_never_matches() {
        let mut idx = FlatIndex::new(16);
        let mut rng = Rng::new(2);
        let v = rand_unit(&mut rng, 16);
        let id = idx.insert(&v);
        idx.insert(&rand_unit(&mut rng, 16));
        idx.remove(id);
        let hits = idx.search(&v, 2);
        assert!(hits.iter().all(|h| h.id != id));
        assert_eq!(idx.live_len(), 1);
        assert_eq!(idx.len(), 2);
    }

    #[test]
    fn results_sorted_desc() {
        let mut idx = FlatIndex::new(32);
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let v = rand_unit(&mut rng, 32);
            idx.insert(&v);
        }
        let q = rand_unit(&mut rng, 32);
        let hits = idx.search(&q, 10);
        for w in hits.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn unrolled_dot_matches_naive() {
        let mut rng = Rng::new(4);
        for n in [1, 7, 8, 15, 64, 384, 385] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let fast = FlatIndex::dot_unrolled(&a, &b);
            assert!((naive - fast).abs() < 1e-3, "n={n}: {naive} vs {fast}");
        }
    }

    #[test]
    fn k_larger_than_len() {
        let mut idx = FlatIndex::new(8);
        let mut rng = Rng::new(5);
        idx.insert(&rand_unit(&mut rng, 8));
        idx.insert(&rand_unit(&mut rng, 8));
        assert_eq!(idx.search(&rand_unit(&mut rng, 8), 10).len(), 2);
    }

    #[test]
    fn sq8_flat_finds_self() {
        let opts = IndexOpts {
            quantization: Quantization::Sq8,
            segment_rows: 32,
            ..IndexOpts::default()
        };
        let mut idx = FlatIndex::with_opts(24, opts);
        let mut rng = Rng::new(6);
        let vs: Vec<Vec<f32>> = (0..200).map(|_| rand_unit(&mut rng, 24)).collect();
        for v in &vs {
            idx.insert(v);
        }
        assert!(idx.quant_params().is_some());
        for (i, v) in vs.iter().enumerate() {
            // exact re-rank makes self-recall exact even under quantization
            assert_eq!(idx.search(v, 1)[0].id, i);
        }
    }
}
