//! Durable cache persistence: binary snapshots + append-only WAL + crash
//! recovery + size-triggered compaction.
//!
//! The paper's cache accrues value over millions of queries, but an
//! in-process store dies with the process. This module makes the cache a
//! long-lived asset (cf. SCALM / MeanCache, which both treat the semantic
//! cache as a persistent store):
//!
//! * **Snapshot** — one binary file holding the full cache state: every id
//!   slot (live entries *and* tombstones, so ids stay stable), L2-normalized
//!   embeddings, eviction/touch metadata, the logical clock, and the cache
//!   stats. Written atomically (tmp + rename) and verified by a trailing
//!   checksum.
//! * **WAL** — an append-only log of every `insert` / `remove` / `touch`
//!   between snapshots. Each record is individually checksummed so a torn
//!   tail (crash mid-append) is detected and dropped, never replayed.
//! * **Recovery** — `snapshot + WAL replay → identical cache`. A generation
//!   counter pairs each WAL with the snapshot it extends; stale files from
//!   older generations are garbage-collected on open.
//! * **Compaction** — once the WAL outgrows `compact_bytes`, the whole state
//!   is folded into a fresh snapshot at generation `g+1` and a new empty WAL
//!   is started; the old generation's files are deleted.
//!
//! File layout inside `data_dir` (all integers little-endian):
//!
//! ```text
//! snapshot-<gen>.snap:
//!   "TWKS" | version u32 | generation u64 | dim u64 | tick u64
//!   | stats (inserts, lookups, exact_hits, evictions: u64 x4)
//!   | [version >= 2] quant flag u8 (0 = none, 1 = SQ8);
//!       SQ8: min f32[dim] | scale f32[dim]   (each as u32 count + raw f32)
//!   | n_slots u64
//!   | per slot: flag u8 (0 = tombstone, 1 = live);
//!       live: query str | response str | embedding f32[dim]
//!             | inserted_at u64 | last_used u64 | use_count u64
//!   | checksum u64 (hash of every preceding byte)
//!
//! wal-<gen>.log:
//!   "TWKW" | version u32 | generation u64
//!   | records: op u8 | payload_len u32 | payload | checksum u64 (op+payload)
//! ```
//!
//! Version history: v1 had no quantization section. v2 (the SQ8/segmented
//! index release) persists the trained scalar-quantization params so a
//! restart encodes identical u8 codes and returns identical hits. Old v1
//! snapshots and WALs still recover (the quant section defaults to none);
//! new files are always written at the current version. The WAL *record*
//! format is unchanged across v1/v2.
//!
//! Strings are `u32` length + UTF-8 bytes; embeddings are `u32` count + raw
//! f32 little-endian. Checksums use the crate's FNV-style `hash_bytes`.
//!
//! A `LOCK` file (owner pid) guards the directory against a second writer;
//! see `acquire_lock`.
//!
//! Caveat: recovery rebuilds the vector index by re-inserting embeddings.
//! For FLAT this is bit-identical (same rows, same order, same scores). For
//! IVF_FLAT the recovered quantizer may train at a different point than the
//! original run's (tombstones replay as insert+remove, shifting the live
//! count trajectory), so ANN results near cluster borders can differ after
//! recovery; the quantizer state itself is not serialized.

use std::fs::{self, File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::segment::Sq8Params;
use super::store::CacheStats;
use crate::util::rng::hash_bytes;

pub const SNAPSHOT_MAGIC: [u8; 4] = *b"TWKS";
pub const WAL_MAGIC: [u8; 4] = *b"TWKW";
/// Current on-disk format. Readers accept `MIN_FORMAT_VERSION..=FORMAT_VERSION`.
pub const FORMAT_VERSION: u32 = 2;
pub const MIN_FORMAT_VERSION: u32 = 1;

const OP_INSERT: u8 = 1;
const OP_REMOVE: u8 = 2;
const OP_TOUCH: u8 = 3;
const OP_GEN_BUMP: u8 = 4;

/// `[persist]` section of the config. An empty `data_dir` disables the
/// subsystem entirely (the paper-faithful ephemeral mode).
#[derive(Clone, Debug)]
pub struct PersistConfig {
    /// Directory for snapshot + WAL files. Empty string = disabled.
    pub data_dir: String,
    /// fsync the WAL after every append (durable but slower). Snapshots are
    /// always synced regardless.
    pub wal_fsync: bool,
    /// Fold the WAL into a fresh snapshot once it exceeds this many bytes.
    pub compact_bytes: u64,
    /// Group-commit window for WAL fsyncs, in milliseconds. With
    /// `wal_fsync = true` and a non-zero window, an append only pays
    /// `sync_data` once the window has elapsed since the last sync, so a
    /// burst of inserts shares one fsync instead of serializing on the disk.
    /// The tradeoff is explicit: a crash can lose at most the window's worth
    /// of acknowledged appends. `0` keeps fsync-per-append; the value is
    /// ignored entirely when `wal_fsync = false` (which never syncs).
    pub fsync_batch_ms: u64,
}

impl Default for PersistConfig {
    fn default() -> Self {
        PersistConfig {
            data_dir: String::new(),
            wal_fsync: false,
            compact_bytes: 64 * 1024 * 1024,
            fsync_batch_ms: 0,
        }
    }
}

impl PersistConfig {
    pub fn enabled(&self) -> bool {
        !self.data_dir.is_empty()
    }
}

/// What recovery found on open (surfaced in `EngineStats` and logs).
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// Generation of the recovered state.
    pub generation: u64,
    /// Id slots restored from the snapshot (live + tombstoned).
    pub snapshot_slots: u64,
    /// WAL records replayed on top of the snapshot.
    pub replayed_ops: u64,
    /// Live entries in the cache after recovery.
    pub recovered_entries: u64,
    /// True when the WAL ended in a torn (partially-written) record that was
    /// discarded.
    pub torn_tail: bool,
}

/// Live counters for the persistence layer (surfaced in stats/metrics).
#[derive(Clone, Copy, Debug, Default)]
pub struct PersistStatus {
    pub generation: u64,
    pub wal_bytes: u64,
    pub wal_records: u64,
    pub compactions: u64,
    /// Unix seconds of the last compaction/snapshot (0 = never this run).
    pub last_compaction_unix: u64,
    /// Journal append failures (the cache keeps serving; see store.rs).
    pub io_errors: u64,
}

/// Everything a snapshot captures for one live id slot.
#[derive(Clone, Debug)]
pub struct SnapshotEntry {
    pub query: String,
    pub response: String,
    pub embedding: Vec<f32>,
    pub inserted_at: u64,
    pub last_used: u64,
    pub use_count: u64,
}

/// Full serializable cache state (`None` slots are tombstones, kept so that
/// ids stay stable across restarts).
#[derive(Clone, Debug)]
pub struct SnapshotState {
    pub dim: usize,
    pub tick: u64,
    pub stats: CacheStats,
    /// Trained SQ8 params (format v2+). Restoring them before re-inserting
    /// rows makes the rebuilt codes — and therefore every search result —
    /// identical to the pre-restart cache.
    pub quant: Option<Sq8Params>,
    pub entries: Vec<Option<SnapshotEntry>>,
}

/// One WAL record (the read-side representation; the write side encodes
/// straight from borrowed data to avoid clones on the hot path).
#[derive(Clone, Debug)]
pub enum WalOp {
    Insert {
        id: u64,
        tick: u64,
        query: String,
        response: String,
        embedding: Vec<f32>,
    },
    Remove {
        id: u64,
        tick: u64,
    },
    Touch {
        id: u64,
        tick: u64,
    },
    /// Terminator written by `compact` at the end of a generation's WAL:
    /// journaling continues in generation `next_gen`. Recovery treats it as
    /// a no-op; a [`WalTailer`] uses it to follow the handoff to the next
    /// log file instead of being stranded mid-stream.
    GenBump {
        next_gen: u64,
    },
}

// ---------------------------------------------------------------------------
// byte-level encoding helpers
// ---------------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    put_u32(buf, v.len() as u32);
    for x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked reader over a byte slice.
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Self {
        Cursor { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("truncated record: wanted {n} bytes at offset {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(s);
        Ok(u64::from_le_bytes(a))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        Ok(String::from_utf8(s.to_vec()).context("invalid UTF-8 in record")?)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let s = self.take(n * 4)?;
        let mut v = Vec::with_capacity(n);
        for i in 0..n {
            let mut a = [0u8; 4];
            a.copy_from_slice(&s[i * 4..i * 4 + 4]);
            v.push(f32::from_le_bytes(a));
        }
        Ok(v)
    }

    fn done(&self) -> bool {
        self.pos == self.b.len()
    }
}

// ---------------------------------------------------------------------------
// snapshot encode / decode
// ---------------------------------------------------------------------------

/// Serialize a snapshot (including trailing checksum).
pub fn encode_snapshot(state: &SnapshotState, generation: u64) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + state.entries.len() * 64);
    buf.extend_from_slice(&SNAPSHOT_MAGIC);
    put_u32(&mut buf, FORMAT_VERSION);
    put_u64(&mut buf, generation);
    put_u64(&mut buf, state.dim as u64);
    put_u64(&mut buf, state.tick);
    put_u64(&mut buf, state.stats.inserts);
    put_u64(&mut buf, state.stats.lookups);
    put_u64(&mut buf, state.stats.exact_hits);
    put_u64(&mut buf, state.stats.evictions);
    match &state.quant {
        None => buf.push(0),
        Some(p) => {
            buf.push(1);
            put_f32s(&mut buf, &p.min);
            put_f32s(&mut buf, &p.scale);
        }
    }
    put_u64(&mut buf, state.entries.len() as u64);
    for slot in &state.entries {
        match slot {
            None => buf.push(0),
            Some(e) => {
                buf.push(1);
                put_str(&mut buf, &e.query);
                put_str(&mut buf, &e.response);
                put_f32s(&mut buf, &e.embedding);
                put_u64(&mut buf, e.inserted_at);
                put_u64(&mut buf, e.last_used);
                put_u64(&mut buf, e.use_count);
            }
        }
    }
    let sum = hash_bytes(&buf);
    put_u64(&mut buf, sum);
    buf
}

/// Parse + verify a snapshot; returns the state and its generation.
pub fn decode_snapshot(bytes: &[u8]) -> Result<(SnapshotState, u64)> {
    if bytes.len() < 4 + 4 + 8 + 8 {
        bail!("snapshot too short ({} bytes)", bytes.len());
    }
    let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let mut a = [0u8; 8];
    a.copy_from_slice(sum_bytes);
    let want = u64::from_le_bytes(a);
    let got = hash_bytes(body);
    if want != got {
        bail!("snapshot checksum mismatch (file {want:#x}, computed {got:#x})");
    }
    let mut c = Cursor::new(body);
    if c.take(4)? != SNAPSHOT_MAGIC {
        bail!("bad snapshot magic");
    }
    let version = c.u32()?;
    if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
        bail!("unsupported snapshot version {version}");
    }
    let generation = c.u64()?;
    let dim = c.u64()? as usize;
    let tick = c.u64()?;
    let stats = CacheStats {
        inserts: c.u64()?,
        lookups: c.u64()?,
        exact_hits: c.u64()?,
        evictions: c.u64()?,
    };
    // v1 predates the quantization section: default to none.
    let quant = if version >= 2 {
        match c.u8()? {
            0 => None,
            1 => {
                let min = c.f32s()?;
                let scale = c.f32s()?;
                if min.len() != dim || scale.len() != dim {
                    bail!(
                        "quant params dim {}/{} != header dim {dim}",
                        min.len(),
                        scale.len()
                    );
                }
                Some(Sq8Params { min, scale })
            }
            f => bail!("bad quant flag {f}"),
        }
    } else {
        None
    };
    let n = c.u64()? as usize;
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        match c.u8()? {
            0 => entries.push(None),
            1 => {
                let query = c.str()?;
                let response = c.str()?;
                let embedding = c.f32s()?;
                if embedding.len() != dim {
                    bail!(
                        "snapshot embedding dim {} != header dim {dim}",
                        embedding.len()
                    );
                }
                let inserted_at = c.u64()?;
                let last_used = c.u64()?;
                let use_count = c.u64()?;
                entries.push(Some(SnapshotEntry {
                    query,
                    response,
                    embedding,
                    inserted_at,
                    last_used,
                    use_count,
                }));
            }
            f => bail!("bad slot flag {f}"),
        }
    }
    if !c.done() {
        bail!("trailing bytes after snapshot body");
    }
    Ok((SnapshotState { dim, tick, stats, quant, entries }, generation))
}

// ---------------------------------------------------------------------------
// WAL writer / reader
// ---------------------------------------------------------------------------

const WAL_HEADER_LEN: u64 = 4 + 4 + 8;

/// Append-only WAL handle. Each record is framed and checksummed so that a
/// crash mid-write corrupts at most the tail, which replay detects and drops.
pub struct WalWriter {
    file: File,
    fsync: bool,
    /// Group-commit window (zero = fsync on every append when `fsync`).
    batch_window: Duration,
    last_sync: Instant,
    /// Appended-but-not-synced bytes exist (only meaningful when `fsync`).
    dirty: bool,
    bytes: u64,
    records: u64,
}

impl WalWriter {
    /// Create a fresh WAL (truncates) and write the header.
    fn create(path: &Path, generation: u64, fsync: bool, batch_ms: u64) -> Result<WalWriter> {
        let mut file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(path)
            .with_context(|| format!("creating WAL {}", path.display()))?;
        let mut header = Vec::with_capacity(WAL_HEADER_LEN as usize);
        header.extend_from_slice(&WAL_MAGIC);
        put_u32(&mut header, FORMAT_VERSION);
        put_u64(&mut header, generation);
        file.write_all(&header)?;
        file.sync_data()?;
        Ok(WalWriter {
            file,
            fsync,
            batch_window: Duration::from_millis(batch_ms),
            last_sync: Instant::now(),
            dirty: false,
            bytes: WAL_HEADER_LEN,
            records: 0,
        })
    }

    /// Reopen an existing WAL for append at `valid_bytes` (everything past a
    /// torn tail is truncated away first).
    fn open_append(
        path: &Path,
        valid_bytes: u64,
        records: u64,
        fsync: bool,
        batch_ms: u64,
    ) -> Result<WalWriter> {
        let mut file = OpenOptions::new()
            .write(true)
            .open(path)
            .with_context(|| format!("opening WAL {}", path.display()))?;
        file.set_len(valid_bytes)?;
        file.seek(SeekFrom::End(0))?;
        Ok(WalWriter {
            file,
            fsync,
            batch_window: Duration::from_millis(batch_ms),
            last_sync: Instant::now(),
            dirty: false,
            bytes: valid_bytes,
            records,
        })
    }

    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn records(&self) -> u64 {
        self.records
    }

    fn append_raw(&mut self, op: u8, payload: &[u8]) -> Result<()> {
        let mut frame = Vec::with_capacity(1 + 4 + payload.len() + 8);
        frame.push(op);
        put_u32(&mut frame, payload.len() as u32);
        frame.extend_from_slice(payload);
        let mut sum_input = Vec::with_capacity(1 + payload.len());
        sum_input.push(op);
        sum_input.extend_from_slice(payload);
        put_u64(&mut frame, hash_bytes(&sum_input));
        self.file.write_all(&frame)?;
        if self.fsync {
            // Group commit: inside the batch window the append is only
            // marked dirty; the next append past the window (or an explicit
            // `sync`) pays one fsync for the whole burst.
            if self.batch_window.is_zero() || self.last_sync.elapsed() >= self.batch_window {
                self.file.sync_data()?;
                self.last_sync = Instant::now();
                self.dirty = false;
            } else {
                self.dirty = true;
            }
        }
        self.bytes += frame.len() as u64;
        self.records += 1;
        Ok(())
    }

    pub fn append_insert(
        &mut self,
        id: u64,
        tick: u64,
        query: &str,
        response: &str,
        embedding: &[f32],
    ) -> Result<()> {
        let mut p = Vec::with_capacity(16 + query.len() + response.len() + embedding.len() * 4);
        put_u64(&mut p, id);
        put_u64(&mut p, tick);
        put_str(&mut p, query);
        put_str(&mut p, response);
        put_f32s(&mut p, embedding);
        self.append_raw(OP_INSERT, &p)
    }

    pub fn append_remove(&mut self, id: u64, tick: u64) -> Result<()> {
        let mut p = Vec::with_capacity(16);
        put_u64(&mut p, id);
        put_u64(&mut p, tick);
        self.append_raw(OP_REMOVE, &p)
    }

    pub fn append_touch(&mut self, id: u64, tick: u64) -> Result<()> {
        let mut p = Vec::with_capacity(16);
        put_u64(&mut p, id);
        put_u64(&mut p, tick);
        self.append_raw(OP_TOUCH, &p)
    }

    fn append_gen_bump(&mut self, next_gen: u64) -> Result<()> {
        let mut p = Vec::with_capacity(8);
        put_u64(&mut p, next_gen);
        self.append_raw(OP_GEN_BUMP, &p)
    }

    fn sync(&mut self) -> Result<()> {
        self.file.sync_data()?;
        self.last_sync = Instant::now();
        self.dirty = false;
        Ok(())
    }
}

/// Result of scanning a WAL file.
pub struct WalScan {
    pub generation: u64,
    pub ops: Vec<WalOp>,
    /// Byte offset of the last fully-valid record's end.
    pub valid_bytes: u64,
    /// True when trailing bytes after `valid_bytes` were discarded.
    pub torn_tail: bool,
}

/// Read a WAL file, stopping (not failing) at the first torn/corrupt record.
pub fn read_wal(path: &Path) -> Result<WalScan> {
    let mut bytes = Vec::new();
    File::open(path)
        .with_context(|| format!("opening WAL {}", path.display()))?
        .read_to_end(&mut bytes)?;
    if bytes.len() < WAL_HEADER_LEN as usize {
        bail!("WAL shorter than header ({} bytes)", bytes.len());
    }
    if bytes[..4] != WAL_MAGIC {
        bail!("bad WAL magic");
    }
    let mut c = Cursor::new(&bytes);
    c.take(4)?; // magic
    let version = c.u32()?;
    if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
        bail!("unsupported WAL version {version}");
    }
    let generation = c.u64()?;
    let mut ops = Vec::new();
    let mut valid = c.pos as u64;
    let mut torn = false;
    loop {
        if c.done() {
            break;
        }
        match read_wal_record(&mut c) {
            Ok(op) => {
                ops.push(op);
                valid = c.pos as u64;
            }
            Err(_) => {
                // Torn tail: drop everything from the failed record on.
                torn = true;
                break;
            }
        }
    }
    Ok(WalScan { generation, ops, valid_bytes: valid, torn_tail: torn })
}

fn read_wal_record(c: &mut Cursor) -> Result<WalOp> {
    let op = c.u8()?;
    let len = c.u32()? as usize;
    let payload = c.take(len)?;
    let want = c.u64()?;
    let mut sum_input = Vec::with_capacity(1 + len);
    sum_input.push(op);
    sum_input.extend_from_slice(payload);
    let got = hash_bytes(&sum_input);
    if want != got {
        bail!("WAL record checksum mismatch");
    }
    let mut p = Cursor::new(payload);
    let rec = match op {
        OP_INSERT => WalOp::Insert {
            id: p.u64()?,
            tick: p.u64()?,
            query: p.str()?,
            response: p.str()?,
            embedding: p.f32s()?,
        },
        OP_REMOVE => WalOp::Remove { id: p.u64()?, tick: p.u64()? },
        OP_TOUCH => WalOp::Touch { id: p.u64()?, tick: p.u64()? },
        OP_GEN_BUMP => WalOp::GenBump { next_gen: p.u64()? },
        x => bail!("unknown WAL op {x}"),
    };
    if !p.done() {
        bail!("trailing bytes in WAL payload");
    }
    Ok(rec)
}

// ---------------------------------------------------------------------------
// WAL tailing: the read side of replication shipping
// ---------------------------------------------------------------------------

/// Decode one raw on-disk WAL record frame (as surfaced by
/// [`WalTailer::poll`] and shipped verbatim to replicas) back into a
/// [`WalOp`]. Verifies the per-record checksum.
pub fn decode_wal_record(frame: &[u8]) -> Result<WalOp> {
    let mut c = Cursor::new(frame);
    let rec = read_wal_record(&mut c)?;
    if !c.done() {
        bail!("trailing bytes after WAL record frame");
    }
    Ok(rec)
}

/// One record observed by a [`WalTailer`]: its position (generation plus
/// 1-based sequence number within that generation) and the raw on-disk
/// frame (`op | len | payload | checksum`), ready to ship over the wire
/// verbatim — the replica re-verifies the checksum on decode.
#[derive(Clone, Debug)]
pub struct TailedRecord {
    pub generation: u64,
    pub seq: u64,
    pub op: WalOp,
    pub frame: Vec<u8>,
}

/// Cursor that follows a data directory's WAL across appends *and*
/// compactions. Only complete, checksummed records are ever surfaced — a
/// torn or still-being-written tail is left for a later poll — so the
/// tailer observes exactly the prefix that crash recovery would replay.
/// When it reads a [`WalOp::GenBump`] terminator it hops to the next
/// generation's file and keeps going; `compact` retains the previous
/// generation's WAL precisely so this handoff never races file deletion.
pub struct WalTailer {
    dir: PathBuf,
    generation: u64,
    offset: u64,
    seq: u64,
}

impl WalTailer {
    /// Start at the very beginning of `generation`'s WAL.
    pub fn from_generation_start(dir: &Path, generation: u64) -> WalTailer {
        WalTailer {
            dir: dir.to_path_buf(),
            generation,
            offset: WAL_HEADER_LEN,
            seq: 0,
        }
    }

    /// Resume after `seq` complete records of `generation` (a replica's
    /// acked position). Fails when the file is gone or holds fewer records
    /// than claimed — the caller falls back to a fresh bootstrap.
    pub fn resume(dir: &Path, generation: u64, seq: u64) -> Result<WalTailer> {
        let mut t = WalTailer::from_generation_start(dir, generation);
        if seq == 0 {
            return Ok(t);
        }
        let path = wal_path(dir, generation);
        let bytes = fs::read(&path)
            .with_context(|| format!("resuming tailer on {}", path.display()))?;
        if bytes.len() < WAL_HEADER_LEN as usize || bytes[..4] != WAL_MAGIC {
            bail!("WAL {} malformed; cannot resume", path.display());
        }
        let mut c = Cursor::new(&bytes);
        c.pos = WAL_HEADER_LEN as usize;
        while t.seq < seq {
            match read_wal_record(&mut c) {
                Ok(_) => {
                    t.seq += 1;
                    t.offset = c.pos as u64;
                }
                Err(_) => bail!(
                    "WAL {} has only {} complete records, cannot resume at {seq}",
                    path.display(),
                    t.seq
                ),
            }
        }
        Ok(t)
    }

    /// Current position: (generation, records consumed in it).
    pub fn position(&self) -> (u64, u64) {
        (self.generation, self.seq)
    }

    /// Collect every complete record appended since the last poll, following
    /// generation bumps into the next WAL file. Returns an empty vec when
    /// nothing new is ready; errors mean the tailer lost the log (file
    /// vanished or shrank under it) and the caller must re-bootstrap.
    pub fn poll(&mut self) -> Result<Vec<TailedRecord>> {
        let mut out = Vec::new();
        loop {
            let path = wal_path(&self.dir, self.generation);
            let bytes = match fs::read(&path) {
                Ok(b) => b,
                // A just-bumped-to generation whose file isn't visible yet
                // (or a fresh dir): nothing to read, not an error.
                Err(_) if self.offset == WAL_HEADER_LEN => return Ok(out),
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("tailing WAL {}", path.display()))
                }
            };
            if bytes.len() < WAL_HEADER_LEN as usize {
                return Ok(out); // header still being written
            }
            if bytes[..4] != WAL_MAGIC {
                bail!("bad WAL magic in {}", path.display());
            }
            if (bytes.len() as u64) < self.offset {
                bail!(
                    "WAL {} shrank below tailer offset {} (log rewritten?)",
                    path.display(),
                    self.offset
                );
            }
            let mut c = Cursor::new(&bytes);
            c.pos = self.offset as usize;
            let mut bumped = None;
            while !c.done() {
                let start = c.pos;
                match read_wal_record(&mut c) {
                    Ok(op) => {
                        self.offset = c.pos as u64;
                        self.seq += 1;
                        let next = match &op {
                            WalOp::GenBump { next_gen } => Some(*next_gen),
                            _ => None,
                        };
                        out.push(TailedRecord {
                            generation: self.generation,
                            seq: self.seq,
                            op,
                            frame: bytes[start..c.pos].to_vec(),
                        });
                        if let Some(g) = next {
                            bumped = Some(g);
                            break;
                        }
                    }
                    // Incomplete / torn tail: the rest arrives (or is
                    // truncated away by recovery) later.
                    Err(_) => break,
                }
            }
            match bumped {
                Some(g) => {
                    self.generation = g;
                    self.offset = WAL_HEADER_LEN;
                    self.seq = 0;
                }
                None => return Ok(out),
            }
        }
    }
}

/// What a replica needs to bootstrap: the newest snapshot's generation and
/// raw file bytes (`None` while the dir is still at generation 0 with no
/// snapshot). The shipper sends these verbatim; the replica decodes with
/// [`decode_snapshot`] and then tails the WAL from that generation's start.
pub fn bootstrap_view(dir: &Path) -> Result<(u64, Option<Vec<u8>>)> {
    let mut newest: Option<u64> = None;
    for ent in fs::read_dir(dir)
        .with_context(|| format!("reading data dir {}", dir.display()))?
    {
        let name = ent?.file_name();
        if let Some(g) = parse_gen(&name.to_string_lossy(), "snapshot-", ".snap") {
            newest = Some(newest.unwrap_or(0).max(g));
        }
    }
    match newest {
        Some(g) => {
            let path = snapshot_path(dir, g);
            let bytes = fs::read(&path)
                .with_context(|| format!("reading snapshot {}", path.display()))?;
            Ok((g, Some(bytes)))
        }
        None => Ok((0, None)),
    }
}

// ---------------------------------------------------------------------------
// the persistence manager: generations, recovery, compaction
// ---------------------------------------------------------------------------

fn snapshot_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("snapshot-{generation:08}.snap"))
}

fn wal_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("wal-{generation:08}.log"))
}

fn parse_gen(name: &str, prefix: &str, suffix: &str) -> Option<u64> {
    name.strip_prefix(prefix)?.strip_suffix(suffix)?.parse().ok()
}

fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

fn lock_path(dir: &Path) -> PathBuf {
    dir.join("LOCK")
}

/// Advisory cross-process lock: a `LOCK` file holding the owner's pid. Two
/// processes appending to the same WAL would interleave frames and corrupt
/// the stream, so a second open fails fast while the owner is alive. A lock
/// left by a dead process (crash) is detected via `/proc/<pid>` on Linux
/// and taken over; on platforms without `/proc` the lock is best-effort.
fn acquire_lock(dir: &Path) -> Result<()> {
    let path = lock_path(dir);
    if let Ok(prev) = fs::read_to_string(&path) {
        if let Ok(pid) = prev.trim().parse::<u32>() {
            let alive = pid != std::process::id()
                && Path::new(&format!("/proc/{pid}")).exists();
            if alive {
                bail!(
                    "data dir {} is locked by live process {pid} \
                     (two writers would corrupt the WAL); remove {} only if \
                     that process is really gone",
                    dir.display(),
                    path.display()
                );
            }
        }
    }
    fs::write(&path, format!("{}\n", std::process::id()))
        .with_context(|| format!("writing lock {}", path.display()))?;
    Ok(())
}

/// Owns the data directory: the open WAL, the generation counter, and the
/// compaction machinery. Attached to a `SemanticCache` after recovery; the
/// cache journals every mutation through it.
pub struct Persistence {
    dir: PathBuf,
    cfg: PersistConfig,
    generation: u64,
    wal: WalWriter,
    compactions: u64,
    last_compaction_unix: u64,
    pub(super) io_errors: u64,
    /// Set when a WAL append failed: further appends are suppressed (a gap
    /// or partial frame would make everything after it unrecoverable) until
    /// a successful compaction re-establishes a clean snapshot + fresh WAL.
    poisoned: bool,
}

impl Persistence {
    /// Open (or create) the data dir, pick the newest verified snapshot, and
    /// scan its WAL. Returns the manager plus whatever state must be
    /// replayed into a fresh cache.
    ///
    /// A snapshot that exists but fails verification is an **error**, not a
    /// silent fallback: compaction deletes the WAL the snapshot folded, so
    /// skipping a corrupt snapshot would serve an empty cache as if nothing
    /// were lost.
    pub fn open(
        cfg: &PersistConfig,
    ) -> Result<(Persistence, Option<SnapshotState>, Vec<WalOp>, RecoveryReport)> {
        if !cfg.enabled() {
            bail!("persistence is disabled (empty data_dir)");
        }
        let dir = PathBuf::from(&cfg.data_dir);
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating data dir {}", dir.display()))?;
        acquire_lock(&dir)?;

        // Newest snapshot generation on disk, if any.
        let mut snap_gens: Vec<u64> = Vec::new();
        for ent in fs::read_dir(&dir)? {
            let ent = ent?;
            let name = ent.file_name();
            let name = name.to_string_lossy();
            if let Some(g) = parse_gen(&name, "snapshot-", ".snap") {
                snap_gens.push(g);
            }
        }
        snap_gens.sort_unstable();

        let mut report = RecoveryReport::default();
        let (snapshot, generation) = match snap_gens.last() {
            Some(&g) => {
                let path = snapshot_path(&dir, g);
                let mut bytes = Vec::new();
                File::open(&path)
                    .with_context(|| format!("opening snapshot {}", path.display()))?
                    .read_to_end(&mut bytes)?;
                let (state, file_gen) = decode_snapshot(&bytes)
                    .with_context(|| format!("verifying snapshot {}", path.display()))?;
                if file_gen != g {
                    bail!(
                        "snapshot {} claims generation {file_gen}, filename says {g}",
                        path.display()
                    );
                }
                report.snapshot_slots = state.entries.len() as u64;
                (Some(state), g)
            }
            None => (None, 0),
        };
        report.generation = generation;

        // Scan + reopen this generation's WAL (create it if absent — e.g. a
        // crash between snapshot rename and WAL creation during compaction).
        let wpath = wal_path(&dir, generation);
        let (wal, ops) = if wpath.exists() {
            let scan = read_wal(&wpath)
                .with_context(|| format!("scanning WAL {}", wpath.display()))?;
            if scan.generation != generation {
                bail!(
                    "WAL {} is generation {}, expected {generation}",
                    wpath.display(),
                    scan.generation
                );
            }
            report.replayed_ops = scan.ops.len() as u64;
            report.torn_tail = scan.torn_tail;
            let w = WalWriter::open_append(
                &wpath,
                scan.valid_bytes,
                scan.ops.len() as u64,
                cfg.wal_fsync,
                cfg.fsync_batch_ms,
            )?;
            (w, scan.ops)
        } else {
            (
                WalWriter::create(&wpath, generation, cfg.wal_fsync, cfg.fsync_batch_ms)?,
                Vec::new(),
            )
        };

        let p = Persistence {
            dir,
            cfg: cfg.clone(),
            generation,
            wal,
            compactions: 0,
            last_compaction_unix: 0,
            io_errors: 0,
            poisoned: false,
        };
        p.gc_stale_generations();
        Ok((p, snapshot, ops, report))
    }

    /// Delete files from generations other than the current one (stale after
    /// compaction, or left behind by a crash mid-compaction).
    fn gc_stale_generations(&self) {
        let entries = match fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(_) => return,
        };
        for ent in entries.flatten() {
            let name = ent.file_name();
            let name = name.to_string_lossy().to_string();
            let stale = match (
                parse_gen(&name, "snapshot-", ".snap"),
                parse_gen(&name, "wal-", ".log"),
            ) {
                (Some(g), _) => g != self.generation,
                // The previous generation's WAL is retained so a replication
                // tailer can still read through its gen-bump terminator.
                (_, Some(g)) => g != self.generation && g + 1 != self.generation,
                _ => name.ends_with(".tmp"),
            };
            if stale {
                let _ = fs::remove_file(ent.path());
            }
        }
    }

    pub fn wal_mut(&mut self) -> &mut WalWriter {
        &mut self.wal
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn status(&self) -> PersistStatus {
        PersistStatus {
            generation: self.generation,
            wal_bytes: self.wal.bytes(),
            wal_records: self.wal.records(),
            compactions: self.compactions,
            last_compaction_unix: self.last_compaction_unix,
            io_errors: self.io_errors,
        }
    }

    /// True once the WAL has outgrown the configured compaction threshold —
    /// or when a failed append poisoned it and only a fresh snapshot can
    /// restore durability.
    pub fn wants_compaction(&self) -> bool {
        self.poisoned || self.wal.bytes() >= self.cfg.compact_bytes
    }

    pub(super) fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    pub(super) fn poison(&mut self) {
        self.poisoned = true;
    }

    /// Fold the given full state into a fresh snapshot at generation `g+1`,
    /// start an empty WAL, and delete the old generation's files. Returns
    /// the new generation.
    pub fn compact(&mut self, state: &SnapshotState) -> Result<u64> {
        let new_gen = self.generation + 1;
        let bytes = encode_snapshot(state, new_gen);
        let final_path = snapshot_path(&self.dir, new_gen);
        let tmp_path = self.dir.join(format!("snapshot-{new_gen:08}.snap.tmp"));
        {
            let mut f = File::create(&tmp_path)
                .with_context(|| format!("creating {}", tmp_path.display()))?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        // Create the new generation's WAL *before* publishing its snapshot:
        // once the rename lands, recovery commits to generation g+1, so its
        // WAL must already exist. (A crash before the rename leaves a stale
        // future WAL that gc_stale_generations sweeps.) The reverse order
        // would let a WAL-creation failure strand all subsequent journaling
        // in the old generation, which the next open garbage-collects.
        let new_wal = WalWriter::create(
            &wal_path(&self.dir, new_gen),
            new_gen,
            self.cfg.wal_fsync,
            self.cfg.fsync_batch_ms,
        )?;
        if let Err(e) = fs::rename(&tmp_path, &final_path) {
            let _ = fs::remove_file(wal_path(&self.dir, new_gen));
            let _ = fs::remove_file(&tmp_path);
            return Err(e)
                .with_context(|| format!("publishing {}", final_path.display()));
        }
        let old_gen = self.generation;
        // Terminate the old WAL with a handoff record so an attached
        // replication tailer follows the bump into the new generation's file
        // instead of being stranded mid-stream. Written *after* the rename
        // (recovery must never see live records trailing a bump: before the
        // rename a crash would resume journaling in the old generation) and
        // best-effort (the old log is already superseded for recovery).
        if !self.poisoned {
            let _ = self.wal.append_gen_bump(new_gen);
            let _ = self.wal.sync();
        }
        self.wal = new_wal;
        self.generation = new_gen;
        self.compactions += 1;
        self.last_compaction_unix = unix_now();
        self.poisoned = false;
        let _ = fs::remove_file(snapshot_path(&self.dir, old_gen));
        // Keep the just-terminated WAL around for one generation so a tailer
        // mid-read can still reach its bump record; drop its predecessor.
        if let Some(prev) = old_gen.checked_sub(1) {
            let _ = fs::remove_file(wal_path(&self.dir, prev));
        }
        Ok(new_gen)
    }
}

impl Drop for Persistence {
    fn drop(&mut self) {
        // Release the advisory lock iff we still own it.
        let path = lock_path(&self.dir);
        if let Ok(prev) = fs::read_to_string(&path) {
            if prev.trim().parse::<u32>() == Ok(std::process::id()) {
                let _ = fs::remove_file(&path);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "tweakllm-persist-{}-{}",
            std::process::id(),
            tag
        ));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn state_with(n: usize, dim: usize) -> SnapshotState {
        let entries = (0..n)
            .map(|i| {
                if i % 5 == 3 {
                    None // tombstone
                } else {
                    Some(SnapshotEntry {
                        query: format!("query {i}"),
                        response: format!("response {i}"),
                        embedding: (0..dim).map(|d| (i * dim + d) as f32).collect(),
                        inserted_at: i as u64,
                        last_used: i as u64 + 1,
                        use_count: i as u64 % 3,
                    })
                }
            })
            .collect();
        SnapshotState {
            dim,
            tick: 2 * n as u64,
            stats: CacheStats { inserts: n as u64, lookups: 7, exact_hits: 2, evictions: 1 },
            quant: None,
            entries,
        }
    }

    /// Hand-encode a version-1 snapshot (no quantization section) so the
    /// backward-compat path is pinned against real v1 bytes.
    fn encode_snapshot_v1(state: &SnapshotState, generation: u64) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(&SNAPSHOT_MAGIC);
        put_u32(&mut buf, 1);
        put_u64(&mut buf, generation);
        put_u64(&mut buf, state.dim as u64);
        put_u64(&mut buf, state.tick);
        put_u64(&mut buf, state.stats.inserts);
        put_u64(&mut buf, state.stats.lookups);
        put_u64(&mut buf, state.stats.exact_hits);
        put_u64(&mut buf, state.stats.evictions);
        put_u64(&mut buf, state.entries.len() as u64);
        for slot in &state.entries {
            match slot {
                None => buf.push(0),
                Some(e) => {
                    buf.push(1);
                    put_str(&mut buf, &e.query);
                    put_str(&mut buf, &e.response);
                    put_f32s(&mut buf, &e.embedding);
                    put_u64(&mut buf, e.inserted_at);
                    put_u64(&mut buf, e.last_used);
                    put_u64(&mut buf, e.use_count);
                }
            }
        }
        let sum = hash_bytes(&buf);
        put_u64(&mut buf, sum);
        buf
    }

    #[test]
    fn snapshot_roundtrip() {
        let s = state_with(23, 8);
        let bytes = encode_snapshot(&s, 5);
        let (back, generation) = decode_snapshot(&bytes).unwrap();
        assert_eq!(generation, 5);
        assert_eq!(back.dim, 8);
        assert_eq!(back.tick, s.tick);
        assert_eq!(back.stats.inserts, s.stats.inserts);
        assert_eq!(back.entries.len(), 23);
        assert!(back.entries[3].is_none());
        let e = back.entries[4].as_ref().unwrap();
        assert_eq!(e.query, "query 4");
        assert_eq!(e.embedding.len(), 8);
        assert_eq!(e.last_used, 5);
    }

    #[test]
    fn v1_snapshot_still_decodes() {
        let s = state_with(9, 4);
        let bytes = encode_snapshot_v1(&s, 3);
        let (back, generation) = decode_snapshot(&bytes).unwrap();
        assert_eq!(generation, 3);
        assert_eq!(back.entries.len(), 9);
        assert!(back.quant.is_none(), "v1 has no quant section");
        assert_eq!(back.stats.inserts, s.stats.inserts);
    }

    #[test]
    fn quant_params_roundtrip_in_v2() {
        let mut s = state_with(5, 3);
        s.quant = Some(Sq8Params {
            min: vec![-0.5, -0.25, 0.0],
            scale: vec![0.004, 0.002, 0.001],
        });
        let bytes = encode_snapshot(&s, 2);
        let (back, _) = decode_snapshot(&bytes).unwrap();
        assert_eq!(back.quant, s.quant);
    }

    #[test]
    fn snapshot_detects_corruption() {
        let s = state_with(4, 4);
        let mut bytes = encode_snapshot(&s, 1);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        assert!(decode_snapshot(&bytes).is_err());
    }

    #[test]
    fn wal_roundtrip_and_torn_tail() {
        let dir = tmp_dir("wal");
        let path = wal_path(&dir, 3);
        {
            let mut w = WalWriter::create(&path, 3, false, 0).unwrap();
            w.append_insert(0, 1, "q0", "r0", &[0.5, -0.5]).unwrap();
            w.append_touch(0, 2).unwrap();
            w.append_remove(0, 3).unwrap();
            w.sync().unwrap();
            assert_eq!(w.records(), 3);
        }
        let scan = read_wal(&path).unwrap();
        assert_eq!(scan.generation, 3);
        assert_eq!(scan.ops.len(), 3);
        assert!(!scan.torn_tail);
        match &scan.ops[0] {
            WalOp::Insert { id, tick, query, embedding, .. } => {
                assert_eq!((*id, *tick), (0, 1));
                assert_eq!(query, "q0");
                assert_eq!(embedding, &vec![0.5, -0.5]);
            }
            other => panic!("expected insert, got {other:?}"),
        }

        // Append garbage: replay keeps the valid prefix and flags the tear.
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&[OP_INSERT, 200, 0, 0]).unwrap(); // truncated frame
        }
        let scan = read_wal(&path).unwrap();
        assert_eq!(scan.ops.len(), 3);
        assert!(scan.torn_tail);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_creates_and_recovers_generations() {
        let dir = tmp_dir("open");
        let cfg = PersistConfig {
            data_dir: dir.to_string_lossy().to_string(),
            wal_fsync: false,
            compact_bytes: u64::MAX,
            fsync_batch_ms: 0,
        };
        // Fresh dir: generation 0, no snapshot, empty WAL.
        {
            let (mut p, snap, ops, report) = Persistence::open(&cfg).unwrap();
            assert!(snap.is_none());
            assert!(ops.is_empty());
            assert_eq!(report.generation, 0);
            p.wal_mut().append_insert(0, 1, "q", "r", &[1.0]).unwrap();
            // Compact into generation 1.
            let state = SnapshotState {
                dim: 1,
                tick: 1,
                stats: CacheStats { inserts: 1, ..Default::default() },
                quant: None,
                entries: vec![Some(SnapshotEntry {
                    query: "q".into(),
                    response: "r".into(),
                    embedding: vec![1.0],
                    inserted_at: 1,
                    last_used: 1,
                    use_count: 0,
                })],
            };
            assert_eq!(p.compact(&state).unwrap(), 1);
            p.wal_mut().append_touch(0, 2).unwrap();
        }
        // The terminated generation-0 WAL is retained (a replication tailer
        // may still need its gen-bump record); reopen resumes generation 1
        // with the snapshot plus one WAL op.
        assert!(wal_path(&dir, 0).exists(), "previous-gen WAL is kept for tailers");
        assert!(!snapshot_path(&dir, 0).exists());
        {
            let (p, snap, ops, report) = Persistence::open(&cfg).unwrap();
            assert_eq!(p.generation(), 1);
            assert_eq!(report.generation, 1);
            let snap = snap.unwrap();
            assert_eq!(snap.entries.len(), 1);
            assert_eq!(ops.len(), 1);
            assert!(matches!(ops[0], WalOp::Touch { id: 0, tick: 2 }));
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lock_file_blocks_a_second_live_writer() {
        let dir = tmp_dir("lock");
        let cfg = PersistConfig {
            data_dir: dir.to_string_lossy().to_string(),
            wal_fsync: false,
            compact_bytes: u64::MAX,
            fsync_batch_ms: 0,
        };
        {
            let (_p, _, _, _) = Persistence::open(&cfg).unwrap();
            assert!(lock_path(&dir).exists());
        }
        // Dropped: the lock is released.
        assert!(!lock_path(&dir).exists());
        // A lock held by a live foreign process blocks the open. pid 1 is
        // always alive on Linux; elsewhere the lock is best-effort only.
        if cfg!(target_os = "linux") && Path::new("/proc/1").exists() {
            fs::write(lock_path(&dir), "1\n").unwrap();
            assert!(Persistence::open(&cfg).is_err());
            fs::remove_file(lock_path(&dir)).unwrap();
        }
        // A stale lock from a dead process is taken over.
        fs::write(lock_path(&dir), "999999999\n").unwrap();
        assert!(Persistence::open(&cfg).is_ok());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_snapshot_is_an_error_not_a_fallback() {
        let dir = tmp_dir("corrupt");
        let cfg = PersistConfig {
            data_dir: dir.to_string_lossy().to_string(),
            wal_fsync: false,
            compact_bytes: u64::MAX,
            fsync_batch_ms: 0,
        };
        {
            let (mut p, _, _, _) = Persistence::open(&cfg).unwrap();
            let state = state_with(6, 2);
            p.compact(&state).unwrap();
        }
        // Flip a byte in the snapshot: open must refuse, not serve empty.
        let path = snapshot_path(&dir, 1);
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        fs::write(&path, &bytes).unwrap();
        assert!(Persistence::open(&cfg).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn tailer_follows_appends_and_compaction_handoff() {
        let dir = tmp_dir("tailer");
        let cfg = PersistConfig {
            data_dir: dir.to_string_lossy().to_string(),
            wal_fsync: false,
            compact_bytes: u64::MAX,
            fsync_batch_ms: 0,
        };
        let (mut p, _, _, _) = Persistence::open(&cfg).unwrap();
        let mut t = WalTailer::from_generation_start(&dir, 0);
        assert!(t.poll().unwrap().is_empty());

        p.wal_mut().append_insert(0, 1, "q0", "r0", &[1.0]).unwrap();
        p.wal_mut().append_touch(0, 2).unwrap();
        let recs = t.poll().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!((recs[0].generation, recs[0].seq), (0, 1));
        assert_eq!((recs[1].generation, recs[1].seq), (0, 2));
        // Shipped frames decode back to the same ops.
        assert!(matches!(
            decode_wal_record(&recs[0].frame).unwrap(),
            WalOp::Insert { id: 0, tick: 1, .. }
        ));

        // Compact: the tailer reads the bump terminator in the old WAL and
        // hops into the new generation without missing later appends.
        p.compact(&state_with(2, 1)).unwrap();
        p.wal_mut().append_remove(0, 9).unwrap();
        let recs = t.poll().unwrap();
        assert_eq!(recs.len(), 2);
        assert!(matches!(recs[0].op, WalOp::GenBump { next_gen: 1 }));
        assert_eq!((recs[0].generation, recs[0].seq), (0, 3));
        assert!(matches!(recs[1].op, WalOp::Remove { id: 0, tick: 9 }));
        assert_eq!((recs[1].generation, recs[1].seq), (1, 1));
        assert_eq!(t.position(), (1, 1));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn tailer_resume_skips_acked_records() {
        let dir = tmp_dir("resume");
        let path = wal_path(&dir, 0);
        let mut w = WalWriter::create(&path, 0, false, 0).unwrap();
        w.append_insert(0, 1, "a", "ra", &[1.0]).unwrap();
        w.append_insert(1, 2, "b", "rb", &[2.0]).unwrap();
        w.append_touch(0, 3).unwrap();
        w.sync().unwrap();

        let mut t = WalTailer::resume(&dir, 0, 2).unwrap();
        let recs = t.poll().unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!((recs[0].generation, recs[0].seq), (0, 3));
        assert!(matches!(recs[0].op, WalOp::Touch { id: 0, tick: 3 }));
        // Claiming a position past the log's end fails: the shipper falls
        // back to a fresh bootstrap instead of silently skipping records.
        assert!(WalTailer::resume(&dir, 0, 9).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn group_commit_window_defers_fsync() {
        let dir = tmp_dir("batch");
        // A huge window: the first post-create append lands inside it, so
        // the writer marks itself dirty instead of paying sync_data.
        let path = wal_path(&dir, 0);
        let mut w = WalWriter::create(&path, 0, true, 60_000).unwrap();
        w.append_insert(0, 1, "q", "r", &[1.0]).unwrap();
        assert!(w.dirty, "append inside the window defers the fsync");
        w.sync().unwrap();
        assert!(!w.dirty);
        // Window 0 keeps fsync-per-append semantics.
        let path1 = wal_path(&dir, 1);
        let mut w1 = WalWriter::create(&path1, 1, true, 0).unwrap();
        w1.append_insert(0, 1, "q", "r", &[1.0]).unwrap();
        assert!(!w1.dirty);
        // Either way every complete record is readable.
        assert_eq!(read_wal(&path).unwrap().ops.len(), 1);
        assert_eq!(read_wal(&path1).unwrap().ops.len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn bootstrap_view_reports_newest_snapshot() {
        let dir = tmp_dir("bootstrap");
        let cfg = PersistConfig {
            data_dir: dir.to_string_lossy().to_string(),
            wal_fsync: false,
            compact_bytes: u64::MAX,
            fsync_batch_ms: 0,
        };
        {
            let (mut p, _, _, _) = Persistence::open(&cfg).unwrap();
            let (g, snap) = bootstrap_view(&dir).unwrap();
            assert_eq!(g, 0);
            assert!(snap.is_none(), "generation 0 has no snapshot yet");
            p.compact(&state_with(4, 2)).unwrap();
        }
        let (g, snap) = bootstrap_view(&dir).unwrap();
        assert_eq!(g, 1);
        let (state, file_gen) = decode_snapshot(&snap.unwrap()).unwrap();
        assert_eq!(file_gen, 1);
        assert_eq!(state.entries.len(), 4);
        let _ = fs::remove_dir_all(&dir);
    }
}
