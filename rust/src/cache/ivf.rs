//! IVF_FLAT index: k-means coarse quantizer + inverted lists + nprobe search.
//!
//! This mirrors the index family the paper configures in Milvus (Table 1:
//! "IVF_FLAT index on embeddings for search acceleration"). Vectors are
//! assigned to their nearest centroid's inverted list; a query scans only the
//! `nprobe` nearest lists. The quantizer trains lazily once `train_after`
//! vectors have arrived and retrains when the store grows by `retrain_factor`
//! — cheap insurance against drift as the cache fills (the paper's cache is
//! append-only and distribution-shifting by construction).
//!
//! Row storage is the segmented store (`cache::segment`): the untrained
//! brute-force path inherits its sharded parallel scan, `Quantization::Sq8`
//! makes the probe scan read u8 codes with an exact f32 re-rank (the Milvus
//! IVF_SQ8 analog), and tombstone compaction reclaims evicted rows. Dead ids
//! linger in the inverted lists (they are skipped at probe time) until the
//! next retrain rebuilds the lists from live rows only.

use std::sync::Arc;

use super::segment::{dot_f32, IndexOpts, SegmentedStore, Sq8Params};
use super::{SearchHit, VectorIndex};
use crate::util::{Rng, ThreadPool};

pub struct IvfFlatIndex {
    nlist: usize,
    nprobe: usize,
    train_after: usize,
    retrain_factor: f64,
    seed: u64,
    /// Segmented row storage; ids are stable slot numbers.
    store: SegmentedStore,
    // Quantizer state. Empty until trained; until then search falls back to
    // the store's (sharded) brute-force scan — identical results, no lists.
    centroids: Vec<f32>,
    lists: Vec<Vec<usize>>,
    assignments: Vec<u32>,
    trained_at: usize,
}

pub const UNASSIGNED: u32 = u32::MAX;

impl IvfFlatIndex {
    pub fn new(dim: usize, nlist: usize, nprobe: usize) -> Self {
        Self::with_opts(dim, nlist, nprobe, IndexOpts::default())
    }

    pub fn with_opts(dim: usize, nlist: usize, nprobe: usize, opts: IndexOpts) -> Self {
        assert!(dim > 0 && nlist > 0 && nprobe > 0);
        IvfFlatIndex {
            nlist,
            nprobe: nprobe.min(nlist),
            train_after: (nlist * 8).max(64),
            retrain_factor: 4.0,
            seed: 0x1ff_2025,
            store: SegmentedStore::new(dim, opts),
            centroids: Vec::new(),
            lists: Vec::new(),
            assignments: Vec::new(),
            trained_at: 0,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn nprobe(&self) -> usize {
        self.nprobe
    }

    pub fn set_nprobe(&mut self, nprobe: usize) {
        self.nprobe = nprobe.clamp(1, self.nlist);
    }

    pub fn is_trained(&self) -> bool {
        !self.centroids.is_empty()
    }

    pub fn store(&self) -> &SegmentedStore {
        &self.store
    }

    #[inline]
    fn row(&self, id: usize) -> &[f32] {
        self.store.row(id).expect("live id has a row")
    }

    #[inline]
    fn centroid(&self, c: usize) -> &[f32] {
        &self.centroids[c * self.dim()..(c + 1) * self.dim()]
    }

    fn nearest_centroid(&self, v: &[f32]) -> usize {
        let mut best = 0usize;
        let mut best_score = f32::NEG_INFINITY;
        for c in 0..self.lists.len() {
            let s = dot_f32(self.centroid(c), v);
            if s > best_score {
                best_score = s;
                best = c;
            }
        }
        best
    }

    /// Lloyd's k-means (cosine / spherical: centroids renormalized each
    /// round) over all live vectors. A handful of iterations is plenty for a
    /// coarse quantizer.
    fn train(&mut self) {
        let dim = self.dim();
        let live = self.store.live_ids();
        let k = self.nlist.min(live.len().max(1));
        if live.is_empty() {
            return;
        }
        let mut rng = Rng::new(self.seed ^ self.store.len() as u64);
        // k-means++ style seeding lite: random distinct picks.
        let picks = rng.sample_indices(live.len(), k);
        let mut centroids = vec![0.0f32; k * dim];
        for (c, &p) in picks.iter().enumerate() {
            centroids[c * dim..(c + 1) * dim].copy_from_slice(self.row(live[p]));
        }
        let mut assign = vec![0usize; live.len()];
        for _iter in 0..6 {
            // assignment step
            for (li, &id) in live.iter().enumerate() {
                let v = self.row(id);
                let mut best = 0;
                let mut best_s = f32::NEG_INFINITY;
                for c in 0..k {
                    let s = dot_f32(&centroids[c * dim..(c + 1) * dim], v);
                    if s > best_s {
                        best_s = s;
                        best = c;
                    }
                }
                assign[li] = best;
            }
            // update step
            let mut sums = vec![0.0f32; k * dim];
            let mut counts = vec![0usize; k];
            for (li, &id) in live.iter().enumerate() {
                let c = assign[li];
                counts[c] += 1;
                let v = self.row(id);
                let dst = &mut sums[c * dim..(c + 1) * dim];
                for (d, &x) in dst.iter_mut().zip(v) {
                    *d += x;
                }
            }
            for c in 0..k {
                if counts[c] == 0 {
                    // re-seed empty cluster from a random live vector
                    let id = live[rng.usize(live.len())];
                    sums[c * dim..(c + 1) * dim].copy_from_slice(self.row(id));
                }
                let cent = &mut sums[c * dim..(c + 1) * dim];
                crate::util::normalize(cent);
            }
            centroids = sums;
        }
        self.centroids = centroids;
        self.lists = vec![Vec::new(); k];
        self.assignments = vec![UNASSIGNED; self.store.len()];
        for (li, &id) in live.iter().enumerate() {
            self.lists[assign[li]].push(id);
            self.assignments[id] = assign[li] as u32;
        }
        self.trained_at = live.len();
    }

    fn maybe_train(&mut self) {
        // O(1): the store maintains the live count incrementally (the old
        // path recounted tombstones with a full scan on every insert,
        // turning bulk loads O(n²)).
        let n_live = self.store.live_len();
        if !self.is_trained() {
            if n_live >= self.train_after {
                self.train();
            }
        } else if n_live as f64 >= self.trained_at as f64 * self.retrain_factor {
            self.train();
        }
    }

    /// Exact scan over every live row (the pre-training path and the recall
    /// reference in tests/benches). Inherits the store's sharded fan-out.
    pub fn brute_force(&self, q: &[f32], k: usize) -> Vec<SearchHit> {
        self.store.search(q, k)
    }
}

impl VectorIndex for IvfFlatIndex {
    fn insert(&mut self, v: &[f32]) -> usize {
        let id = self.store.insert(v);
        debug_assert_eq!(id, self.assignments.len());
        if self.is_trained() {
            let c = self.nearest_centroid(v);
            self.lists[c].push(id);
            self.assignments.push(c as u32);
        } else {
            self.assignments.push(UNASSIGNED);
        }
        self.maybe_train();
        id
    }

    fn search(&self, q: &[f32], k: usize) -> Vec<SearchHit> {
        assert_eq!(q.len(), self.dim(), "dimension mismatch");
        if !self.is_trained() {
            return self.store.search(q, k);
        }
        // rank centroids, probe the top-nprobe lists
        let mut cent_scores: Vec<(usize, f32)> = (0..self.lists.len())
            .map(|c| (c, dot_f32(self.centroid(c), q)))
            .collect();
        cent_scores.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let probe_ids = cent_scores
            .iter()
            .take(self.nprobe)
            .flat_map(|&(c, _)| self.lists[c].iter().copied());
        self.store.search_subset(q, k, probe_ids)
    }

    fn len(&self) -> usize {
        self.store.len()
    }

    fn remove(&mut self, id: usize) {
        // The inverted lists keep the id (skipped at probe time) until the
        // next retrain rebuilds them; the store reclaims the row's memory
        // via tombstone compaction.
        self.store.remove(id);
    }

    fn dim(&self) -> usize {
        self.store.dim()
    }

    fn insert_tombstone(&mut self) -> usize {
        let id = self.store.insert_tombstone();
        debug_assert_eq!(id, self.assignments.len());
        self.assignments.push(UNASSIGNED);
        id
    }

    fn live_len(&self) -> usize {
        self.store.live_len()
    }

    fn set_pool(&mut self, pool: Arc<ThreadPool>, shards: usize) {
        self.store.set_pool(pool, shards);
    }

    fn quant_params(&self) -> Option<Sq8Params> {
        self.store.quant_params()
    }

    fn set_quant_params(&mut self, p: Sq8Params) {
        self.store.set_quant_params(p);
    }
}

#[cfg(test)]
mod tests {
    use super::super::segment::Quantization;
    use super::*;
    use crate::util::{normalize, Rng};

    fn rand_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        normalize(&mut v);
        v
    }

    /// Clustered data: IVF's bread and butter.
    fn clustered(rng: &mut Rng, n: usize, dim: usize, n_clusters: usize) -> Vec<Vec<f32>> {
        let centers: Vec<Vec<f32>> = (0..n_clusters).map(|_| rand_unit(rng, dim)).collect();
        (0..n)
            .map(|i| {
                let c = &centers[i % n_clusters];
                let mut v: Vec<f32> = c
                    .iter()
                    .map(|x| x + 0.25 * rng.normal() as f32)
                    .collect();
                normalize(&mut v);
                v
            })
            .collect()
    }

    #[test]
    fn brute_force_before_training() {
        let mut idx = IvfFlatIndex::new(32, 16, 4);
        let mut rng = Rng::new(1);
        let v = rand_unit(&mut rng, 32);
        idx.insert(&v);
        assert!(!idx.is_trained());
        let hits = idx.search(&v, 1);
        assert_eq!(hits[0].id, 0);
    }

    #[test]
    fn trains_after_threshold_and_high_recall() {
        let mut idx = IvfFlatIndex::new(32, 8, 3);
        let mut rng = Rng::new(2);
        let vs = clustered(&mut rng, 600, 32, 8);
        for v in &vs {
            idx.insert(v);
        }
        assert!(idx.is_trained());
        // recall@1 vs brute force on held-out queries near the data
        let mut hitc = 0;
        for i in 0..100 {
            let q = &vs[i * 6 % vs.len()];
            let ivf = idx.search(q, 1);
            let bf = idx.brute_force(q, 1);
            if ivf[0].id == bf[0].id {
                hitc += 1;
            }
        }
        assert!(hitc >= 90, "recall@1 = {hitc}/100");
    }

    #[test]
    fn self_query_after_training() {
        let mut idx = IvfFlatIndex::new(16, 4, 2);
        let mut rng = Rng::new(3);
        let vs = clustered(&mut rng, 300, 16, 4);
        for v in &vs {
            idx.insert(v);
        }
        // every vector should find itself: it lives in its own nearest list
        // (nprobe=2 gives slack at cluster borders)
        let mut ok = 0;
        for (i, v) in vs.iter().enumerate() {
            if idx.search(v, 1)[0].id == i {
                ok += 1;
            }
        }
        assert!(ok as f64 >= vs.len() as f64 * 0.95, "self-recall={ok}/{}", vs.len());
    }

    #[test]
    fn removed_excluded_after_training() {
        let mut idx = IvfFlatIndex::new(16, 4, 4);
        let mut rng = Rng::new(4);
        let vs = clustered(&mut rng, 200, 16, 4);
        for v in &vs {
            idx.insert(v);
        }
        idx.remove(10);
        let hits = idx.search(&vs[10], 5);
        assert!(hits.iter().all(|h| h.id != 10));
    }

    #[test]
    fn nprobe_full_equals_bruteforce() {
        let mut idx = IvfFlatIndex::new(24, 6, 6);
        let mut rng = Rng::new(5);
        let vs = clustered(&mut rng, 400, 24, 6);
        for v in &vs {
            idx.insert(v);
        }
        let q = rand_unit(&mut rng, 24);
        let a = idx.search(&q, 7);
        let b = idx.brute_force(&q, 7);
        assert_eq!(
            a.iter().map(|h| h.id).collect::<Vec<_>>(),
            b.iter().map(|h| h.id).collect::<Vec<_>>()
        );
    }

    #[test]
    fn sq8_ivf_high_self_recall() {
        let opts = IndexOpts {
            quantization: Quantization::Sq8,
            segment_rows: 128,
            ..IndexOpts::default()
        };
        let mut idx = IvfFlatIndex::with_opts(16, 4, 2, opts);
        let mut rng = Rng::new(6);
        let vs = clustered(&mut rng, 400, 16, 4);
        for v in &vs {
            idx.insert(v);
        }
        assert!(idx.is_trained());
        assert!(idx.quant_params().is_some());
        let mut ok = 0;
        for (i, v) in vs.iter().enumerate() {
            if idx.search(v, 1)[0].id == i {
                ok += 1;
            }
        }
        assert!(ok as f64 >= vs.len() as f64 * 0.95, "self-recall={ok}/{}", vs.len());
    }
}
