//! Segmented row storage: the shared substrate under `FlatIndex` and
//! `IvfFlatIndex`.
//!
//! Three capabilities the monolithic `Vec<f32>` storage could not offer:
//!
//! * **Sharded parallel scan** — rows live in fixed-size segments; sealed
//!   segments are distributed round-robin over N shards and scanned on the
//!   shared `util::ThreadPool` (per-shard `TopK`, deterministic merge), so
//!   search scales with cores instead of pinning one.
//! * **SQ8 quantization** — the Milvus IVF_SQ8 analog: per-dimension
//!   min/max affine quantization to u8, trained once on the first sealed
//!   segment and frozen. Scans score codes asymmetrically (u8 codes × f32
//!   query, one decode fused into the dot product) which cuts scan memory
//!   bandwidth ~4×; the top candidates are re-ranked with the exact f32
//!   rows before results leave the store.
//! * **Tombstone compaction** — removals mark rows dead; once a segment's
//!   dead fraction passes `compact_tombstone_frac` the segment is rewritten
//!   without its dead rows and the stable-id indirection table is remapped.
//!   Ids handed to callers never change — `SemanticCache`, eviction
//!   metadata, and the WAL/snapshot format all key on stable ids.
//!
//! Determinism contract (load-bearing for the persistence round-trip and
//! the shard-invariance tests): every result set is merged by
//! `(score desc, id asc)`, and every row's score is computed by the same
//! function over the same bytes regardless of shard count. Hence 1 shard ≡
//! N shards exactly. Restarts reproduce identical codes (the SQ8 params
//! ride in snapshot format v2) and identical hits whenever the layout
//! round-trips; a restore that compacts tombstones away can only move rows
//! from code-scored sealed segments into the exactly-scored active tail,
//! which never makes candidate selection worse (see DESIGN.md).

use std::sync::mpsc;
use std::sync::Arc;

use super::{SearchHit, TopK};
use crate::util::ThreadPool;

/// Rows per segment. 4096 × 384 dims × 4 B ≈ 6.3 MiB of f32 (1.6 MiB of
/// SQ8 codes): big enough that the scan stays sequential, small enough that
/// a 10k-entry cache already has material to shard.
pub const DEFAULT_SEGMENT_ROWS: usize = 4096;

/// Exact re-rank budget for quantized search: the approximate pass keeps
/// `max(k * SQ8_RERANK_FACTOR, SQ8_RERANK_MIN)` candidates per shard, the
/// merged top candidates are re-scored against the f32 rows.
pub const SQ8_RERANK_FACTOR: usize = 4;
pub const SQ8_RERANK_MIN: usize = 32;

/// Subset (IVF probe) scans below this many resolved rows stay on the
/// calling thread — fan-out overhead would dominate.
pub const PARALLEL_SUBSET_MIN: usize = 2048;

/// Storage mode for segment rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Quantization {
    /// Exact f32 rows (the pre-existing behavior).
    None,
    /// u8 scalar quantization with exact f32 re-rank.
    Sq8,
}

impl Quantization {
    pub fn parse(s: &str) -> Option<Quantization> {
        Some(match s.to_ascii_lowercase().as_str() {
            "none" | "f32" | "flat" => Quantization::None,
            "sq8" => Quantization::Sq8,
            _ => return None,
        })
    }
}

/// Construction-time knobs shared by both index families (the `[index]`
/// config section).
#[derive(Clone, Copy, Debug)]
pub struct IndexOpts {
    pub quantization: Quantization,
    pub segment_rows: usize,
    /// Rewrite a segment once this fraction of its rows is dead.
    /// `<= 0` disables compaction.
    pub compact_tombstone_frac: f32,
}

impl Default for IndexOpts {
    fn default() -> Self {
        IndexOpts {
            quantization: Quantization::None,
            segment_rows: DEFAULT_SEGMENT_ROWS,
            compact_tombstone_frac: 0.3,
        }
    }
}

/// Per-dimension affine u8 quantization: `value ≈ min[d] + code * scale[d]`.
/// Trained once (first sealed segment) and frozen so codes stay comparable
/// across segments and across restarts; persisted in snapshot format v2.
#[derive(Clone, Debug, PartialEq)]
pub struct Sq8Params {
    pub min: Vec<f32>,
    pub scale: Vec<f32>,
}

impl Sq8Params {
    /// Train from `data` (row-major, `data.len() % dim == 0`).
    pub fn train(dim: usize, data: &[f32]) -> Sq8Params {
        assert!(dim > 0 && !data.is_empty() && data.len() % dim == 0);
        let mut min = vec![f32::INFINITY; dim];
        let mut max = vec![f32::NEG_INFINITY; dim];
        for row in data.chunks_exact(dim) {
            for (d, &x) in row.iter().enumerate() {
                if x < min[d] {
                    min[d] = x;
                }
                if x > max[d] {
                    max[d] = x;
                }
            }
        }
        let scale = min
            .iter()
            .zip(&max)
            .map(|(lo, hi)| ((hi - lo) / 255.0).max(1e-9))
            .collect();
        Sq8Params { min, scale }
    }

    pub fn dim(&self) -> usize {
        self.min.len()
    }

    #[inline]
    pub fn encode_into(&self, v: &[f32], out: &mut Vec<u8>) {
        debug_assert_eq!(v.len(), self.dim());
        out.reserve(v.len());
        for d in 0..v.len() {
            let q = (v[d] - self.min[d]) / self.scale[d];
            out.push(q.round().clamp(0.0, 255.0) as u8);
        }
    }

    /// Precompute the per-query scoring tables so the inner loop is a pure
    /// `u8 × f32` dot: `score = offset + Σ code[d] * qs[d]` where
    /// `offset = Σ min[d] * q[d]` and `qs[d] = scale[d] * q[d]`.
    pub fn query(&self, q: &[f32]) -> Sq8Query {
        debug_assert_eq!(q.len(), self.dim());
        let offset = dot_f32(&self.min, q);
        let qs = self.scale.iter().zip(q).map(|(s, x)| s * x).collect();
        Sq8Query { offset, qs }
    }
}

/// Per-query precomputation for asymmetric SQ8 scoring.
#[derive(Clone, Debug)]
pub struct Sq8Query {
    pub offset: f32,
    pub qs: Vec<f32>,
}

impl Sq8Query {
    #[inline]
    pub fn score(&self, codes: &[u8]) -> f32 {
        self.offset + dot_u8_f32(codes, &self.qs)
    }
}

/// Vectorization-friendly dot product: `chunks_exact(8)` gives the compiler
/// bounds-check-free fixed-width blocks that auto-vectorize to f32x8; eight
/// independent accumulators hide FMA latency. (Moved here from
/// `cache::flat` when storage was segmented; see EXPERIMENTS.md §Perf.)
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for k in 0..8 {
            acc[k] += xa[k] * xb[k];
        }
    }
    let mut tail = 0.0f32;
    for (xa, xb) in ra.iter().zip(rb) {
        tail += xa * xb;
    }
    acc.iter().sum::<f32>() + tail
}

/// The SQ8 scan kernel: u8 codes against the precomputed f32 table. Same
/// 8-wide shape as `dot_f32`; the u8→f32 convert fuses into the FMA.
#[inline]
pub fn dot_u8_f32(codes: &[u8], qs: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    let ca = codes.chunks_exact(8);
    let cb = qs.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for k in 0..8 {
            acc[k] += xa[k] as f32 * xb[k];
        }
    }
    let mut tail = 0.0f32;
    for (xa, xb) in ra.iter().zip(rb) {
        tail += *xa as f32 * xb;
    }
    acc.iter().sum::<f32>() + tail
}

/// Deterministic top-k merge: `(score desc, id asc)`, truncated to `k`.
/// Every search path funnels through this so shard count and physical
/// layout never change the result set.
pub fn merge_hits(mut hits: Vec<SearchHit>, k: usize) -> Vec<SearchHit> {
    hits.sort_by(|a, b| {
        b.score
            .partial_cmp(&a.score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    hits.truncate(k.max(1));
    hits
}

// ---------------------------------------------------------------------------
// Segment
// ---------------------------------------------------------------------------

/// One fixed-capacity block of rows. Sealed segments are immutable behind an
/// `Arc` except for tombstone marks and compaction, both of which happen
/// under `&mut self` of the store (no scan in flight → `Arc::get_mut`).
#[derive(Debug)]
pub struct Segment {
    dim: usize,
    /// Row-major exact vectors. Kept in every mode: the SQ8 scan never
    /// touches them (that is the bandwidth win), but re-rank, compaction,
    /// and k-means training read them.
    rows: Vec<f32>,
    /// SQ8 codes, row-major; empty until quantization params exist.
    codes: Vec<u8>,
    /// Stable id of each row.
    ids: Vec<usize>,
    live: Vec<bool>,
    dead: usize,
}

impl Segment {
    fn new(dim: usize) -> Segment {
        Segment { dim, rows: Vec::new(), codes: Vec::new(), ids: Vec::new(), live: Vec::new(), dead: 0 }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.rows[r * self.dim..(r + 1) * self.dim]
    }

    #[inline]
    fn code_row(&self, r: usize) -> &[u8] {
        &self.codes[r * self.dim..(r + 1) * self.dim]
    }

    fn push(&mut self, id: usize, v: &[f32], params: Option<&Sq8Params>) -> usize {
        let r = self.ids.len();
        self.rows.extend_from_slice(v);
        if let Some(p) = params {
            p.encode_into(v, &mut self.codes);
        }
        self.ids.push(id);
        self.live.push(true);
        r
    }

    fn kill(&mut self, r: usize) {
        if self.live[r] {
            self.live[r] = false;
            self.dead += 1;
        }
    }

    fn dead_frac(&self) -> f32 {
        if self.ids.is_empty() {
            0.0
        } else {
            self.dead as f32 / self.ids.len() as f32
        }
    }

    /// (Re-)encode every row — used when params arrive after rows did
    /// (training happens at the first seal).
    fn ensure_codes(&mut self, params: &Sq8Params) {
        if self.codes.len() == self.ids.len() * self.dim {
            return;
        }
        self.codes.clear();
        for r in 0..self.ids.len() {
            let row = &self.rows[r * self.dim..(r + 1) * self.dim];
            params.encode_into(row, &mut self.codes);
        }
    }

    /// Drop dead rows, reclaiming their memory. Stable ids are unchanged;
    /// the caller remaps id → row through `ids`.
    fn rewrite(&mut self, params: Option<&Sq8Params>) {
        let n_live = self.ids.len() - self.dead;
        let mut rows = Vec::with_capacity(n_live * self.dim);
        let mut codes = Vec::with_capacity(if params.is_some() { n_live * self.dim } else { 0 });
        let mut ids = Vec::with_capacity(n_live);
        for r in 0..self.ids.len() {
            if !self.live[r] {
                continue;
            }
            let row = &self.rows[r * self.dim..(r + 1) * self.dim];
            rows.extend_from_slice(row);
            if let Some(p) = params {
                p.encode_into(row, &mut codes);
            }
            ids.push(self.ids[r]);
        }
        self.rows = rows;
        self.codes = codes;
        self.live = vec![true; ids.len()];
        self.ids = ids;
        self.dead = 0;
    }

    /// Exact scan into a bounded top-k.
    pub fn scan_f32(&self, q: &[f32], top: &mut TopK) {
        for r in 0..self.ids.len() {
            if self.live[r] {
                top.push(SearchHit { id: self.ids[r], score: dot_f32(self.row(r), q) });
            }
        }
    }

    /// Approximate scan over u8 codes into a bounded top-k.
    pub fn scan_sq8(&self, sq: &Sq8Query, top: &mut TopK) {
        for r in 0..self.ids.len() {
            if self.live[r] {
                top.push(SearchHit { id: self.ids[r], score: sq.score(self.code_row(r)) });
            }
        }
    }

    /// Score one row: u8 codes when this segment has them (sealed,
    /// quantized), exact f32 otherwise (the growing active segment).
    #[inline]
    fn score_row(&self, r: usize, q: &[f32], sq: Option<&Sq8Query>) -> f32 {
        match sq {
            Some(sq) if self.codes.len() == self.ids.len() * self.dim => {
                sq.score(self.code_row(r))
            }
            _ => dot_f32(self.row(r), q),
        }
    }
}

// ---------------------------------------------------------------------------
// SegmentedStore
// ---------------------------------------------------------------------------

/// id → physical location. `seg == TOMBSTONE_SEG` marks a removed id.
#[derive(Clone, Copy, Debug)]
struct Loc {
    seg: u32,
    row: u32,
}

const TOMBSTONE_SEG: u32 = u32::MAX;

pub struct SegmentedStore {
    dim: usize,
    opts: IndexOpts,
    params: Option<Arc<Sq8Params>>,
    /// Immutable (post-seal) segments; the scan fans out over these.
    sealed: Vec<Arc<Segment>>,
    /// The growing tail segment (index `sealed.len()`), always scanned
    /// exactly (f32) on the calling thread.
    active: Segment,
    /// Stable-id indirection: compaction rewrites segments and remaps rows
    /// here; ids handed out by `insert` never change.
    locs: Vec<Loc>,
    live: usize,
    pool: Option<Arc<ThreadPool>>,
    shards: usize,
}

impl SegmentedStore {
    pub fn new(dim: usize, opts: IndexOpts) -> SegmentedStore {
        assert!(dim > 0);
        assert!(opts.segment_rows > 0);
        SegmentedStore {
            dim,
            opts,
            params: None,
            sealed: Vec::new(),
            active: Segment::new(dim),
            locs: Vec::new(),
            live: 0,
            pool: None,
            shards: 1,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Total id slots (live + tombstoned) — ids are slot positions.
    pub fn len(&self) -> usize {
        self.locs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.locs.is_empty()
    }

    /// Live rows, maintained incrementally (O(1); the old IVF train check
    /// recounted tombstones with a full scan on every insert).
    pub fn live_len(&self) -> usize {
        self.live
    }

    pub fn is_live(&self, id: usize) -> bool {
        self.locs.get(id).is_some_and(|l| l.seg != TOMBSTONE_SEG)
    }

    pub fn quantization(&self) -> Quantization {
        self.opts.quantization
    }

    /// Trained quantization params, if any (persisted in snapshots).
    pub fn quant_params(&self) -> Option<Sq8Params> {
        self.params.as_ref().map(|p| (**p).clone())
    }

    /// Install previously-trained params (persistence recovery). Must run
    /// before rows arrive so codes are identical to the pre-restart run.
    /// Ignored when this store is not quantized: a snapshot written under
    /// SQ8 but reopened with `quantization = "none"` must not keep encoding
    /// (and re-persisting) codes nothing will ever read.
    pub fn set_quant_params(&mut self, p: Sq8Params) {
        if self.opts.quantization != Quantization::Sq8 {
            return;
        }
        assert_eq!(p.dim(), self.dim, "quant params dim mismatch");
        assert!(self.locs.is_empty(), "set_quant_params on a non-empty store");
        self.params = Some(Arc::new(p));
    }

    /// Attach the shared worker pool; searches fan sealed segments out over
    /// `shards` jobs. `shards <= 1` keeps the scan on the calling thread.
    pub fn set_pool(&mut self, pool: Arc<ThreadPool>, shards: usize) {
        self.shards = shards.max(1);
        self.pool = if self.shards > 1 { Some(pool) } else { None };
    }

    pub fn segment_count(&self) -> usize {
        self.sealed.len() + 1
    }

    /// Exact f32 row of a live id.
    pub fn row(&self, id: usize) -> Option<&[f32]> {
        let loc = self.locs.get(id)?;
        if loc.seg == TOMBSTONE_SEG {
            return None;
        }
        Some(self.segment(loc.seg as usize).row(loc.row as usize))
    }

    fn segment(&self, idx: usize) -> &Segment {
        if idx == self.sealed.len() {
            &self.active
        } else {
            &self.sealed[idx]
        }
    }

    pub fn insert(&mut self, v: &[f32]) -> usize {
        assert_eq!(v.len(), self.dim, "dimension mismatch");
        if self.active.len() == self.opts.segment_rows {
            self.seal_active();
        }
        let id = self.locs.len();
        let row = self.active.push(id, v, self.params.as_deref());
        self.locs.push(Loc { seg: self.sealed.len() as u32, row: row as u32 });
        self.live += 1;
        id
    }

    /// Allocate a stable id with no physical row (persistence restore of a
    /// tombstoned slot — the old path inserted a zero placeholder row that
    /// was scanned forever).
    pub fn insert_tombstone(&mut self) -> usize {
        let id = self.locs.len();
        self.locs.push(Loc { seg: TOMBSTONE_SEG, row: 0 });
        id
    }

    fn seal_active(&mut self) {
        // First seal trains SQ8 (unless params were imported): the first
        // `segment_rows` inserts are the training sample. Deterministic in
        // insertion order, so WAL replay retrains identically.
        if self.opts.quantization == Quantization::Sq8 && self.params.is_none() {
            self.params = Some(Arc::new(Sq8Params::train(self.dim, &self.active.rows)));
        }
        let mut seg = std::mem::replace(&mut self.active, Segment::new(self.dim));
        if let Some(p) = self.params.clone() {
            seg.ensure_codes(&p);
        }
        self.sealed.push(Arc::new(seg));
    }

    pub fn remove(&mut self, id: usize) {
        let Some(&loc) = self.locs.get(id) else { return };
        if loc.seg == TOMBSTONE_SEG {
            return;
        }
        self.locs[id] = Loc { seg: TOMBSTONE_SEG, row: 0 };
        self.live -= 1;
        let seg_idx = loc.seg as usize;
        if seg_idx == self.sealed.len() {
            self.active.kill(loc.row as usize);
            if self.wants_compaction(self.active.dead_frac(), self.active.dead) {
                self.compact_active();
            }
        } else {
            let seg = Arc::get_mut(&mut self.sealed[seg_idx])
                .expect("segment aliased during remove");
            seg.kill(loc.row as usize);
            let (frac, dead) = (seg.dead_frac(), seg.dead);
            if self.wants_compaction(frac, dead) {
                self.compact_segment(seg_idx);
            }
        }
    }

    fn wants_compaction(&self, dead_frac: f32, dead: usize) -> bool {
        self.opts.compact_tombstone_frac > 0.0
            && dead > 0
            && dead_frac >= self.opts.compact_tombstone_frac
    }

    /// Rewrite one sealed segment without its dead rows and remap the
    /// surviving ids. Stable ids are unchanged.
    fn compact_segment(&mut self, seg_idx: usize) {
        let params = self.params.clone();
        {
            let seg = Arc::get_mut(&mut self.sealed[seg_idx])
                .expect("segment aliased during compaction");
            seg.rewrite(params.as_deref());
        }
        let seg = &self.sealed[seg_idx];
        for (row, &id) in seg.ids.iter().enumerate() {
            self.locs[id] = Loc { seg: seg_idx as u32, row: row as u32 };
        }
    }

    fn compact_active(&mut self) {
        let params = self.params.clone();
        self.active.rewrite(params.as_deref());
        let seg_idx = self.sealed.len() as u32;
        for row in 0..self.active.ids.len() {
            let id = self.active.ids[row];
            self.locs[id] = Loc { seg: seg_idx, row: row as u32 };
        }
    }

    // -- search ------------------------------------------------------------

    pub fn search(&self, q: &[f32], k: usize) -> Vec<SearchHit> {
        assert_eq!(q.len(), self.dim, "dimension mismatch");
        let k = k.max(1);
        match (self.opts.quantization, self.params.clone()) {
            (Quantization::Sq8, Some(p)) => self.search_sq8(q, k, &p),
            // SQ8 before training (nothing sealed yet) is an exact scan.
            _ => self.search_f32(q, k),
        }
    }

    fn shard_groups(&self) -> Vec<Vec<Arc<Segment>>> {
        let n = self.shards.min(self.sealed.len()).max(1);
        let mut groups: Vec<Vec<Arc<Segment>>> = vec![Vec::new(); n];
        for (i, seg) in self.sealed.iter().enumerate() {
            groups[i % n].push(Arc::clone(seg));
        }
        groups
    }

    /// Fan the sealed segments out over the pool; each job pushes into its
    /// own `TopK(cap)` and sends the result back. Falls back to an inline
    /// scan without a pool. Returns the concatenated per-shard top lists
    /// (callers merge deterministically).
    fn scan_sealed(&self, cap: usize, q: &[f32], sq: Option<&Sq8Query>) -> Vec<SearchHit> {
        match &self.pool {
            Some(pool) if self.sealed.len() > 1 => {
                let q: Arc<Vec<f32>> = Arc::new(q.to_vec());
                let sq: Option<Arc<Sq8Query>> = sq.map(|s| Arc::new(s.clone()));
                let (tx, rx) = mpsc::channel::<Vec<SearchHit>>();
                let mut jobs = 0usize;
                for group in self.shard_groups() {
                    if group.is_empty() {
                        continue;
                    }
                    let q = Arc::clone(&q);
                    let sq = sq.clone();
                    let tx = tx.clone();
                    jobs += 1;
                    pool.execute(move || {
                        let mut top = TopK::new(cap);
                        for seg in &group {
                            match &sq {
                                Some(sq) => seg.scan_sq8(sq, &mut top),
                                None => seg.scan_f32(&q, &mut top),
                            }
                        }
                        // Release the segment refs BEFORE the result becomes
                        // observable: the caller may mutate (remove/compact)
                        // via `Arc::get_mut` as soon as every shard reports.
                        drop(group);
                        let _ = tx.send(top.into_vec());
                    });
                }
                drop(tx);
                let mut hits = Vec::with_capacity(jobs * cap);
                for _ in 0..jobs {
                    hits.extend(rx.recv().expect("shard scan worker panicked"));
                }
                hits
            }
            _ => {
                let mut top = TopK::new(cap);
                for seg in &self.sealed {
                    match sq {
                        Some(sq) => seg.scan_sq8(sq, &mut top),
                        None => seg.scan_f32(q, &mut top),
                    }
                }
                top.into_vec()
            }
        }
    }

    fn search_f32(&self, q: &[f32], k: usize) -> Vec<SearchHit> {
        let mut hits = self.scan_sealed(k, q, None);
        let mut top = TopK::new(k);
        self.active.scan_f32(q, &mut top);
        hits.extend(top.into_vec());
        merge_hits(hits, k)
    }

    fn search_sq8(&self, q: &[f32], k: usize, params: &Sq8Params) -> Vec<SearchHit> {
        let cand_k = (k * SQ8_RERANK_FACTOR).max(SQ8_RERANK_MIN);
        let sq = params.query(q);
        // Approximate candidates from the sealed segments' codes…
        let cands = merge_hits(self.scan_sealed(cand_k, q, Some(&sq)), cand_k);
        // …re-ranked exactly against the f32 rows.
        let mut hits: Vec<SearchHit> = cands
            .into_iter()
            .map(|h| SearchHit {
                id: h.id,
                score: dot_f32(self.row(h.id).expect("candidate row vanished"), q),
            })
            .collect();
        // The active (growing) segment is always scored exactly.
        let mut top = TopK::new(k);
        self.active.scan_f32(q, &mut top);
        hits.extend(top.into_vec());
        merge_hits(hits, k)
    }

    /// Search restricted to `ids` (the IVF probe path). Dead ids are
    /// skipped. Quantized stores score codes first and re-rank the top
    /// candidates exactly, mirroring `search`; probes resolving to
    /// `PARALLEL_SUBSET_MIN`+ rows fan out across the scan shards
    /// (grouped by segment so each job touches contiguous-ish memory).
    pub fn search_subset<I>(&self, q: &[f32], k: usize, ids: I) -> Vec<SearchHit>
    where
        I: IntoIterator<Item = usize>,
    {
        assert_eq!(q.len(), self.dim, "dimension mismatch");
        let k = k.max(1);
        let quant = matches!(self.opts.quantization, Quantization::Sq8) && self.params.is_some();
        let sq = if quant {
            Some(self.params.as_ref().expect("checked above").query(q))
        } else {
            None
        };
        // Candidate budget: quantized scans over-fetch for the exact re-rank.
        let cap = if quant { (k * SQ8_RERANK_FACTOR).max(SQ8_RERANK_MIN) } else { k };

        // Resolve live ids to (row, id) pairs grouped by segment; the last
        // group is the active segment (scored exactly, on this thread).
        let mut by_seg: Vec<Vec<(u32, usize)>> = vec![Vec::new(); self.sealed.len() + 1];
        let mut sealed_rows = 0usize;
        for id in ids {
            if let Some(&loc) = self.locs.get(id) {
                if loc.seg == TOMBSTONE_SEG {
                    continue;
                }
                by_seg[loc.seg as usize].push((loc.row, id));
                if (loc.seg as usize) < self.sealed.len() {
                    sealed_rows += 1;
                }
            }
        }
        let active_rows = by_seg.pop().expect("active group");

        let mut hits: Vec<SearchHit>;
        match &self.pool {
            Some(pool) if sealed_rows >= PARALLEL_SUBSET_MIN => {
                let q_arc: Arc<Vec<f32>> = Arc::new(q.to_vec());
                let sq_arc: Option<Arc<Sq8Query>> = sq.clone().map(Arc::new);
                let mut groups: Vec<Vec<(Arc<Segment>, Vec<(u32, usize)>)>> =
                    vec![Vec::new(); self.shards];
                for (seg_idx, rows) in by_seg.into_iter().enumerate() {
                    if !rows.is_empty() {
                        groups[seg_idx % self.shards]
                            .push((Arc::clone(&self.sealed[seg_idx]), rows));
                    }
                }
                let (tx, rx) = mpsc::channel::<Vec<SearchHit>>();
                let mut jobs = 0usize;
                for group in groups {
                    if group.is_empty() {
                        continue;
                    }
                    let q = Arc::clone(&q_arc);
                    let sq = sq_arc.clone();
                    let tx = tx.clone();
                    jobs += 1;
                    pool.execute(move || {
                        let mut top = TopK::new(cap);
                        for (seg, rows) in &group {
                            for &(row, id) in rows {
                                let score = seg.score_row(row as usize, &q, sq.as_deref());
                                top.push(SearchHit { id, score });
                            }
                        }
                        // See scan_sealed: segment refs must die before the
                        // result is observable (Arc::get_mut on remove).
                        drop(group);
                        let _ = tx.send(top.into_vec());
                    });
                }
                drop(tx);
                hits = Vec::with_capacity(jobs * cap);
                for _ in 0..jobs {
                    hits.extend(rx.recv().expect("subset scan worker panicked"));
                }
            }
            _ => {
                let mut top = TopK::new(cap);
                for (seg_idx, rows) in by_seg.iter().enumerate() {
                    let seg = &self.sealed[seg_idx];
                    for &(row, id) in rows {
                        let score = seg.score_row(row as usize, q, sq.as_ref());
                        top.push(SearchHit { id, score });
                    }
                }
                hits = top.into_vec();
            }
        }
        if quant {
            // Exact re-rank of the merged approximate candidates.
            hits = merge_hits(hits, cap)
                .into_iter()
                .map(|h| SearchHit {
                    id: h.id,
                    score: dot_f32(self.row(h.id).expect("candidate row vanished"), q),
                })
                .collect();
        }
        // Active-segment rows are always scored exactly.
        let mut top = TopK::new(k);
        for &(row, id) in &active_rows {
            top.push(SearchHit { id, score: dot_f32(self.active.row(row as usize), q) });
        }
        hits.extend(top.into_vec());
        merge_hits(hits, k)
    }

    /// All live stable ids in ascending order (IVF training input).
    pub fn live_ids(&self) -> Vec<usize> {
        (0..self.locs.len()).filter(|&id| self.locs[id].seg != TOMBSTONE_SEG).collect()
    }

    /// Bytes of row payload currently held (f32 + codes), for diagnostics
    /// and the compaction tests.
    pub fn payload_bytes(&self) -> usize {
        let seg_bytes =
            |s: &Segment| s.rows.len() * std::mem::size_of::<f32>() + s.codes.len();
        self.sealed.iter().map(seg_bytes).sum::<usize>() + seg_bytes(&self.active)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{normalize, Rng};

    fn rand_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        normalize(&mut v);
        v
    }

    fn clustered(rng: &mut Rng, n: usize, dim: usize, clusters: usize) -> Vec<Vec<f32>> {
        let centers: Vec<Vec<f32>> = (0..clusters).map(|_| rand_unit(rng, dim)).collect();
        (0..n)
            .map(|i| {
                let mut v: Vec<f32> = centers[i % clusters]
                    .iter()
                    .map(|x| x + 0.25 * rng.normal() as f32)
                    .collect();
                normalize(&mut v);
                v
            })
            .collect()
    }

    fn opts(quant: Quantization, segment_rows: usize) -> IndexOpts {
        IndexOpts { quantization: quant, segment_rows, compact_tombstone_frac: 0.3 }
    }

    #[test]
    fn dot_u8_matches_dequantized() {
        let mut rng = Rng::new(1);
        let dim = 48;
        let data: Vec<f32> = (0..dim * 8).map(|_| rng.normal() as f32).collect();
        let p = Sq8Params::train(dim, &data);
        let q = rand_unit(&mut rng, dim);
        let sq = p.query(&q);
        for row in data.chunks_exact(dim) {
            let mut codes = Vec::new();
            p.encode_into(row, &mut codes);
            // naive: dequantize then dot
            let deq: Vec<f32> = codes
                .iter()
                .enumerate()
                .map(|(d, &c)| p.min[d] + c as f32 * p.scale[d])
                .collect();
            let want = dot_f32(&deq, &q);
            let got = sq.score(&codes);
            assert!((want - got).abs() < 1e-3, "{want} vs {got}");
        }
    }

    #[test]
    fn insert_search_across_segment_boundary() {
        let mut store = SegmentedStore::new(16, opts(Quantization::None, 8));
        let mut rng = Rng::new(2);
        let vs: Vec<Vec<f32>> = (0..37).map(|_| rand_unit(&mut rng, 16)).collect();
        for v in &vs {
            store.insert(v);
        }
        assert!(store.segment_count() > 2);
        for (i, v) in vs.iter().enumerate() {
            let hits = store.search(v, 1);
            assert_eq!(hits[0].id, i);
            assert!((hits[0].score - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn sharded_equals_single_threaded_exactly() {
        let mut rng = Rng::new(3);
        let vs = clustered(&mut rng, 500, 24, 6);
        let queries: Vec<Vec<f32>> = (0..24).map(|_| rand_unit(&mut rng, 24)).collect();
        for quant in [Quantization::None, Quantization::Sq8] {
            let mut single = SegmentedStore::new(24, opts(quant, 64));
            let mut sharded = SegmentedStore::new(24, opts(quant, 64));
            sharded.set_pool(Arc::new(ThreadPool::new(4)), 4);
            for v in &vs {
                single.insert(v);
                sharded.insert(v);
            }
            // a few tombstones so the dead-skip path is covered
            for id in [3usize, 77, 140, 301] {
                single.remove(id);
                sharded.remove(id);
            }
            for q in &queries {
                let a = single.search(q, 7);
                let b = sharded.search(q, 7);
                assert_eq!(a, b, "shard count changed results");
            }
        }
    }

    #[test]
    fn sq8_recall_vs_exact_on_clustered_data() {
        let dim = 64;
        let mut rng = Rng::new(4);
        let vs = clustered(&mut rng, 3000, dim, 12);
        let mut exact = SegmentedStore::new(dim, opts(Quantization::None, 256));
        let mut sq8 = SegmentedStore::new(dim, opts(Quantization::Sq8, 256));
        for v in &vs {
            exact.insert(v);
            sq8.insert(v);
        }
        let mut agree = 0;
        let n_q = 200;
        for i in 0..n_q {
            let q = &vs[(i * 13) % vs.len()];
            let a = exact.search(q, 1)[0];
            let b = sq8.search(q, 1)[0];
            if a.id == b.id {
                agree += 1;
            }
        }
        assert!(agree as f64 >= n_q as f64 * 0.95, "recall@1 = {agree}/{n_q}");
    }

    #[test]
    fn compaction_reclaims_memory_and_keeps_ids() {
        let dim = 8;
        let mut store = SegmentedStore::new(dim, opts(Quantization::None, 32));
        let mut rng = Rng::new(5);
        let vs: Vec<Vec<f32>> = (0..128).map(|_| rand_unit(&mut rng, dim)).collect();
        for v in &vs {
            store.insert(v);
        }
        let before = store.payload_bytes();
        // kill 40% of every sealed segment → each crosses the 0.3 threshold
        let mut removed = Vec::new();
        for id in (0..128).step_by(5) {
            store.remove(id);
            removed.push(id);
        }
        for id in (1..128).step_by(5) {
            store.remove(id);
            removed.push(id);
        }
        assert!(store.payload_bytes() < before, "compaction reclaimed nothing");
        assert_eq!(store.live_len(), 128 - removed.len());
        // survivors keep their stable ids and exact rows
        for (id, v) in vs.iter().enumerate() {
            if removed.contains(&id) {
                assert!(store.row(id).is_none());
                continue;
            }
            assert_eq!(store.row(id).unwrap(), v.as_slice(), "row moved for id {id}");
            assert_eq!(store.search(v, 1)[0].id, id);
        }
        // removed ids never match again
        for &id in &removed {
            let hits = store.search(&vs[id], 10);
            assert!(hits.iter().all(|h| h.id != id));
        }
    }

    #[test]
    fn tombstone_slots_have_no_rows() {
        let mut store = SegmentedStore::new(4, IndexOpts::default());
        let a = store.insert(&[1.0, 0.0, 0.0, 0.0]);
        let t = store.insert_tombstone();
        let b = store.insert(&[0.0, 1.0, 0.0, 0.0]);
        assert_eq!((a, t, b), (0, 1, 2));
        assert_eq!(store.len(), 3);
        assert_eq!(store.live_len(), 2);
        assert!(store.row(t).is_none());
        let hits = store.search(&[1.0, 0.0, 0.0, 0.0], 3);
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].id, 0);
    }

    #[test]
    fn quant_params_roundtrip_reproduces_scores() {
        let dim = 16;
        let mut rng = Rng::new(6);
        let vs = clustered(&mut rng, 200, dim, 4);
        let mut a = SegmentedStore::new(dim, opts(Quantization::Sq8, 32));
        for v in &vs {
            a.insert(v);
        }
        let params = a.quant_params().expect("trained after first seal");
        // rebuild with imported params (the snapshot-restore path)
        let mut b = SegmentedStore::new(dim, opts(Quantization::Sq8, 32));
        b.set_quant_params(params);
        for v in &vs {
            b.insert(v);
        }
        let q = rand_unit(&mut rng, dim);
        assert_eq!(a.search(&q, 5), b.search(&q, 5));
    }

    #[test]
    fn unquantized_store_ignores_imported_params() {
        // Migration: snapshot written under SQ8, reopened with
        // quantization = "none" — params are dropped, no codes are built,
        // and the next snapshot persists quant = None.
        let dim = 8;
        let mut rng = Rng::new(8);
        let mut store = SegmentedStore::new(dim, opts(Quantization::None, 4));
        store.set_quant_params(Sq8Params {
            min: vec![-1.0; dim],
            scale: vec![0.01; dim],
        });
        assert!(store.quant_params().is_none());
        for _ in 0..12 {
            store.insert(&rand_unit(&mut rng, dim));
        }
        // payload is pure f32: no code bytes accrued
        assert_eq!(store.payload_bytes(), 12 * dim * 4);
    }

    #[test]
    fn search_subset_filters_and_matches_full_search() {
        let dim = 12;
        let mut rng = Rng::new(7);
        let vs: Vec<Vec<f32>> = (0..60).map(|_| rand_unit(&mut rng, dim)).collect();
        let mut store = SegmentedStore::new(dim, opts(Quantization::None, 16));
        for v in &vs {
            store.insert(v);
        }
        store.remove(10);
        let q = rand_unit(&mut rng, dim);
        let full = store.search(&q, 5);
        let subset = store.search_subset(&q, 5, 0..60);
        assert_eq!(full, subset);
        assert!(store.search_subset(&q, 5, [10usize; 1]).is_empty());
    }
}
