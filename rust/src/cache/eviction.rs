//! Cache eviction policies.
//!
//! The paper's implementation is append-only (§3.1) and names eviction as
//! future work (§6.2); we implement the standard family so the ablation
//! bench (`vector_index`) can compare them under a bounded cache.

use std::collections::HashMap;

/// Which entry to evict when the cache is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Paper default: never evict.
    None,
    /// Least-recently-used (hit or insert refreshes recency).
    Lru,
    /// Least-frequently-used (hit count; ties broken by recency).
    Lfu,
    /// Time-to-live: evict entries older than `ttl_ticks` regardless of use.
    Ttl,
    /// First-in-first-out.
    Fifo,
}

impl EvictionPolicy {
    pub fn parse(s: &str) -> Option<EvictionPolicy> {
        Some(match s.to_ascii_lowercase().as_str() {
            "none" => EvictionPolicy::None,
            "lru" => EvictionPolicy::Lru,
            "lfu" => EvictionPolicy::Lfu,
            "ttl" => EvictionPolicy::Ttl,
            "fifo" => EvictionPolicy::Fifo,
            _ => return None,
        })
    }
}

/// Bookkeeping for a bounded cache. The store calls `on_insert` / `on_hit`
/// with a logical clock tick; `victim()` returns the id to evict.
#[derive(Debug)]
pub struct EvictionStrategy {
    pub policy: EvictionPolicy,
    pub capacity: usize,
    pub ttl_ticks: u64,
    inserted_at: HashMap<usize, u64>,
    last_used: HashMap<usize, u64>,
    use_count: HashMap<usize, u64>,
    live: Vec<usize>,
}

impl EvictionStrategy {
    pub fn new(policy: EvictionPolicy, capacity: usize) -> Self {
        EvictionStrategy {
            policy,
            capacity: capacity.max(1),
            ttl_ticks: u64::MAX,
            inserted_at: HashMap::new(),
            last_used: HashMap::new(),
            use_count: HashMap::new(),
            live: Vec::new(),
        }
    }

    pub fn with_ttl(mut self, ttl_ticks: u64) -> Self {
        self.ttl_ticks = ttl_ticks;
        self
    }

    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    pub fn on_insert(&mut self, id: usize, tick: u64) {
        self.inserted_at.insert(id, tick);
        self.last_used.insert(id, tick);
        self.use_count.insert(id, 0);
        self.live.push(id);
    }

    pub fn on_hit(&mut self, id: usize, tick: u64) {
        self.last_used.insert(id, tick);
        *self.use_count.entry(id).or_insert(0) += 1;
    }

    /// True when an insert would exceed capacity (policy != None).
    pub fn needs_eviction(&self) -> bool {
        self.policy != EvictionPolicy::None && self.live.len() >= self.capacity
    }

    /// Entries past TTL at `tick` (only for Ttl policy).
    pub fn expired(&self, tick: u64) -> Vec<usize> {
        if self.policy != EvictionPolicy::Ttl {
            return Vec::new();
        }
        self.live
            .iter()
            .copied()
            .filter(|id| {
                tick.saturating_sub(*self.inserted_at.get(id).unwrap_or(&0))
                    > self.ttl_ticks
            })
            .collect()
    }

    /// Pick and forget the victim. Returns None when nothing is evictable.
    pub fn victim(&mut self) -> Option<usize> {
        if self.live.is_empty() {
            return None;
        }
        let idx = match self.policy {
            EvictionPolicy::None => return None,
            EvictionPolicy::Fifo | EvictionPolicy::Ttl => 0, // oldest insert
            EvictionPolicy::Lru => {
                let mut best = 0;
                for (i, id) in self.live.iter().enumerate() {
                    if self.last_used[id] < self.last_used[&self.live[best]] {
                        best = i;
                    }
                }
                best
            }
            EvictionPolicy::Lfu => {
                let mut best = 0;
                for (i, id) in self.live.iter().enumerate() {
                    let (c, t) = (self.use_count[id], self.last_used[id]);
                    let (bc, bt) =
                        (self.use_count[&self.live[best]], self.last_used[&self.live[best]]);
                    if c < bc || (c == bc && t < bt) {
                        best = i;
                    }
                }
                best
            }
        };
        let id = self.live.remove(idx);
        self.inserted_at.remove(&id);
        self.last_used.remove(&id);
        self.use_count.remove(&id);
        Some(id)
    }

    /// Per-id metadata `(inserted_at, last_used, use_count)` for snapshots.
    /// `None` when the id is not live (evicted / never inserted).
    pub fn meta(&self, id: usize) -> Option<(u64, u64, u64)> {
        let inserted = *self.inserted_at.get(&id)?;
        Some((
            inserted,
            self.last_used.get(&id).copied().unwrap_or(inserted),
            self.use_count.get(&id).copied().unwrap_or(0),
        ))
    }

    /// Re-register an id with explicit metadata (persistence recovery).
    /// Ids must be restored in ascending order so FIFO/TTL victim selection
    /// (which takes `live[0]` as oldest) matches the pre-crash ordering.
    pub fn restore(&mut self, id: usize, inserted_at: u64, last_used: u64, use_count: u64) {
        self.inserted_at.insert(id, inserted_at);
        self.last_used.insert(id, last_used);
        self.use_count.insert(id, use_count);
        self.live.push(id);
    }

    pub fn forget(&mut self, id: usize) {
        self.live.retain(|x| *x != id);
        self.inserted_at.remove(&id);
        self.last_used.remove(&id);
        self.use_count.remove(&id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_policies() {
        assert_eq!(EvictionPolicy::parse("LRU"), Some(EvictionPolicy::Lru));
        assert_eq!(EvictionPolicy::parse("nope"), None);
    }

    #[test]
    fn none_never_evicts() {
        let mut e = EvictionStrategy::new(EvictionPolicy::None, 2);
        e.on_insert(0, 0);
        e.on_insert(1, 1);
        e.on_insert(2, 2);
        assert!(!e.needs_eviction());
        assert_eq!(e.victim(), None);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut e = EvictionStrategy::new(EvictionPolicy::Lru, 3);
        e.on_insert(0, 0);
        e.on_insert(1, 1);
        e.on_insert(2, 2);
        e.on_hit(0, 3); // refresh 0; LRU victim becomes 1
        assert!(e.needs_eviction());
        assert_eq!(e.victim(), Some(1));
    }

    #[test]
    fn lfu_evicts_least_used() {
        let mut e = EvictionStrategy::new(EvictionPolicy::Lfu, 3);
        for id in 0..3 {
            e.on_insert(id, id as u64);
        }
        e.on_hit(0, 5);
        e.on_hit(0, 6);
        e.on_hit(2, 7);
        assert_eq!(e.victim(), Some(1)); // never hit
    }

    #[test]
    fn fifo_evicts_oldest() {
        let mut e = EvictionStrategy::new(EvictionPolicy::Fifo, 2);
        e.on_insert(7, 0);
        e.on_insert(8, 1);
        e.on_hit(7, 2); // FIFO ignores recency
        assert_eq!(e.victim(), Some(7));
    }

    #[test]
    fn ttl_expiry() {
        let mut e = EvictionStrategy::new(EvictionPolicy::Ttl, 100).with_ttl(10);
        e.on_insert(0, 0);
        e.on_insert(1, 5);
        assert_eq!(e.expired(20), vec![0, 1]);
        assert_eq!(e.expired(12), vec![0]);
        assert_eq!(e.expired(5), Vec::<usize>::new());
    }

    #[test]
    fn meta_roundtrips_through_restore() {
        let mut e = EvictionStrategy::new(EvictionPolicy::Lru, 4);
        e.on_insert(0, 10);
        e.on_hit(0, 12);
        e.on_hit(0, 15);
        let (ins, last, uses) = e.meta(0).unwrap();
        assert_eq!((ins, last, uses), (10, 15, 2));
        assert_eq!(e.meta(9), None);

        let mut r = EvictionStrategy::new(EvictionPolicy::Lru, 4);
        r.restore(0, ins, last, uses);
        assert_eq!(r.meta(0), Some((10, 15, 2)));
        assert_eq!(r.live_count(), 1);
    }

    #[test]
    fn forget_removes() {
        let mut e = EvictionStrategy::new(EvictionPolicy::Lru, 4);
        e.on_insert(0, 0);
        e.on_insert(1, 1);
        e.forget(0);
        assert_eq!(e.live_count(), 1);
        assert_eq!(e.victim(), Some(1));
    }
}
