//! Semantic cache substrate: the in-process vector database.
//!
//! Stand-in for the paper's Milvus v2.5 deployment (Table 1): stores
//! `(query_text, query_embedding, response_text)` triples, serves cosine
//! top-k via a FLAT (exact scan) or IVF_FLAT (k-means coarse quantizer +
//! nprobe) index, and supports the append-only policy the paper uses plus
//! the eviction policies its §6.2 lists as future work.
//!
//! The `persist` submodule makes the store durable: binary snapshots + an
//! append-only WAL with crash-safe recovery, so the cache — the asset whose
//! value accrues over millions of queries — survives process restarts.

pub mod eviction;
pub mod flat;
pub mod ivf;
pub mod persist;
pub mod store;

pub use eviction::{EvictionPolicy, EvictionStrategy};
pub use flat::FlatIndex;
pub use ivf::IvfFlatIndex;
pub use persist::{PersistConfig, PersistStatus, Persistence, RecoveryReport, WalOp};
pub use store::{CacheEntry, CacheStats, IndexKind, SemanticCache};

/// A scored search result.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SearchHit {
    /// Position of the entry in the store (stable id).
    pub id: usize,
    /// Cosine similarity in [-1, 1] (vectors are L2-normalized on insert).
    pub score: f32,
}

/// Common interface over the index families.
pub trait VectorIndex: Send {
    /// Insert a normalized vector; returns its id (insertion order).
    fn insert(&mut self, v: &[f32]) -> usize;

    /// Top-k by cosine similarity. `k >= 1`. Results sorted descending.
    fn search(&self, q: &[f32], k: usize) -> Vec<SearchHit>;

    /// Number of stored vectors (including tombstoned ones for id stability).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Mark an id as removed (eviction). Removed ids never match again.
    fn remove(&mut self, id: usize);

    fn dim(&self) -> usize;
}

/// Maintain a bounded top-k set of hits (small k: linear insertion beats a
/// heap in practice and allocates once).
#[derive(Debug)]
pub struct TopK {
    k: usize,
    hits: Vec<SearchHit>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        TopK { k: k.max(1), hits: Vec::with_capacity(k.max(1) + 1) }
    }

    #[inline]
    pub fn threshold(&self) -> f32 {
        if self.hits.len() < self.k {
            f32::NEG_INFINITY
        } else {
            self.hits[self.hits.len() - 1].score
        }
    }

    #[inline]
    pub fn push(&mut self, hit: SearchHit) {
        if hit.score <= self.threshold() {
            return;
        }
        let pos = self
            .hits
            .iter()
            .position(|h| h.score < hit.score)
            .unwrap_or(self.hits.len());
        self.hits.insert(pos, hit);
        self.hits.truncate(self.k);
    }

    pub fn into_vec(self) -> Vec<SearchHit> {
        self.hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_keeps_best_sorted() {
        let mut t = TopK::new(3);
        for (i, s) in [0.1, 0.9, 0.5, 0.7, 0.3].iter().enumerate() {
            t.push(SearchHit { id: i, score: *s });
        }
        let v = t.into_vec();
        assert_eq!(v.len(), 3);
        assert_eq!(v[0].id, 1);
        assert_eq!(v[1].id, 3);
        assert_eq!(v[2].id, 2);
    }

    #[test]
    fn topk_k1() {
        let mut t = TopK::new(1);
        t.push(SearchHit { id: 0, score: 0.2 });
        t.push(SearchHit { id: 1, score: 0.8 });
        t.push(SearchHit { id: 2, score: 0.5 });
        let v = t.into_vec();
        assert_eq!(v, vec![SearchHit { id: 1, score: 0.8 }]);
    }
}
