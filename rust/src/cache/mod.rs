//! Semantic cache substrate: the in-process vector database.
//!
//! Stand-in for the paper's Milvus v2.5 deployment (Table 1): stores
//! `(query_text, query_embedding, response_text)` triples, serves cosine
//! top-k via a FLAT (exact scan) or IVF_FLAT (k-means coarse quantizer +
//! nprobe) index, and supports the append-only policy the paper uses plus
//! the eviction policies its §6.2 lists as future work.
//!
//! The `segment` submodule is the shared row-storage substrate under both
//! index families: fixed-size segments scanned in parallel across shards,
//! optional SQ8 scalar quantization (u8 codes + exact re-rank, the Milvus
//! IVF_SQ8 analog), and tombstone compaction behind a stable-id
//! indirection layer (see DESIGN.md "Index formats & hot path").
//!
//! The `persist` submodule makes the store durable: binary snapshots + an
//! append-only WAL with crash-safe recovery, so the cache — the asset whose
//! value accrues over millions of queries — survives process restarts.

pub mod eviction;
pub mod flat;
pub mod ivf;
pub mod persist;
pub mod segment;
pub mod store;

pub use eviction::{EvictionPolicy, EvictionStrategy};
pub use flat::FlatIndex;
pub use ivf::IvfFlatIndex;
pub use persist::{PersistConfig, PersistStatus, Persistence, RecoveryReport, WalOp};
pub use segment::{IndexOpts, Quantization, SegmentedStore, Sq8Params};
pub use store::{query_key, CacheEntry, CacheStats, IndexKind, SemanticCache};

use std::sync::Arc;

use crate::util::ThreadPool;

/// A scored search result.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SearchHit {
    /// Position of the entry in the store (stable id).
    pub id: usize,
    /// Cosine similarity in [-1, 1] (vectors are L2-normalized on insert).
    pub score: f32,
}

/// Common interface over the index families.
pub trait VectorIndex: Send {
    /// Insert a normalized vector; returns its id (insertion order).
    fn insert(&mut self, v: &[f32]) -> usize;

    /// Top-k by cosine similarity. `k >= 1`. Results sorted descending.
    fn search(&self, q: &[f32], k: usize) -> Vec<SearchHit>;

    /// Number of stored vectors (including tombstoned ones for id stability).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Mark an id as removed (eviction). Removed ids never match again.
    /// Segmented indexes reclaim the row's memory once the owning segment's
    /// dead fraction passes `compact_tombstone_frac`.
    fn remove(&mut self, id: usize);

    fn dim(&self) -> usize;

    /// Allocate a stable id with no live row (persistence restore of a
    /// tombstoned slot). The default emulates it for indexes without true
    /// tombstone support: insert a placeholder row and remove it.
    fn insert_tombstone(&mut self) -> usize {
        let placeholder = vec![0.0f32; self.dim()];
        let id = self.insert(&placeholder);
        self.remove(id);
        id
    }

    /// Live (non-tombstoned) vectors. Defaults to `len()` for indexes that
    /// do not track removals separately.
    fn live_len(&self) -> usize {
        self.len()
    }

    /// Attach the shared worker pool for sharded scans. No-op by default.
    fn set_pool(&mut self, _pool: Arc<ThreadPool>, _shards: usize) {}

    /// Trained scalar-quantization params, if this index quantizes
    /// (persisted in snapshot format v2 so codes survive restarts).
    fn quant_params(&self) -> Option<Sq8Params> {
        None
    }

    /// Install recovered quantization params. Must be called on an empty
    /// index. No-op for unquantized indexes.
    fn set_quant_params(&mut self, _p: Sq8Params) {}
}

/// Maintain a bounded top-k set of hits (small k: linear insertion beats a
/// heap in practice and allocates once).
///
/// Totally ordered by `(score desc, id asc)` — ties are broken by id, not
/// by push order, so the retained set is identical no matter how the scan
/// was partitioned. This is what makes the sharded scan's "1 shard ≡ N
/// shards" contract hold even when equal scores straddle the k boundary
/// (exact ties are common under SQ8's coarse u8 scores).
#[derive(Debug)]
pub struct TopK {
    k: usize,
    hits: Vec<SearchHit>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        TopK { k: k.max(1), hits: Vec::with_capacity(k.max(1) + 1) }
    }

    #[inline]
    pub fn threshold(&self) -> f32 {
        if self.hits.len() < self.k {
            f32::NEG_INFINITY
        } else {
            self.hits[self.hits.len() - 1].score
        }
    }

    /// `(score desc, id asc)` ordering: does `a` rank strictly before `b`?
    #[inline]
    fn ranks_before(a: &SearchHit, b: &SearchHit) -> bool {
        a.score > b.score || (a.score == b.score && a.id < b.id)
    }

    #[inline]
    pub fn push(&mut self, hit: SearchHit) {
        if self.hits.len() == self.k && !Self::ranks_before(&hit, &self.hits[self.k - 1]) {
            return;
        }
        let pos = self
            .hits
            .iter()
            .position(|h| Self::ranks_before(&hit, h))
            .unwrap_or(self.hits.len());
        self.hits.insert(pos, hit);
        self.hits.truncate(self.k);
    }

    pub fn into_vec(self) -> Vec<SearchHit> {
        self.hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_keeps_best_sorted() {
        let mut t = TopK::new(3);
        for (i, s) in [0.1, 0.9, 0.5, 0.7, 0.3].iter().enumerate() {
            t.push(SearchHit { id: i, score: *s });
        }
        let v = t.into_vec();
        assert_eq!(v.len(), 3);
        assert_eq!(v[0].id, 1);
        assert_eq!(v[1].id, 3);
        assert_eq!(v[2].id, 2);
    }

    #[test]
    fn topk_ties_kept_by_lowest_id_regardless_of_push_order() {
        // Five equal scores pushed in scrambled order: a TopK(3) must keep
        // ids 0,1,2 — the property the sharded merge relies on.
        for order in [[4usize, 0, 3, 1, 2], [2, 4, 1, 3, 0], [0, 1, 2, 3, 4]] {
            let mut t = TopK::new(3);
            for &id in &order {
                t.push(SearchHit { id, score: 0.5 });
            }
            let ids: Vec<usize> = t.into_vec().iter().map(|h| h.id).collect();
            assert_eq!(ids, vec![0, 1, 2], "push order {order:?}");
        }
    }

    #[test]
    fn topk_k1() {
        let mut t = TopK::new(1);
        t.push(SearchHit { id: 0, score: 0.2 });
        t.push(SearchHit { id: 1, score: 0.8 });
        t.push(SearchHit { id: 2, score: 0.5 });
        let v = t.into_vec();
        assert_eq!(v, vec![SearchHit { id: 1, score: 0.8 }]);
    }
}
