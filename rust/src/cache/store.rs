//! The semantic cache: entries + vector index + exact-match fast path +
//! eviction. This is the paper's "Vector Database" + "Cache Management"
//! boxes in Figure 1.

use std::collections::HashMap;

use super::{EvictionPolicy, EvictionStrategy, FlatIndex, IvfFlatIndex, SearchHit, VectorIndex};

/// One cached interaction: the paper stores exactly this triple.
#[derive(Clone, Debug)]
pub struct CacheEntry {
    pub query_text: String,
    pub response_text: String,
    /// L2-normalized embedding (kept for re-ranking / debugging; the index
    /// holds its own copy in scan-friendly layout).
    pub embedding: Vec<f32>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub inserts: u64,
    pub lookups: u64,
    pub exact_hits: u64,
    pub evictions: u64,
}

/// Index family selector (Table 1 uses IVF_FLAT; FLAT is the exact baseline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexKind {
    Flat,
    IvfFlat { nlist: usize, nprobe: usize },
}

pub struct SemanticCache {
    entries: Vec<Option<CacheEntry>>,
    index: Box<dyn VectorIndex>,
    /// Exact-match fast path: normalized text -> entry id. §6.1 of the paper:
    /// "For exact matches (cosine similarity = 1.0), directly returning
    /// cached responses without tweaking ensures further cost savings".
    exact: HashMap<u64, usize>,
    exact_enabled: bool,
    eviction: EvictionStrategy,
    tick: u64,
    stats: CacheStats,
}

impl SemanticCache {
    pub fn new(dim: usize, kind: IndexKind) -> Self {
        let index: Box<dyn VectorIndex> = match kind {
            IndexKind::Flat => Box::new(FlatIndex::new(dim)),
            IndexKind::IvfFlat { nlist, nprobe } => {
                Box::new(IvfFlatIndex::new(dim, nlist, nprobe))
            }
        };
        SemanticCache {
            entries: Vec::new(),
            index,
            exact: HashMap::new(),
            exact_enabled: true,
            eviction: EvictionStrategy::new(EvictionPolicy::None, usize::MAX),
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn with_eviction(mut self, policy: EvictionPolicy, capacity: usize) -> Self {
        self.eviction = EvictionStrategy::new(policy, capacity);
        self
    }

    pub fn with_exact_match(mut self, enabled: bool) -> Self {
        self.exact_enabled = enabled;
        self
    }

    fn text_key(text: &str) -> u64 {
        // Normalize whitespace + case so trivially-reformatted duplicates hit.
        let norm: String = text.split_whitespace().collect::<Vec<_>>().join(" ");
        crate::util::rng::hash_bytes(norm.to_lowercase().as_bytes())
    }

    /// Insert a (query, response, embedding) triple; returns the entry id.
    pub fn insert(&mut self, query: &str, response: &str, embedding: Vec<f32>) -> usize {
        self.tick += 1;
        self.stats.inserts += 1;
        while self.eviction.needs_eviction() {
            if let Some(victim) = self.eviction.victim() {
                self.index.remove(victim);
                if let Some(e) = self.entries[victim].take() {
                    self.exact.remove(&Self::text_key(&e.query_text));
                }
                self.stats.evictions += 1;
            } else {
                break;
            }
        }
        let id = self.index.insert(&embedding);
        debug_assert_eq!(id, self.entries.len());
        self.entries.push(Some(CacheEntry {
            query_text: query.to_string(),
            response_text: response.to_string(),
            embedding,
        }));
        if self.exact_enabled {
            self.exact.insert(Self::text_key(query), id);
        }
        self.eviction.on_insert(id, self.tick);
        id
    }

    /// Exact-text fast path (no embedding needed). Returns the entry.
    pub fn lookup_exact(&mut self, query: &str) -> Option<(usize, &CacheEntry)> {
        if !self.exact_enabled {
            return None;
        }
        self.tick += 1;
        let id = *self.exact.get(&Self::text_key(query))?;
        let e = self.entries[id].as_ref()?;
        self.stats.exact_hits += 1;
        self.eviction.on_hit(id, self.tick);
        Some((id, e))
    }

    /// ANN lookup: top-k entries by cosine similarity.
    pub fn search(&mut self, embedding: &[f32], k: usize) -> Vec<SearchHit> {
        self.tick += 1;
        self.stats.lookups += 1;
        self.index.search(embedding, k)
    }

    /// Record that a search hit was *used* (feeds LRU/LFU).
    pub fn touch(&mut self, id: usize) {
        self.tick += 1;
        self.eviction.on_hit(id, self.tick);
    }

    pub fn entry(&self, id: usize) -> Option<&CacheEntry> {
        self.entries.get(id).and_then(|e| e.as_ref())
    }

    pub fn len(&self) -> usize {
        self.entries.iter().filter(|e| e.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn dim(&self) -> usize {
        self.index.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{normalize, Rng};

    fn unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        normalize(&mut v);
        v
    }

    #[test]
    fn insert_search_roundtrip() {
        let mut c = SemanticCache::new(16, IndexKind::Flat);
        let mut rng = Rng::new(1);
        let e = unit(&mut rng, 16);
        let id = c.insert("why is the sky blue?", "rayleigh scattering", e.clone());
        let hits = c.search(&e, 1);
        assert_eq!(hits[0].id, id);
        assert!(hits[0].score > 0.999);
        assert_eq!(c.entry(id).unwrap().response_text, "rayleigh scattering");
    }

    #[test]
    fn exact_fast_path_normalizes() {
        let mut c = SemanticCache::new(8, IndexKind::Flat);
        let mut rng = Rng::new(2);
        c.insert("Why is the sky   blue?", "resp", unit(&mut rng, 8));
        assert!(c.lookup_exact("why is the sky blue?").is_some());
        assert!(c.lookup_exact("why is the sea blue?").is_none());
        assert_eq!(c.stats().exact_hits, 1);
    }

    #[test]
    fn exact_path_can_be_disabled() {
        let mut c = SemanticCache::new(8, IndexKind::Flat).with_exact_match(false);
        let mut rng = Rng::new(3);
        c.insert("q", "r", unit(&mut rng, 8));
        assert!(c.lookup_exact("q").is_none());
    }

    #[test]
    fn bounded_lru_evicts() {
        let mut c = SemanticCache::new(8, IndexKind::Flat)
            .with_eviction(EvictionPolicy::Lru, 3);
        let mut rng = Rng::new(4);
        let vs: Vec<_> = (0..4).map(|_| unit(&mut rng, 8)).collect();
        for (i, v) in vs.iter().enumerate() {
            c.insert(&format!("q{i}"), "r", v.clone());
        }
        assert_eq!(c.len(), 3);
        assert_eq!(c.stats().evictions, 1);
        // q0 was evicted: exact lookup gone, index won't return it
        assert!(c.lookup_exact("q0").is_none());
        let hits = c.search(&vs[0], 4);
        assert!(hits.iter().all(|h| h.id != 0));
    }

    #[test]
    fn ivf_backend_works() {
        let mut c = SemanticCache::new(
            16,
            IndexKind::IvfFlat { nlist: 4, nprobe: 2 },
        );
        let mut rng = Rng::new(5);
        let vs: Vec<_> = (0..200).map(|_| unit(&mut rng, 16)).collect();
        for (i, v) in vs.iter().enumerate() {
            c.insert(&format!("q{i}"), &format!("r{i}"), v.clone());
        }
        let hits = c.search(&vs[42], 1);
        assert_eq!(hits[0].id, 42);
    }

    #[test]
    fn append_only_by_default() {
        let mut c = SemanticCache::new(8, IndexKind::Flat);
        let mut rng = Rng::new(6);
        for i in 0..100 {
            c.insert(&format!("q{i}"), "r", unit(&mut rng, 8));
        }
        assert_eq!(c.len(), 100);
        assert_eq!(c.stats().evictions, 0);
    }
}
