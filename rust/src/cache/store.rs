//! The semantic cache: entries + vector index + exact-match fast path +
//! eviction. This is the paper's "Vector Database" + "Cache Management"
//! boxes in Figure 1.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::persist::{
    PersistConfig, Persistence, PersistStatus, RecoveryReport, SnapshotEntry, SnapshotState,
    WalOp,
};
use super::segment::IndexOpts;
use super::{EvictionPolicy, EvictionStrategy, FlatIndex, IvfFlatIndex, SearchHit, VectorIndex};
use crate::util::ThreadPool;

/// One cached interaction: the paper stores exactly this triple.
#[derive(Clone, Debug)]
pub struct CacheEntry {
    pub query_text: String,
    pub response_text: String,
    /// L2-normalized embedding (kept for re-ranking / debugging; the index
    /// holds its own copy in scan-friendly layout).
    pub embedding: Vec<f32>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub inserts: u64,
    pub lookups: u64,
    pub exact_hits: u64,
    pub evictions: u64,
}

/// Index family selector (Table 1 uses IVF_FLAT; FLAT is the exact baseline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexKind {
    Flat,
    IvfFlat { nlist: usize, nprobe: usize },
}

/// Normalized exact-match key: whitespace-collapsed, case-folded hash.
/// Shared by the cache's exact fast path and the scheduler's in-flight miss
/// dedup so "the same query" means the same thing in both places.
pub fn query_key(text: &str) -> u64 {
    let norm: String = text.split_whitespace().collect::<Vec<_>>().join(" ");
    crate::util::rng::hash_bytes(norm.to_lowercase().as_bytes())
}

pub struct SemanticCache {
    entries: Vec<Option<CacheEntry>>,
    index: Box<dyn VectorIndex>,
    /// Exact-match fast path: normalized text -> entry id. §6.1 of the paper:
    /// "For exact matches (cosine similarity = 1.0), directly returning
    /// cached responses without tweaking ensures further cost savings".
    exact: HashMap<u64, usize>,
    exact_enabled: bool,
    eviction: EvictionStrategy,
    tick: u64,
    stats: CacheStats,
    /// Durability layer (snapshots + WAL). `None` = ephemeral (paper mode).
    persist: Option<Persistence>,
}

impl SemanticCache {
    pub fn new(dim: usize, kind: IndexKind) -> Self {
        Self::with_opts(dim, kind, IndexOpts::default())
    }

    /// Build with explicit index tuning (`[index]` section: quantization,
    /// segment size, tombstone-compaction threshold).
    pub fn with_opts(dim: usize, kind: IndexKind, opts: IndexOpts) -> Self {
        let index: Box<dyn VectorIndex> = match kind {
            IndexKind::Flat => Box::new(FlatIndex::with_opts(dim, opts)),
            IndexKind::IvfFlat { nlist, nprobe } => {
                Box::new(IvfFlatIndex::with_opts(dim, nlist, nprobe, opts))
            }
        };
        SemanticCache {
            entries: Vec::new(),
            index,
            exact: HashMap::new(),
            exact_enabled: true,
            eviction: EvictionStrategy::new(EvictionPolicy::None, usize::MAX),
            tick: 0,
            stats: CacheStats::default(),
            persist: None,
        }
    }

    /// Build a durable cache: recover `snapshot + WAL` from `cfg.data_dir`
    /// (creating it on first run), then keep journaling every mutation.
    pub fn open_persistent(
        dim: usize,
        kind: IndexKind,
        policy: EvictionPolicy,
        capacity: usize,
        exact_enabled: bool,
        cfg: &PersistConfig,
    ) -> Result<(SemanticCache, RecoveryReport)> {
        Self::open_persistent_with(
            dim,
            kind,
            IndexOpts::default(),
            policy,
            capacity,
            exact_enabled,
            cfg,
        )
    }

    /// `open_persistent` with explicit index tuning (the Router path).
    pub fn open_persistent_with(
        dim: usize,
        kind: IndexKind,
        opts: IndexOpts,
        policy: EvictionPolicy,
        capacity: usize,
        exact_enabled: bool,
        cfg: &PersistConfig,
    ) -> Result<(SemanticCache, RecoveryReport)> {
        let (persistence, snapshot, ops, mut report) = Persistence::open(cfg)?;
        let mut cache = SemanticCache::with_opts(dim, kind, opts)
            .with_eviction(policy, capacity)
            .with_exact_match(exact_enabled);
        if let Some(state) = snapshot {
            if state.dim != dim {
                bail!(
                    "snapshot dim {} does not match embedder dim {dim}",
                    state.dim
                );
            }
            cache.restore(state);
        }
        for op in ops {
            cache.apply_wal_op(op)?;
        }
        report.recovered_entries = cache.len() as u64;
        cache.persist = Some(persistence);
        Ok((cache, report))
    }

    pub fn with_eviction(mut self, policy: EvictionPolicy, capacity: usize) -> Self {
        self.eviction = EvictionStrategy::new(policy, capacity);
        self
    }

    pub fn with_exact_match(mut self, enabled: bool) -> Self {
        self.exact_enabled = enabled;
        self
    }

    /// Hand the shared worker pool to the index: searches fan the sealed
    /// segments out over `shards` scan jobs (1 = stay single-threaded).
    pub fn set_pool(&mut self, pool: Arc<ThreadPool>, shards: usize) {
        self.index.set_pool(pool, shards);
    }

    fn text_key(text: &str) -> u64 {
        query_key(text)
    }

    /// Insert a (query, response, embedding) triple; returns the entry id.
    pub fn insert(&mut self, query: &str, response: &str, embedding: Vec<f32>) -> usize {
        self.tick += 1;
        self.stats.inserts += 1;
        while self.eviction.needs_eviction() {
            if let Some(victim) = self.eviction.victim() {
                self.index.remove(victim);
                if let Some(e) = self.entries[victim].take() {
                    // Only drop the exact-map key if it still points at the
                    // victim: a later duplicate insert may own it by now.
                    let key = Self::text_key(&e.query_text);
                    if self.exact.get(&key) == Some(&victim) {
                        self.exact.remove(&key);
                    }
                }
                self.stats.evictions += 1;
                let tick = self.tick;
                self.journal(|w| w.append_remove(victim as u64, tick));
            } else {
                break;
            }
        }
        let id = self.index.insert(&embedding);
        debug_assert_eq!(id, self.entries.len());
        let tick = self.tick;
        self.journal(|w| w.append_insert(id as u64, tick, query, response, &embedding));
        self.entries.push(Some(CacheEntry {
            query_text: query.to_string(),
            response_text: response.to_string(),
            embedding,
        }));
        if self.exact_enabled {
            self.exact.insert(Self::text_key(query), id);
        }
        self.eviction.on_insert(id, self.tick);
        self.maybe_compact();
        id
    }

    /// Exact-text fast path (no embedding needed). Returns the entry.
    pub fn lookup_exact(&mut self, query: &str) -> Option<(usize, &CacheEntry)> {
        if !self.exact_enabled {
            return None;
        }
        self.tick += 1;
        let id = *self.exact.get(&Self::text_key(query))?;
        if self.entries.get(id).is_none_or(|e| e.is_none()) {
            return None;
        }
        self.stats.exact_hits += 1;
        self.eviction.on_hit(id, self.tick);
        let tick = self.tick;
        self.journal(|w| w.append_touch(id as u64, tick));
        self.maybe_compact();
        self.entries[id].as_ref().map(|e| (id, e))
    }

    /// ANN lookup: top-k entries by cosine similarity.
    pub fn search(&mut self, embedding: &[f32], k: usize) -> Vec<SearchHit> {
        self.tick += 1;
        self.stats.lookups += 1;
        self.index.search(embedding, k)
    }

    /// Record that a search hit was *used* (feeds LRU/LFU). No-op when the
    /// entry is gone: scheduler completions touch at session EOS, and the
    /// basis entry may have been evicted while the generation was in
    /// flight — reviving a dead id in the eviction maps (or journaling a
    /// Touch for a removed entry) must not happen.
    pub fn touch(&mut self, id: usize) {
        if self.entries.get(id).is_none_or(|e| e.is_none()) {
            return;
        }
        self.tick += 1;
        self.eviction.on_hit(id, self.tick);
        let tick = self.tick;
        self.journal(|w| w.append_touch(id as u64, tick));
        // Hit-heavy workloads append Touch records without ever inserting,
        // so the size check must live on this path too.
        self.maybe_compact();
    }

    /// Append one record to the WAL, if persistence is attached. Journal
    /// failures never take down serving: they are counted (see
    /// `persist_status().io_errors`) and logged, and the cache stays usable
    /// as an ephemeral store. A failed append *poisons* the WAL — a gap or
    /// partial frame would make every later record unrecoverable, so
    /// appends stop until the next successful compaction (which the next
    /// mutation attempts via `maybe_compact`) re-establishes durability.
    fn journal<F>(&mut self, f: F)
    where
        F: FnOnce(&mut super::persist::WalWriter) -> Result<()>,
    {
        if let Some(p) = self.persist.as_mut() {
            if p.is_poisoned() {
                return;
            }
            if let Err(e) = f(p.wal_mut()) {
                p.io_errors += 1;
                p.poison();
                eprintln!("[cache::persist] WAL append failed: {e:#}");
            }
        }
    }

    /// Fold the WAL into a fresh snapshot when it outgrew `compact_bytes`.
    fn maybe_compact(&mut self) {
        let wants = self.persist.as_ref().is_some_and(|p| p.wants_compaction());
        if wants {
            if let Err(e) = self.compact_now() {
                if let Some(p) = self.persist.as_mut() {
                    p.io_errors += 1;
                }
                eprintln!("[cache::persist] compaction failed: {e:#}");
            }
        }
    }

    /// Force a snapshot + WAL rotation now (graceful shutdown, the
    /// `{"admin": "snapshot"}` protocol verb). Returns the new generation,
    /// or `None` when persistence is disabled.
    pub fn compact_now(&mut self) -> Result<Option<u64>> {
        if self.persist.is_none() {
            return Ok(None);
        }
        let state = self.snapshot_state();
        let p = self.persist.as_mut().expect("checked above");
        Ok(Some(p.compact(&state)?))
    }

    /// Live persistence counters (`None` when running ephemeral).
    pub fn persist_status(&self) -> Option<PersistStatus> {
        self.persist.as_ref().map(|p| p.status())
    }

    /// Capture the full cache state for a snapshot: every id slot (live and
    /// tombstoned), embeddings, and eviction/touch metadata.
    pub fn snapshot_state(&self) -> SnapshotState {
        let entries = self
            .entries
            .iter()
            .enumerate()
            .map(|(id, slot)| {
                slot.as_ref().map(|e| {
                    let (inserted_at, last_used, use_count) =
                        self.eviction.meta(id).unwrap_or((0, 0, 0));
                    SnapshotEntry {
                        query: e.query_text.clone(),
                        response: e.response_text.clone(),
                        embedding: e.embedding.clone(),
                        inserted_at,
                        last_used,
                        use_count,
                    }
                })
            })
            .collect();
        SnapshotState {
            dim: self.index.dim(),
            tick: self.tick,
            stats: self.stats,
            quant: self.index.quant_params(),
            entries,
        }
    }

    /// Rebuild state from a snapshot. Only valid on a freshly-built cache.
    /// Tombstoned slots keep their pre-crash ids via true index tombstones
    /// (no placeholder rows — their memory is never allocated, let alone
    /// scanned). Quantization params are installed *before* any row so the
    /// rebuilt codes — and every search result — match the pre-restart run.
    fn restore(&mut self, state: SnapshotState) {
        assert!(
            self.entries.is_empty(),
            "restore() requires an empty cache"
        );
        if let Some(p) = state.quant {
            self.index.set_quant_params(p);
        }
        for (id, slot) in state.entries.into_iter().enumerate() {
            match slot {
                Some(e) => {
                    let got = self.index.insert(&e.embedding);
                    debug_assert_eq!(got, id);
                    if self.exact_enabled {
                        self.exact.insert(Self::text_key(&e.query), id);
                    }
                    self.eviction.restore(id, e.inserted_at, e.last_used, e.use_count);
                    self.entries.push(Some(CacheEntry {
                        query_text: e.query,
                        response_text: e.response,
                        embedding: e.embedding,
                    }));
                }
                None => {
                    let got = self.index.insert_tombstone();
                    debug_assert_eq!(got, id);
                    self.entries.push(None);
                }
            }
        }
        self.tick = state.tick;
        self.stats = state.stats;
    }

    /// Replay one WAL record on top of the current state. Unlike `insert`,
    /// replay never runs the eviction policy: the original run's evictions
    /// are explicit `Remove` records that precede their triggering insert.
    fn apply_wal_op(&mut self, op: WalOp) -> Result<()> {
        match op {
            WalOp::Insert { id, tick, query, response, embedding } => {
                let id = id as usize;
                if id != self.entries.len() {
                    bail!(
                        "WAL insert id {id} out of order (expected {})",
                        self.entries.len()
                    );
                }
                if embedding.len() != self.index.dim() {
                    bail!(
                        "WAL embedding dim {} != index dim {}",
                        embedding.len(),
                        self.index.dim()
                    );
                }
                let got = self.index.insert(&embedding);
                debug_assert_eq!(got, id);
                if self.exact_enabled {
                    self.exact.insert(Self::text_key(&query), id);
                }
                self.eviction.restore(id, tick, tick, 0);
                self.entries.push(Some(CacheEntry {
                    query_text: query,
                    response_text: response,
                    embedding,
                }));
                self.stats.inserts += 1;
                self.tick = self.tick.max(tick);
            }
            WalOp::Remove { id, tick } => {
                let id = id as usize;
                if let Some(e) = self.entries.get_mut(id).and_then(|s| s.take()) {
                    // Mirror the live eviction path: leave the key alone if
                    // a later duplicate insert owns it.
                    let key = Self::text_key(&e.query_text);
                    if self.exact.get(&key) == Some(&id) {
                        self.exact.remove(&key);
                    }
                    self.index.remove(id);
                    self.eviction.forget(id);
                    self.stats.evictions += 1;
                }
                self.tick = self.tick.max(tick);
            }
            WalOp::Touch { id, tick } => {
                let id = id as usize;
                if self.entries.get(id).is_some_and(|e| e.is_some()) {
                    self.eviction.on_hit(id, tick);
                }
                self.tick = self.tick.max(tick);
            }
            WalOp::GenBump { .. } => {
                // Compaction handoff marker — state-free on replay; only a
                // shipping tailer acts on it (by switching log files).
            }
        }
        Ok(())
    }

    /// Install a replicated snapshot (the WAL-shipping bootstrap payload)
    /// into a freshly-built cache; afterwards keep the replica converged by
    /// feeding every shipped record to [`Self::apply_replicated_op`].
    /// Replication is literally recovery applied continuously, so a replica
    /// never journals: the shipped records already live in the owner's WAL,
    /// and re-journaling them here would double-write on promotion. To
    /// re-bootstrap (shipper restarted from a newer generation), build a
    /// fresh cache and restore into that instead.
    pub fn restore_replicated(&mut self, state: SnapshotState) -> Result<()> {
        if !self.entries.is_empty() {
            bail!("restore_replicated requires a fresh cache (rebuild to re-bootstrap)");
        }
        if state.dim != self.index.dim() {
            bail!(
                "replicated snapshot dim {} != index dim {}",
                state.dim,
                self.index.dim()
            );
        }
        self.restore(state);
        Ok(())
    }

    /// Apply one shipped WAL record through the recovery path (see
    /// [`Self::restore_replicated`]).
    pub fn apply_replicated_op(&mut self, op: WalOp) -> Result<()> {
        self.apply_wal_op(op)
    }

    pub fn entry(&self, id: usize) -> Option<&CacheEntry> {
        self.entries.get(id).and_then(|e| e.as_ref())
    }

    pub fn len(&self) -> usize {
        self.entries.iter().filter(|e| e.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn dim(&self) -> usize {
        self.index.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{normalize, Rng};

    fn unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        normalize(&mut v);
        v
    }

    #[test]
    fn insert_search_roundtrip() {
        let mut c = SemanticCache::new(16, IndexKind::Flat);
        let mut rng = Rng::new(1);
        let e = unit(&mut rng, 16);
        let id = c.insert("why is the sky blue?", "rayleigh scattering", e.clone());
        let hits = c.search(&e, 1);
        assert_eq!(hits[0].id, id);
        assert!(hits[0].score > 0.999);
        assert_eq!(c.entry(id).unwrap().response_text, "rayleigh scattering");
    }

    #[test]
    fn exact_fast_path_normalizes() {
        let mut c = SemanticCache::new(8, IndexKind::Flat);
        let mut rng = Rng::new(2);
        c.insert("Why is the sky   blue?", "resp", unit(&mut rng, 8));
        assert!(c.lookup_exact("why is the sky blue?").is_some());
        assert!(c.lookup_exact("why is the sea blue?").is_none());
        assert_eq!(c.stats().exact_hits, 1);
    }

    #[test]
    fn exact_path_can_be_disabled() {
        let mut c = SemanticCache::new(8, IndexKind::Flat).with_exact_match(false);
        let mut rng = Rng::new(3);
        c.insert("q", "r", unit(&mut rng, 8));
        assert!(c.lookup_exact("q").is_none());
    }

    #[test]
    fn bounded_lru_evicts() {
        let mut c = SemanticCache::new(8, IndexKind::Flat)
            .with_eviction(EvictionPolicy::Lru, 3);
        let mut rng = Rng::new(4);
        let vs: Vec<_> = (0..4).map(|_| unit(&mut rng, 8)).collect();
        for (i, v) in vs.iter().enumerate() {
            c.insert(&format!("q{i}"), "r", v.clone());
        }
        assert_eq!(c.len(), 3);
        assert_eq!(c.stats().evictions, 1);
        // q0 was evicted: exact lookup gone, index won't return it
        assert!(c.lookup_exact("q0").is_none());
        let hits = c.search(&vs[0], 4);
        assert!(hits.iter().all(|h| h.id != 0));
    }

    #[test]
    fn ivf_backend_works() {
        let mut c = SemanticCache::new(
            16,
            IndexKind::IvfFlat { nlist: 4, nprobe: 2 },
        );
        let mut rng = Rng::new(5);
        let vs: Vec<_> = (0..200).map(|_| unit(&mut rng, 16)).collect();
        for (i, v) in vs.iter().enumerate() {
            c.insert(&format!("q{i}"), &format!("r{i}"), v.clone());
        }
        let hits = c.search(&vs[42], 1);
        assert_eq!(hits[0].id, 42);
    }

    #[test]
    fn append_only_by_default() {
        let mut c = SemanticCache::new(8, IndexKind::Flat);
        let mut rng = Rng::new(6);
        for i in 0..100 {
            c.insert(&format!("q{i}"), "r", unit(&mut rng, 8));
        }
        assert_eq!(c.len(), 100);
        assert_eq!(c.stats().evictions, 0);
    }

    fn persist_cfg(tag: &str) -> PersistConfig {
        let dir = std::env::temp_dir().join(format!(
            "tweakllm-store-{}-{}",
            std::process::id(),
            tag
        ));
        let _ = std::fs::remove_dir_all(&dir);
        PersistConfig {
            data_dir: dir.to_string_lossy().to_string(),
            wal_fsync: false,
            compact_bytes: u64::MAX,
            fsync_batch_ms: 0,
        }
    }

    #[test]
    fn wal_replay_restores_identical_search_results() {
        let cfg = persist_cfg("replay");
        let mut rng = Rng::new(7);
        let vs: Vec<_> = (0..20).map(|_| unit(&mut rng, 8)).collect();
        let before: Vec<SearchHit>;
        {
            let (mut c, report) = SemanticCache::open_persistent(
                8,
                IndexKind::Flat,
                EvictionPolicy::None,
                usize::MAX,
                true,
                &cfg,
            )
            .unwrap();
            assert_eq!(report.recovered_entries, 0);
            for (i, v) in vs.iter().enumerate() {
                c.insert(&format!("q{i}"), &format!("r{i}"), v.clone());
            }
            before = c.search(&vs[3], 5);
            // No compact_now(): drop without a snapshot = simulated crash.
        }
        let (mut c, report) = SemanticCache::open_persistent(
            8,
            IndexKind::Flat,
            EvictionPolicy::None,
            usize::MAX,
            true,
            &cfg,
        )
        .unwrap();
        assert_eq!(report.recovered_entries, 20);
        assert_eq!(report.replayed_ops, 20);
        assert_eq!(c.len(), 20);
        assert_eq!(c.search(&vs[3], 5), before);
        assert_eq!(c.entry(7).unwrap().response_text, "r7");
        assert!(c.lookup_exact("q11").is_some());
        let _ = std::fs::remove_dir_all(&cfg.data_dir);
    }

    #[test]
    fn snapshot_then_wal_recovers_and_generation_advances() {
        let cfg = persist_cfg("snapwal");
        let mut rng = Rng::new(8);
        let vs: Vec<_> = (0..12).map(|_| unit(&mut rng, 8)).collect();
        {
            let (mut c, _) = SemanticCache::open_persistent(
                8,
                IndexKind::Flat,
                EvictionPolicy::None,
                usize::MAX,
                false,
                &cfg,
            )
            .unwrap();
            for (i, v) in vs.iter().enumerate().take(8) {
                c.insert(&format!("q{i}"), "r", v.clone());
            }
            assert_eq!(c.compact_now().unwrap(), Some(1));
            // Post-snapshot mutations land in the generation-1 WAL.
            for (i, v) in vs.iter().enumerate().skip(8) {
                c.insert(&format!("q{i}"), "r", v.clone());
            }
            let st = c.persist_status().unwrap();
            assert_eq!(st.generation, 1);
            assert_eq!(st.wal_records, 4);
        }
        let (mut c, report) = SemanticCache::open_persistent(
            8,
            IndexKind::Flat,
            EvictionPolicy::None,
            usize::MAX,
            false,
            &cfg,
        )
        .unwrap();
        assert_eq!(report.generation, 1);
        assert_eq!(report.snapshot_slots, 8);
        assert_eq!(report.replayed_ops, 4);
        assert_eq!(c.len(), 12);
        assert_eq!(c.search(&vs[10], 1)[0].id, 10);
        let _ = std::fs::remove_dir_all(&cfg.data_dir);
    }

    #[test]
    fn size_triggered_compaction_folds_wal() {
        let mut cfg = persist_cfg("autocompact");
        cfg.compact_bytes = 2_000; // tiny: force several compactions
        let mut rng = Rng::new(9);
        {
            let (mut c, _) = SemanticCache::open_persistent(
                8,
                IndexKind::Flat,
                EvictionPolicy::None,
                usize::MAX,
                false,
                &cfg,
            )
            .unwrap();
            for i in 0..60 {
                c.insert(&format!("q{i}"), "r", unit(&mut rng, 8));
            }
            let st = c.persist_status().unwrap();
            assert!(st.compactions >= 1, "no compaction at {} bytes", st.wal_bytes);
            assert!(st.wal_bytes < 3_000);
            assert!(st.last_compaction_unix > 0);
        }
        let (c, report) = SemanticCache::open_persistent(
            8,
            IndexKind::Flat,
            EvictionPolicy::None,
            usize::MAX,
            false,
            &cfg,
        )
        .unwrap();
        assert_eq!(c.len(), 60);
        assert!(report.generation >= 1);
        let _ = std::fs::remove_dir_all(&cfg.data_dir);
    }
}
