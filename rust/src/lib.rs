//! # TweakLLM
//!
//! Reproduction of *TweakLLM: A Routing Architecture for Dynamic Tailoring
//! of Cached Responses* (Cheema et al., 2025) as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the paper's contribution: threshold-routed
//!   semantic caching with small-LLM response tweaking, plus every substrate
//!   it depends on (vector DB, tokenizer, batcher, eval harnesses,
//!   baselines, datasets, cost model).
//! * **L2** — JAX models (embedder + Big/Small decoder) in
//!   `python/compile/model.py`, AOT-lowered to HLO text.
//! * **L1** — Pallas kernels (attention, decode attention, fused matmul,
//!   RMSNorm, cosine scoring) in `python/compile/kernels/`.
//!
//! The Rust binary loads `artifacts/*.hlo.txt` via the PJRT CPU client and
//! is self-contained after `make artifacts`; Python never runs on the
//! request path. See DESIGN.md for the experiment index.

pub mod baselines;
pub mod bench;
pub mod cache;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod datasets;
pub mod eval;
pub mod faults;
pub mod llm;
pub mod metrics;
pub mod runtime;
pub mod server;
pub mod tokenizer;
pub mod trace;
pub mod util;
