//! Generator driver: the autoregressive loop over compiled prefill/decode
//! artifacts. Rust owns the loop and the sampling; the transport behind the
//! loop is pluggable (DESIGN.md §Perf L2):
//!
//! * [`ResidentBackend`] — the decode state (KV caches ‖ logits tail) lives
//!   in a single packed device buffer that each step feeds straight back
//!   into the next `run_raw` call. Only the logits (or span token ids) and
//!   the scalar step inputs ever cross the host boundary: O(vocab) per
//!   step instead of O(KV bytes).
//! * [`LiteralBackend`] — the pre-resident behavior: every step fetches the
//!   full KV tuple to host literals and re-uploads it. Kept as the
//!   automatic fallback (old artifact sets, `[runtime] device_resident =
//!   false`) and as the reference for the bit-identity gate in
//!   `rust/tests/runtime_integration.rs`.
//!
//! [`DecodeSession`] is the transport-independent state machine driving
//! sampling and the span/single-step/tail transitions; both backends must
//! produce bit-identical token streams through it.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::{to_f32_vec, ExecArg, Executable, HostTensor, IoSpec, Runtime};
use crate::tokenizer::{Tokenizer, EOS_ID};
use crate::util::Rng;

#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    /// 0.0 = greedy argmax.
    pub temperature: f32,
    /// Restrict sampling to the k most likely tokens (0 = no restriction).
    pub top_k: usize,
    pub max_new_tokens: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        // "default temperature" per the paper's Table 1 — 1.0 with a top-k
        // guard keeps the untrained substrate model's output distribution
        // from degenerating into uniform noise.
        SamplingParams { temperature: 1.0, top_k: 40, max_new_tokens: 32 }
    }
}

impl SamplingParams {
    pub fn greedy(max_new_tokens: usize) -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, max_new_tokens }
    }
}

#[derive(Clone, Debug, Default)]
pub struct GenerationStats {
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub prefill_micros: u128,
    pub decode_micros: u128,
    /// Which transport served the decode loop (resident vs literal).
    pub device_resident: bool,
}

#[derive(Debug)]
pub struct Generation {
    pub token_ids: Vec<i32>,
    pub text: String,
    pub stats: GenerationStats,
}

// ---------------------------------------------------------------------------
// Sampling
// ---------------------------------------------------------------------------

/// Reusable scratch for [`sample_token_with`]: the bounded top-k candidate
/// buffer and the softmax weights. One instance per decode session
/// amortizes both allocations over every sampled token (the previous
/// implementation built a full-vocab index `Vec` plus a weights `Vec` per
/// token).
#[derive(Clone, Debug, Default)]
pub struct SampleScratch {
    cand: Vec<(f32, u32)>,
    weights: Vec<f64>,
}

/// Candidate priority: higher logit wins, ties break toward the lower token
/// id. Returns true when `a` ranks strictly below `b`. (A total order —
/// unlike the old `select_nth` partial selection, whose candidate *set*
/// this reproduces but whose internal ordering was unspecified; the
/// distribution-level unit tests below hold for both.)
#[inline]
fn cand_below(a: (f32, u32), b: (f32, u32)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && a.1 > b.1)
}

/// Sample a token id from logits. Exposed for unit testing.
pub fn sample_token(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> i32 {
    sample_token_with(logits, params, rng, &mut SampleScratch::default())
}

/// Allocation-free top-k sampling: a bounded k-element min-heap over the
/// logits (k ≤ 40 on every configured path) in caller-provided scratch,
/// then an inverse-CDF draw over the k candidates in (logit desc, id asc)
/// order.
pub fn sample_token_with(
    logits: &[f32],
    params: &SamplingParams,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) -> i32 {
    debug_assert!(!logits.is_empty());
    if params.temperature <= 0.0 {
        // greedy
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        return best as i32;
    }
    let k = if params.top_k == 0 { logits.len() } else { params.top_k.min(logits.len()) };
    let cand = &mut scratch.cand;
    cand.clear();
    if k == logits.len() {
        // unrestricted sampling: every token is a candidate, natural order
        cand.extend(logits.iter().enumerate().map(|(i, &x)| (x, i as u32)));
    } else {
        // Bounded min-heap: root is the weakest of the current k candidates;
        // a new logit enters only by beating the root. O(n log k), no alloc.
        for (i, &x) in logits.iter().enumerate() {
            let c = (x, i as u32);
            if cand.len() < k {
                cand.push(c);
                let mut j = cand.len() - 1;
                while j > 0 {
                    let parent = (j - 1) / 2;
                    if cand_below(cand[j], cand[parent]) {
                        cand.swap(j, parent);
                        j = parent;
                    } else {
                        break;
                    }
                }
            } else if cand_below(cand[0], c) {
                cand[0] = c;
                let mut j = 0usize;
                loop {
                    let l = 2 * j + 1;
                    let r = l + 1;
                    let mut m = j;
                    if l < cand.len() && cand_below(cand[l], cand[m]) {
                        m = l;
                    }
                    if r < cand.len() && cand_below(cand[r], cand[m]) {
                        m = r;
                    }
                    if m == j {
                        break;
                    }
                    cand.swap(j, m);
                    j = m;
                }
            }
        }
        cand.sort_unstable_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
    }
    let max = cand.iter().map(|c| c.0).fold(f32::NEG_INFINITY, f32::max);
    let weights = &mut scratch.weights;
    weights.clear();
    weights.extend(cand.iter().map(|c| (((c.0 - max) / params.temperature) as f64).exp()));
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return cand[0].1 as i32;
    }
    cand[rng.weighted(weights)].1 as i32
}

/// The top-k baked into the decode-span artifact
/// (python/compile/model.py::SPAN_TOP_K).
pub const SPAN_TOP_K: usize = 40;

// ---------------------------------------------------------------------------
// Decode backends (transports)
// ---------------------------------------------------------------------------

/// What the decode state machine needs from a transport: one prompt pass,
/// single steps that surface logits for host-side sampling, and optionally
/// fused spans that sample in-graph. Implemented by [`LiteralBackend`],
/// [`ResidentBackend`], and by fakes in unit tests.
pub trait DecodeBackend {
    /// Fused span width, if span execution is available.
    fn span_n(&self) -> Option<usize>;

    /// Whether this transport keeps the decode state on device.
    fn device_resident(&self) -> bool {
        false
    }

    /// Run the prompt pass (`ids` padded, `len` live tokens); returns the
    /// next-token logits.
    fn prefill(&mut self, ids: &[i32], len: usize) -> Result<Vec<f32>>;

    /// One decode step: consume `token` at position `pos`, return logits.
    fn step(&mut self, token: i32, pos: i32) -> Result<Vec<f32>>;

    /// Fused span: consume `token` at `pos`, run `u.len()` steps sampling
    /// in-graph (one uniform per token) at `temperature`; returns the
    /// sampled token ids.
    fn span(&mut self, token: i32, pos: i32, u: &[f32], temperature: f32) -> Result<Vec<i32>>;
}

/// Host-literal transport: the KV tuple round-trips device→host→device on
/// every step — O(KV bytes) per token. The automatic fallback when the
/// resident artifact set is absent, and the reference for the bit-identity
/// gate.
pub struct LiteralBackend {
    prefill: Arc<Executable>,
    decode: Arc<Executable>,
    span: Option<(usize, Arc<Executable>)>,
    kv_spec: IoSpec,
    k: Option<HostTensor>,
    v: Option<HostTensor>,
}

impl LiteralBackend {
    /// Pop the trailing `[.., k_cache, v_cache]` outputs into host tensors
    /// (every literal decode artifact ends its output tuple this way).
    fn store_kv(&mut self, outs: &mut Vec<xla::Literal>, what: &str) -> Result<()> {
        let v = outs.pop().with_context(|| format!("{what} missing v_cache"))?;
        let k = outs.pop().with_context(|| format!("{what} missing k_cache"))?;
        self.v = Some(HostTensor::from_literal(&v, &self.kv_spec)?);
        self.k = Some(HostTensor::from_literal(&k, &self.kv_spec)?);
        Ok(())
    }
}

impl DecodeBackend for LiteralBackend {
    fn span_n(&self) -> Option<usize> {
        self.span.as_ref().map(|(n, _)| *n)
    }

    fn prefill(&mut self, ids: &[i32], len: usize) -> Result<Vec<f32>> {
        let tok_t = HostTensor::i32(ids.to_vec(), &[ids.len()]);
        let len_t = HostTensor::i32(vec![len as i32], &[1]);
        let mut outs = self.prefill.run(&[tok_t, len_t])?;
        self.store_kv(&mut outs, "prefill")?;
        to_f32_vec(&outs.pop().context("prefill logits")?)
    }

    fn step(&mut self, token: i32, pos: i32) -> Result<Vec<f32>> {
        let k = self.k.take().context("decode step before prefill")?;
        let v = self.v.take().context("decode step before prefill")?;
        let inputs = [
            HostTensor::i32(vec![token], &[1]),
            HostTensor::i32(vec![pos], &[1]),
            k,
            v,
        ];
        let mut outs = self.decode.run(&inputs)?;
        self.store_kv(&mut outs, "decode")?;
        to_f32_vec(&outs.pop().context("decode logits")?)
    }

    fn span(&mut self, token: i32, pos: i32, u: &[f32], temperature: f32) -> Result<Vec<i32>> {
        let (_, exe) = self.span.as_ref().context("span artifact not compiled")?;
        let k = self.k.take().context("span before prefill")?;
        let v = self.v.take().context("span before prefill")?;
        let inputs = [
            HostTensor::i32(vec![token], &[1]),
            HostTensor::i32(vec![pos], &[1]),
            k,
            v,
            HostTensor::f32(u.to_vec(), &[u.len()]),
            HostTensor::f32(vec![temperature], &[1]),
        ];
        let mut outs = exe.run(&inputs)?;
        self.store_kv(&mut outs, "span")?;
        Ok(outs.pop().context("span tokens")?.to_vec::<i32>()?)
    }
}

/// The fused span pieces of a resident artifact set.
struct SpanSet {
    n: usize,
    exe: Arc<Executable>,
    /// `{model}_peek_tokens{n}`: slices the sampled ids out of the packed
    /// state — the only thing fetched per span, O(span_n).
    peek: Arc<Executable>,
}

/// The compiled artifact set for device-resident decode: single-root
/// packed-state executables (state = k ‖ v ‖ tail; see
/// python/compile/model.py `state_len`).
pub struct ResidentSet {
    prefill: Arc<Executable>,
    decode: Arc<Executable>,
    /// `{model}_peek_logits`: slices the logits tail out of the packed
    /// state — the only thing fetched per single step, O(vocab).
    peek_logits: Arc<Executable>,
    span: Option<SpanSet>,
}

/// Device-resident transport: the packed decode state lives in one PJRT
/// buffer that is fed straight back into the next step. Per-step host
/// traffic is the scalar inputs up and the logits (or span ids) down; the
/// KV cache never crosses.
///
/// The backend *owns* its state buffer (the executables are shared via
/// `Arc`), so any number of resident sessions can be in flight at once —
/// the decode scheduler interleaves them on the engine thread.
pub struct ResidentBackend {
    set: Arc<ResidentSet>,
    state: Option<xla::PjRtBuffer>,
}

impl ResidentBackend {
    fn take_output(&mut self, mut outs: Vec<xla::PjRtBuffer>, what: &str) -> Result<()> {
        if outs.is_empty() {
            bail!("{what} produced no output buffer");
        }
        // The freshly produced state replaces the previous one; dropping
        // the old buffer releases its device memory.
        self.state = Some(outs.remove(0));
        Ok(())
    }

    fn peek_logits(&self) -> Result<Vec<f32>> {
        let state = self.state.as_ref().context("no resident decode state")?;
        let outs = self.set.peek_logits.run_raw(&[ExecArg::Device(state)])?;
        let lit = outs.first().context("peek_logits produced no output")?.to_literal_sync()?;
        to_f32_vec(&lit)
    }
}

impl DecodeBackend for ResidentBackend {
    fn span_n(&self) -> Option<usize> {
        self.set.span.as_ref().map(|s| s.n)
    }

    fn device_resident(&self) -> bool {
        true
    }

    fn prefill(&mut self, ids: &[i32], len: usize) -> Result<Vec<f32>> {
        let len_in = [len as i32];
        let outs = self.set.prefill.run_raw(&[ExecArg::I32(ids), ExecArg::I32(&len_in)])?;
        self.take_output(outs, "resident prefill")?;
        self.peek_logits()
    }

    fn step(&mut self, token: i32, pos: i32) -> Result<Vec<f32>> {
        let state = self.state.take().context("decode step before prefill")?;
        let tok_in = [token];
        let pos_in = [pos];
        let outs = self.set.decode.run_raw(&[
            ExecArg::I32(&tok_in),
            ExecArg::I32(&pos_in),
            ExecArg::Device(&state),
        ])?;
        self.take_output(outs, "resident decode")?;
        self.peek_logits()
    }

    fn span(&mut self, token: i32, pos: i32, u: &[f32], temperature: f32) -> Result<Vec<i32>> {
        let sp = self.set.span.as_ref().context("span artifacts not compiled")?;
        let state = self.state.take().context("span before prefill")?;
        let tok_in = [token];
        let pos_in = [pos];
        let temp_in = [temperature];
        let outs = sp.exe.run_raw(&[
            ExecArg::I32(&tok_in),
            ExecArg::I32(&pos_in),
            ExecArg::Device(&state),
            ExecArg::F32(u),
            ExecArg::F32(&temp_in),
        ])?;
        self.take_output(outs, "resident span")?;
        let state = self.state.as_ref().expect("state just stored");
        let toks = sp.peek.run_raw(&[ExecArg::Device(state)])?;
        let lit = toks.first().context("peek_tokens produced no output")?.to_literal_sync()?;
        Ok(lit.to_vec::<i32>()?)
    }
}

// ---------------------------------------------------------------------------
// Decode session (the transport-independent state machine)
// ---------------------------------------------------------------------------

enum Phase {
    /// Fresh logits pending a host-side sample.
    Sample { logits: Vec<f32> },
    /// Last token pushed; next unit of work is a span or a single step.
    Advance,
    Done,
}

/// Step-wise decode driver: sample → (span | step) → tail → EOS.
///
/// Owns the sampling scratch and the token buffer; the backend owns the
/// transport (and, for the resident backend, the device buffers).
/// [`DecodeSession::advance`] performs exactly one unit of backend work,
/// which makes a generation resumable step-wise — the hook for future
/// multi-request decode interleaving.
pub struct DecodeSession<B: DecodeBackend> {
    backend: B,
    params: SamplingParams,
    prompt_len: usize,
    max_new: usize,
    use_span: bool,
    generated: Vec<i32>,
    phase: Phase,
    scratch: SampleScratch,
    u_buf: Vec<f32>,
    stats: GenerationStats,
}

impl<B: DecodeBackend> DecodeSession<B> {
    /// Run the prompt pass and enter the sampling phase. The span path is
    /// enabled only when the sampling params match the artifact's baked-in
    /// top-k (greedy works too: temperature ~ 0 collapses the in-graph
    /// softmax onto the argmax).
    pub fn start(
        mut backend: B,
        params: SamplingParams,
        ids: &[i32],
        prompt_len: usize,
        max_seq: usize,
    ) -> Result<Self> {
        if prompt_len == 0 {
            bail!("empty prompt");
        }
        let t0 = std::time::Instant::now();
        let logits = backend.prefill(ids, prompt_len)?;
        let stats = GenerationStats {
            prompt_tokens: prompt_len,
            prefill_micros: t0.elapsed().as_micros(),
            device_resident: backend.device_resident(),
            ..Default::default()
        };
        let max_new = params.max_new_tokens.min(max_seq.saturating_sub(prompt_len));
        let use_span = backend
            .span_n()
            .map(|n| max_new >= n && (params.top_k == SPAN_TOP_K || params.temperature <= 0.0))
            .unwrap_or(false);
        let phase = if max_new == 0 { Phase::Done } else { Phase::Sample { logits } };
        Ok(DecodeSession {
            backend,
            params,
            prompt_len,
            max_new,
            use_span,
            generated: Vec::with_capacity(max_new),
            phase,
            scratch: SampleScratch::default(),
            u_buf: Vec::new(),
            stats,
        })
    }

    pub fn is_done(&self) -> bool {
        matches!(self.phase, Phase::Done)
    }

    /// Tokens generated so far.
    pub fn tokens(&self) -> &[i32] {
        &self.generated
    }

    /// One unit of work: sample one token from pending logits, run one
    /// fused span, or run one single decode step. Returns `true` while work
    /// remains.
    pub fn advance(&mut self, rng: &mut Rng) -> Result<bool> {
        let t0 = std::time::Instant::now();
        let phase = std::mem::replace(&mut self.phase, Phase::Done);
        match phase {
            Phase::Done => {}
            Phase::Sample { logits } => {
                let tok = sample_token_with(&logits, &self.params, rng, &mut self.scratch);
                self.generated.push(tok);
                self.phase = if tok == EOS_ID || self.generated.len() >= self.max_new {
                    Phase::Done
                } else {
                    Phase::Advance
                };
            }
            Phase::Advance => {
                let last = *self.generated.last().expect("Advance implies a token");
                let pos = (self.prompt_len + self.generated.len() - 1) as i32;
                let remaining = self.max_new - self.generated.len();
                let span_n = self.backend.span_n();
                if self.use_span && span_n.map_or(false, |n| remaining >= n) {
                    let n = span_n.expect("use_span implies span_n");
                    self.u_buf.clear();
                    for _ in 0..n {
                        self.u_buf.push(rng.f32());
                    }
                    let temp = self.params.temperature.max(0.0);
                    let tokens = self.backend.span(last, pos, &self.u_buf, temp)?;
                    let mut ended = false;
                    for t in tokens {
                        self.generated.push(t);
                        if t == EOS_ID || self.generated.len() >= self.max_new {
                            ended = true;
                            break;
                        }
                    }
                    self.phase = if ended { Phase::Done } else { Phase::Advance };
                } else {
                    // single step (also the post-span tail)
                    let logits = self.backend.step(last, pos)?;
                    self.phase = Phase::Sample { logits };
                }
            }
        }
        self.stats.decode_micros += t0.elapsed().as_micros();
        Ok(!self.is_done())
    }

    /// Drive the session to completion.
    pub fn run(&mut self, rng: &mut Rng) -> Result<()> {
        while self.advance(rng)? {}
        Ok(())
    }

    /// Finish: the token stream plus stats.
    pub fn finish(mut self) -> (Vec<i32>, GenerationStats) {
        self.stats.generated_tokens = self.generated.len();
        (self.generated, self.stats)
    }
}

// ---------------------------------------------------------------------------
// Generator facade
// ---------------------------------------------------------------------------

pub struct Generator {
    prefill: Arc<Executable>,
    decode: Arc<Executable>,
    /// Fused multi-step decode (§Perf L2): runs N steps + in-graph top-k
    /// sampling per executable call. `None` when the artifact set predates
    /// spans.
    span: Option<(usize, Arc<Executable>)>,
    /// Device-resident artifact set; `None` when the artifacts predate the
    /// packed-state convention or `[runtime] device_resident = false`.
    /// `Arc` so every live session shares one set while owning its state.
    resident: Option<Arc<ResidentSet>>,
    kv_spec: IoSpec,
    tokenizer: Tokenizer,
    pub model_name: String,
    max_prefill: usize,
    max_seq: usize,
}

/// Discover the `{model}_*_res` + `{model}_peek_*` artifact set, validating
/// that every piece agrees on the packed state width AND that the resident
/// transport mirrors the literal transport's span capability exactly —
/// asymmetric span support would consume the RNG differently and break the
/// bit-identical-stream contract. Any inconsistency falls back to the
/// literal transport (with a notice) rather than failing.
fn discover_resident(
    rt: &Runtime,
    model: &str,
    literal_span: Option<usize>,
) -> Option<ResidentSet> {
    let prefill = rt.executable(&format!("{model}_prefill_res")).ok()?;
    let decode = rt.executable(&format!("{model}_decode_res")).ok()?;
    let peek_logits = rt.executable(&format!("{model}_peek_logits")).ok()?;
    let state_len = prefill.spec.outputs.first()?.numel();
    let consistent = prefill.spec.untupled
        && decode.spec.untupled
        && peek_logits.spec.untupled
        && decode.spec.inputs.len() == 3
        && decode.spec.inputs[2].numel() == state_len
        && decode.spec.outputs.first().map(|o| o.numel()) == Some(state_len)
        && peek_logits.spec.inputs.first().map(|i| i.numel()) == Some(state_len);
    if !consistent {
        eprintln!("[runtime] {model}: resident artifacts inconsistent; using literal decode");
        return None;
    }
    let span = match literal_span {
        None => None, // neither transport spans: symmetric
        Some(n) => {
            let exe = rt.executable(&format!("{model}_decode{n}_res")).ok();
            let peek = rt.executable(&format!("{model}_peek_tokens{n}")).ok();
            let set = match (exe, peek) {
                (Some(exe), Some(peek)) => {
                    let ok = exe.spec.untupled
                        && peek.spec.untupled
                        && exe.spec.inputs.len() == 5
                        && exe.spec.inputs[2].numel() == state_len
                        && exe.spec.inputs[3].numel() == n
                        && exe.spec.outputs.first().map(|o| o.numel()) == Some(state_len)
                        && peek.spec.inputs.first().map(|i| i.numel()) == Some(state_len)
                        && peek.spec.outputs.first().map(|o| o.numel()) == Some(n);
                    ok.then_some(SpanSet { n, exe, peek })
                }
                _ => None,
            };
            if set.is_none() {
                eprintln!(
                    "[runtime] {model}: literal span({n}) has no matching resident span; \
                     using literal decode"
                );
                return None;
            }
            set
        }
    };
    Some(ResidentSet { prefill, decode, peek_logits, span })
}

impl Generator {
    /// `model` is "small" or "big" (manifest model names). Prefers the
    /// device-resident transport when its artifacts are compiled.
    pub fn new(rt: &Runtime, model: &str) -> Result<Generator> {
        Self::with_mode(rt, model, true)
    }

    /// `device_resident = false` pins the literal transport even when
    /// resident artifacts exist (`[runtime] device_resident = false`).
    pub fn with_mode(rt: &Runtime, model: &str, device_resident: bool) -> Result<Generator> {
        let spec = rt.manifest.model(model)?;
        // discover a decode-span artifact (name: {model}_decode{N}, N > 1)
        let span = rt
            .manifest
            .artifacts
            .keys()
            .filter_map(|name| {
                let n: usize = name
                    .strip_prefix(&format!("{model}_decode"))?
                    .parse()
                    .ok()?;
                (n > 1).then_some((n, name.clone()))
            })
            .max_by_key(|(n, _)| *n)
            // tolerate selective loading (tests compile only a subset)
            .and_then(|(n, name)| rt.executable(&name).ok().map(|e| (n, e)));
        let resident = if device_resident {
            discover_resident(rt, model, span.as_ref().map(|(n, _)| *n)).map(Arc::new)
        } else {
            None
        };
        let decode = rt.executable(&format!("{model}_decode"))?;
        let kv_spec = decode.spec.inputs[2].clone();
        Ok(Generator {
            prefill: rt.executable(&format!("{model}_prefill"))?,
            decode,
            span,
            resident,
            kv_spec,
            tokenizer: Tokenizer::new(rt.manifest.vocab_size),
            model_name: model.to_string(),
            max_prefill: spec.cfg("max_prefill")?,
            max_seq: spec.cfg("max_seq")?,
        })
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    pub fn max_prefill(&self) -> usize {
        self.max_prefill
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Whether the device-resident transport is available.
    pub fn resident_available(&self) -> bool {
        self.resident.is_some()
    }

    /// Generate a completion for a prompt built from `segments`
    /// (BOS seg0 SEP seg1 ...). Deterministic given `rng`. Uses the
    /// device-resident transport when available, literal otherwise.
    pub fn generate(
        &self,
        segments: &[&str],
        params: &SamplingParams,
        rng: &mut Rng,
    ) -> Result<Generation> {
        self.generate_on(segments, params, rng, self.resident.is_some())
    }

    /// Generate forcing a specific transport (`resident = false` → literal
    /// path). Token streams are bit-identical across transports — gated by
    /// `rust/tests/runtime_integration.rs`.
    pub fn generate_on(
        &self,
        segments: &[&str],
        params: &SamplingParams,
        rng: &mut Rng,
        resident: bool,
    ) -> Result<Generation> {
        let mut session = self.begin_session_on(segments, params, rng.clone(), resident)?;
        while session.advance()? {}
        // Hand the advanced stream back so sequential callers see exactly
        // the pre-session RNG consumption.
        *rng = session.rng.clone();
        Ok(session.finish())
    }

    /// Start a resumable generation that *owns* everything it needs (RNG,
    /// sampling scratch, decode state buffers); the executables stay shared
    /// behind `Arc`s. Any number of sessions can be live at once — this is
    /// the substrate hook for the coordinator's decode scheduler.
    pub fn begin_session(
        &self,
        segments: &[&str],
        params: &SamplingParams,
        rng: Rng,
    ) -> Result<GenSession> {
        self.begin_session_on(segments, params, rng, self.resident.is_some())
    }

    /// `begin_session` forcing a specific transport.
    pub fn begin_session_on(
        &self,
        segments: &[&str],
        params: &SamplingParams,
        rng: Rng,
        resident: bool,
    ) -> Result<GenSession> {
        let (ids, len) = self.tokenizer.encode_prompt(segments, self.max_prefill);
        if len == 0 {
            bail!("empty prompt");
        }
        let inner = if resident {
            let set = self
                .resident
                .as_ref()
                .context("device-resident artifacts not compiled")?;
            let backend = ResidentBackend { set: Arc::clone(set), state: None };
            let s = DecodeSession::start(backend, *params, &ids, len, self.max_seq)?;
            SessionInner::Resident(s)
        } else {
            let backend = LiteralBackend {
                prefill: Arc::clone(&self.prefill),
                decode: Arc::clone(&self.decode),
                span: self.span.clone(),
                kv_spec: self.kv_spec.clone(),
                k: None,
                v: None,
            };
            let s = DecodeSession::start(backend, *params, &ids, len, self.max_seq)?;
            SessionInner::Literal(s)
        };
        Ok(GenSession { inner, rng, tokenizer: self.tokenizer.clone() })
    }
}

/// Which transport a [`GenSession`] runs on (the session owns it either way).
enum SessionInner {
    Literal(DecodeSession<LiteralBackend>),
    Resident(DecodeSession<ResidentBackend>),
}

/// A live, owned, resumable generation: [`DecodeSession`] + its private RNG
/// + the tokenizer needed to render the final text. One `advance()` call is
/// one unit of backend work, so a scheduler can round-robin many sessions
/// on the engine thread without any cross-session state.
pub struct GenSession {
    inner: SessionInner,
    rng: Rng,
    tokenizer: Tokenizer,
}

impl GenSession {
    /// One unit of decode work; `true` while work remains.
    pub fn advance(&mut self) -> Result<bool> {
        match &mut self.inner {
            SessionInner::Literal(s) => s.advance(&mut self.rng),
            SessionInner::Resident(s) => s.advance(&mut self.rng),
        }
    }

    pub fn is_done(&self) -> bool {
        match &self.inner {
            SessionInner::Literal(s) => s.is_done(),
            SessionInner::Resident(s) => s.is_done(),
        }
    }

    /// Consume the session into the finished generation.
    pub fn finish(self) -> Generation {
        let (token_ids, stats) = match self.inner {
            SessionInner::Literal(s) => s.finish(),
            SessionInner::Resident(s) => s.finish(),
        };
        Generation {
            text: self.tokenizer.decode(&token_ids),
            token_ids,
            stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut rng = Rng::new(1);
        let p = SamplingParams::greedy(8);
        assert_eq!(sample_token(&logits, &p, &mut rng), 1);
    }

    #[test]
    fn topk_restricts_support() {
        let mut logits = vec![0.0f32; 100];
        logits[7] = 5.0;
        logits[13] = 4.5;
        let p = SamplingParams { temperature: 1.0, top_k: 2, max_new_tokens: 1 };
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let t = sample_token(&logits, &p, &mut rng);
            assert!(t == 7 || t == 13, "sampled {t}");
        }
    }

    #[test]
    fn temperature_zero_equals_greedy() {
        let logits = vec![0.3, 0.1, 0.9, 0.2];
        let p = SamplingParams { temperature: 0.0, top_k: 5, max_new_tokens: 1 };
        let mut rng = Rng::new(3);
        assert_eq!(sample_token(&logits, &p, &mut rng), 2);
    }

    #[test]
    fn sampling_is_deterministic_given_seed() {
        let logits: Vec<f32> = (0..50).map(|i| ((i * 37) % 11) as f32 / 3.0).collect();
        let p = SamplingParams::default();
        let a: Vec<i32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample_token(&logits, &p, &mut rng)).collect()
        };
        let b: Vec<i32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample_token(&logits, &p, &mut rng)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch() {
        // The bounded-heap path must be a pure function of (logits, rng):
        // reusing one scratch across calls changes nothing.
        let logits: Vec<f32> = (0..200).map(|i| ((i * 53) % 17) as f32 / 4.0).collect();
        let p = SamplingParams { temperature: 0.8, top_k: 12, max_new_tokens: 1 };
        let mut scratch = SampleScratch::default();
        let reused: Vec<i32> = {
            let mut rng = Rng::new(4);
            (0..50).map(|_| sample_token_with(&logits, &p, &mut rng, &mut scratch)).collect()
        };
        let fresh: Vec<i32> = {
            let mut rng = Rng::new(4);
            (0..50).map(|_| sample_token(&logits, &p, &mut rng)).collect()
        };
        assert_eq!(reused, fresh);
    }

    #[test]
    fn topk_candidates_are_the_k_largest() {
        // NB: the heap selection replaced select_nth; candidate sets must
        // still be exactly the k largest logits.
        let logits: Vec<f32> = (0..64).map(|i| ((i * 29) % 31) as f32).collect();
        let p = SamplingParams { temperature: 1.0, top_k: 5, max_new_tokens: 1 };
        let mut top: Vec<(f32, usize)> =
            logits.iter().copied().enumerate().map(|(i, x)| (x, i)).collect();
        top.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let allowed: std::collections::HashSet<i32> =
            top[..5].iter().map(|&(_, i)| i as i32).collect();
        let mut rng = Rng::new(8);
        for _ in 0..300 {
            let t = sample_token(&logits, &p, &mut rng);
            assert!(allowed.contains(&t), "sampled non-top-k token {t}");
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let mut logits = vec![0.0f32; 10];
        logits[0] = 1.0;
        let p = SamplingParams { temperature: 100.0, top_k: 0, max_new_tokens: 1 };
        let mut rng = Rng::new(4);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..300 {
            seen.insert(sample_token(&logits, &p, &mut rng));
        }
        assert!(seen.len() >= 8, "only saw {} distinct tokens", seen.len());
    }

    // -----------------------------------------------------------------------
    // DecodeSession state machine over a scripted fake backend (no
    // artifacts): span → tail → EOS transitions and the fallback switch.
    // -----------------------------------------------------------------------

    struct FakeBackend {
        vocab: usize,
        span_width: Option<usize>,
        /// Tokens the fake emits, in order; greedy sampling reproduces them.
        script: Vec<i32>,
        emitted: usize,
        calls: Vec<String>,
    }

    impl FakeBackend {
        fn new(span_width: Option<usize>, script: Vec<i32>) -> FakeBackend {
            FakeBackend { vocab: 32, span_width, script, emitted: 0, calls: Vec::new() }
        }

        fn logits_for(&mut self) -> Vec<f32> {
            let tok = self.script[self.emitted];
            self.emitted += 1;
            let mut l = vec![0.0f32; self.vocab];
            l[tok as usize] = 10.0;
            l
        }
    }

    impl DecodeBackend for FakeBackend {
        fn span_n(&self) -> Option<usize> {
            self.span_width
        }

        fn prefill(&mut self, ids: &[i32], len: usize) -> Result<Vec<f32>> {
            assert!(ids.len() >= len);
            self.calls.push(format!("prefill({len})"));
            Ok(self.logits_for())
        }

        fn step(&mut self, token: i32, pos: i32) -> Result<Vec<f32>> {
            self.calls.push(format!("step({token},{pos})"));
            Ok(self.logits_for())
        }

        fn span(
            &mut self,
            token: i32,
            pos: i32,
            u: &[f32],
            temperature: f32,
        ) -> Result<Vec<i32>> {
            self.calls.push(format!("span({token},{pos},n={})", u.len()));
            assert_eq!(Some(u.len()), self.span_width);
            assert!(temperature >= 0.0);
            let out = self.script[self.emitted..self.emitted + u.len()].to_vec();
            self.emitted += u.len();
            Ok(out)
        }
    }

    fn drive(backend: FakeBackend, params: SamplingParams) -> (Vec<i32>, Vec<String>) {
        let ids = [1, 1, 1];
        let mut s = DecodeSession::start(backend, params, &ids, 3, 64).unwrap();
        s.run(&mut Rng::new(1)).unwrap();
        // finish() consumes the session; pull the call log out via tokens
        // first (backend moves with the session).
        let tokens = s.tokens().to_vec();
        let calls = s.backend.calls.clone();
        let (toks2, stats) = s.finish();
        assert_eq!(tokens, toks2);
        assert_eq!(stats.generated_tokens, tokens.len());
        (tokens, calls)
    }

    #[test]
    fn session_single_steps_until_eos() {
        let b = FakeBackend::new(None, vec![5, 6, EOS_ID, 9]);
        let (tokens, calls) = drive(b, SamplingParams::greedy(8));
        assert_eq!(tokens, vec![5, 6, EOS_ID]);
        assert_eq!(calls, vec!["prefill(3)", "step(5,3)", "step(6,4)"]);
    }

    #[test]
    fn session_respects_max_new() {
        let b = FakeBackend::new(None, vec![5, 6, 7, 8, 9]);
        let (tokens, calls) = drive(b, SamplingParams::greedy(3));
        assert_eq!(tokens, vec![5, 6, 7]);
        // no step issued for the final sampled token
        assert_eq!(calls, vec!["prefill(3)", "step(5,3)", "step(6,4)"]);
    }

    #[test]
    fn session_span_then_tail_transition() {
        // span width 4, max_new 7: 1 sampled + 4 fused + 2 tail steps.
        let b = FakeBackend::new(Some(4), vec![10, 11, 12, 13, 14, 15, 16]);
        let (tokens, calls) = drive(b, SamplingParams::greedy(7));
        assert_eq!(tokens, vec![10, 11, 12, 13, 14, 15, 16]);
        assert_eq!(
            calls,
            vec!["prefill(3)", "span(10,3,n=4)", "step(14,7)", "step(15,8)"]
        );
    }

    #[test]
    fn session_eos_inside_span_truncates() {
        let b = FakeBackend::new(Some(4), vec![10, 11, EOS_ID, 99, 98]);
        let (tokens, calls) = drive(b, SamplingParams::greedy(8));
        assert_eq!(tokens, vec![10, 11, EOS_ID]);
        assert_eq!(calls, vec!["prefill(3)", "span(10,3,n=4)"]);
    }

    #[test]
    fn session_span_disabled_on_topk_mismatch() {
        // fallback switch: a span-capable backend with non-matching
        // sampling params must take the single-step path only.
        let b = FakeBackend::new(Some(2), vec![10, 11, 12, 13]);
        let params = SamplingParams { temperature: 1.0, top_k: 7, max_new_tokens: 4 };
        let (tokens, calls) = drive(b, params);
        assert_eq!(tokens.len(), 4);
        assert!(
            calls.iter().all(|c| !c.starts_with("span")),
            "span must not run: {calls:?}"
        );
    }

    #[test]
    fn session_transports_agree_on_token_stream() {
        // The fallback contract in miniature: two backends (with and
        // without span support) over the same model emissions produce the
        // same stream under greedy decoding.
        let script = vec![10, 11, 12, 13, 14, 15, 16, 17];
        let spanned = FakeBackend::new(Some(4), script.clone());
        let (with_span, _) = drive(spanned, SamplingParams::greedy(8));
        let (without, _) = drive(FakeBackend::new(None, script), SamplingParams::greedy(8));
        assert_eq!(with_span, without);
    }

    #[test]
    fn interleaved_sessions_match_sequential_streams() {
        // The scheduler contract: with per-session RNGs, round-robin
        // advancing N live sessions yields bit-identical token streams to
        // running each session to completion on its own.
        let params = SamplingParams { temperature: 1.0, top_k: 7, max_new_tokens: 6 };
        let scripts: [Vec<i32>; 3] = [
            vec![10, 11, 12, 13, 14, 15],
            vec![20, 21, EOS_ID, 9, 9, 9],
            vec![5, 6, 7, 8, EOS_ID, 9],
        ];
        let sequential: Vec<Vec<i32>> = scripts
            .iter()
            .enumerate()
            .map(|(i, script)| {
                let b = FakeBackend::new(None, script.clone());
                let ids = [1, 1, 1];
                let mut s = DecodeSession::start(b, params, &ids, 3, 64).unwrap();
                let mut rng = Rng::substream(7, &format!("session/{i}"));
                s.run(&mut rng).unwrap();
                s.finish().0
            })
            .collect();
        // Same sessions, interleaved one advance() at a time.
        let ids = [1, 1, 1];
        let mut live: Vec<(DecodeSession<FakeBackend>, Rng)> = scripts
            .iter()
            .enumerate()
            .map(|(i, script)| {
                let b = FakeBackend::new(None, script.clone());
                (
                    DecodeSession::start(b, params, &ids, 3, 64).unwrap(),
                    Rng::substream(7, &format!("session/{i}")),
                )
            })
            .collect();
        while live.iter().any(|(s, _)| !s.is_done()) {
            for (s, rng) in &mut live {
                if !s.is_done() {
                    s.advance(rng).unwrap();
                }
            }
        }
        let interleaved: Vec<Vec<i32>> = live.into_iter().map(|(s, _)| s.finish().0).collect();
        assert_eq!(interleaved, sequential);
    }

    #[test]
    fn session_zero_budget_generates_nothing() {
        let b = FakeBackend::new(None, vec![5]);
        let ids = [1, 1, 1];
        // prompt_len == max_seq → max_new == 0
        let mut s = DecodeSession::start(b, SamplingParams::greedy(8), &ids, 3, 3).unwrap();
        assert!(s.is_done());
        s.run(&mut Rng::new(1)).unwrap();
        let (tokens, stats) = s.finish();
        assert!(tokens.is_empty());
        assert_eq!(stats.generated_tokens, 0);
    }
}
