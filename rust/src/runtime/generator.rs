//! Generator driver: the autoregressive loop over compiled prefill/decode
//! artifacts. Rust owns the loop and the sampling; the KV cache travels as
//! literals between steps and the prompt is never re-prefilled (DESIGN.md
//! §Perf L2).

use anyhow::{bail, Result};

use super::{to_f32_vec, Executable, HostTensor, Runtime};
use crate::tokenizer::{Tokenizer, EOS_ID};
use crate::util::Rng;

#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    /// 0.0 = greedy argmax.
    pub temperature: f32,
    /// Restrict sampling to the k most likely tokens (0 = no restriction).
    pub top_k: usize,
    pub max_new_tokens: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        // "default temperature" per the paper's Table 1 — 1.0 with a top-k
        // guard keeps the untrained substrate model's output distribution
        // from degenerating into uniform noise.
        SamplingParams { temperature: 1.0, top_k: 40, max_new_tokens: 32 }
    }
}

impl SamplingParams {
    pub fn greedy(max_new_tokens: usize) -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, max_new_tokens }
    }
}

#[derive(Clone, Debug, Default)]
pub struct GenerationStats {
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub prefill_micros: u128,
    pub decode_micros: u128,
}

#[derive(Debug)]
pub struct Generation {
    pub token_ids: Vec<i32>,
    pub text: String,
    pub stats: GenerationStats,
}

/// Sample a token id from logits. Exposed for unit testing.
pub fn sample_token(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> i32 {
    debug_assert!(!logits.is_empty());
    if params.temperature <= 0.0 {
        // greedy
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        return best as i32;
    }
    // top-k indices by logit (partial selection; k is small)
    let k = if params.top_k == 0 { logits.len() } else { params.top_k.min(logits.len()) };
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        logits[b].partial_cmp(&logits[a]).unwrap()
    });
    idx.truncate(k);
    let max = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let mut weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i] - max) / params.temperature) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return idx[0] as i32;
    }
    for w in &mut weights {
        *w /= total;
    }
    idx[rng.weighted(&weights)] as i32
}

pub struct Generator {
    prefill: std::sync::Arc<Executable>,
    decode: std::sync::Arc<Executable>,
    /// Fused multi-step decode (§Perf L2): runs N steps + in-graph top-k
    /// sampling per executable call, amortizing the KV-cache transfer.
    /// `None` when the artifact set predates spans. Only used when the
    /// sampling params match the baked-in top-k (see `SPAN_TOP_K`).
    span: Option<(usize, std::sync::Arc<Executable>)>,
    tokenizer: Tokenizer,
    pub model_name: String,
    max_prefill: usize,
    max_seq: usize,
}

/// The top-k baked into the decode-span artifact
/// (python/compile/model.py::SPAN_TOP_K).
pub const SPAN_TOP_K: usize = 40;

impl Generator {
    /// `model` is "small" or "big" (manifest model names).
    pub fn new(rt: &Runtime, model: &str) -> Result<Generator> {
        let spec = rt.manifest.model(model)?;
        // discover a decode-span artifact (name: {model}_decode{N}, N > 1)
        let span = rt
            .manifest
            .artifacts
            .keys()
            .filter_map(|name| {
                let n: usize = name
                    .strip_prefix(&format!("{model}_decode"))?
                    .parse()
                    .ok()?;
                (n > 1).then_some((n, name.clone()))
            })
            .max_by_key(|(n, _)| *n)
            // tolerate selective loading (tests compile only a subset)
            .and_then(|(n, name)| rt.executable(&name).ok().map(|e| (n, e)));
        Ok(Generator {
            prefill: rt.executable(&format!("{model}_prefill"))?,
            decode: rt.executable(&format!("{model}_decode"))?,
            span,
            tokenizer: Tokenizer::new(rt.manifest.vocab_size),
            model_name: model.to_string(),
            max_prefill: spec.cfg("max_prefill")?,
            max_seq: spec.cfg("max_seq")?,
        })
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    pub fn max_prefill(&self) -> usize {
        self.max_prefill
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Generate a completion for a prompt built from `segments`
    /// (BOS seg0 SEP seg1 ...). Deterministic given `rng`.
    pub fn generate(
        &self,
        segments: &[&str],
        params: &SamplingParams,
        rng: &mut Rng,
    ) -> Result<Generation> {
        let (ids, len) = self.tokenizer.encode_prompt(segments, self.max_prefill);
        if len == 0 {
            bail!("empty prompt");
        }
        let mut stats = GenerationStats { prompt_tokens: len, ..Default::default() };

        // --- prefill ---
        let t0 = std::time::Instant::now();
        let tok_t = HostTensor::i32(ids, &[self.max_prefill]);
        let len_t = HostTensor::i32(vec![len as i32], &[1]);
        let mut outs = self.prefill.run(&[tok_t, len_t])?;
        stats.prefill_micros = t0.elapsed().as_micros();
        let kv_spec = &self.decode.spec.inputs[2]; // k_cache spec (shape/dtype)
        let mut v_cache = HostTensor::from_literal(&outs.pop().expect("v_cache"), kv_spec)?;
        let mut k_cache = HostTensor::from_literal(&outs.pop().expect("k_cache"), kv_spec)?;
        let mut logits = to_f32_vec(&outs.pop().expect("logits"))?;

        // --- decode loop ---
        let max_new = params.max_new_tokens.min(self.max_seq - len);
        let mut generated: Vec<i32> = Vec::with_capacity(max_new);
        let t1 = std::time::Instant::now();

        // Fused span path: usable whenever the top-k matches the artifact's
        // baked-in constant (greedy works too: temperature ~ 0 collapses the
        // in-graph softmax onto the argmax).
        let use_span = self
            .span
            .as_ref()
            .map(|(n, _)| {
                max_new >= *n && (params.top_k == SPAN_TOP_K || params.temperature <= 0.0)
            })
            .unwrap_or(false);

        if use_span {
            let (span_n, span_exe) = self.span.as_ref().unwrap();
            let span_n = *span_n;
            // first sampled token comes from the prefill logits (keeps span
            // inputs uniform: span consumes the *input* token and samples n)
            let mut next = sample_token(&logits, params, rng);
            generated.push(next);
            let mut pos = len as i32;
            'outer: while generated.len() < max_new && *generated.last().unwrap() != EOS_ID
            {
                let remaining = max_new - generated.len();
                if remaining < span_n {
                    // finish with single steps
                    break;
                }
                let u: Vec<f32> = (0..span_n).map(|_| rng.f32()).collect();
                let temp = params.temperature.max(0.0);
                let inputs = [
                    HostTensor::i32(vec![next], &[1]),
                    HostTensor::i32(vec![pos], &[1]),
                    k_cache,
                    v_cache,
                    HostTensor::f32(u, &[span_n]),
                    HostTensor::f32(vec![temp], &[1]),
                ];
                let mut outs = span_exe.run(&inputs)?;
                v_cache =
                    HostTensor::from_literal(&outs.pop().expect("v_cache"), kv_spec)?;
                k_cache =
                    HostTensor::from_literal(&outs.pop().expect("k_cache"), kv_spec)?;
                let tokens = outs.pop().expect("tokens").to_vec::<i32>()?;
                for t in tokens {
                    generated.push(t);
                    pos += 1;
                    if t == EOS_ID || generated.len() >= max_new {
                        break 'outer;
                    }
                }
                next = *generated.last().unwrap();
            }
            // tail: finish any remainder with single steps
            while generated.len() < max_new && *generated.last().unwrap() != EOS_ID {
                let pos_now = (len + generated.len() - 1) as i32;
                let inputs = [
                    HostTensor::i32(vec![*generated.last().unwrap()], &[1]),
                    HostTensor::i32(vec![pos_now], &[1]),
                    k_cache,
                    v_cache,
                ];
                let mut outs = self.decode.run(&inputs)?;
                v_cache =
                    HostTensor::from_literal(&outs.pop().expect("v_cache"), kv_spec)?;
                k_cache =
                    HostTensor::from_literal(&outs.pop().expect("k_cache"), kv_spec)?;
                logits = to_f32_vec(&outs.pop().expect("logits"))?;
                generated.push(sample_token(&logits, params, rng));
            }
        } else {
            for step in 0..max_new {
                let next = sample_token(&logits, params, rng);
                generated.push(next);
                if next == EOS_ID || step + 1 == max_new {
                    break;
                }
                let pos = (len + step) as i32;
                let inputs = [
                    HostTensor::i32(vec![next], &[1]),
                    HostTensor::i32(vec![pos], &[1]),
                    k_cache,
                    v_cache,
                ];
                let mut outs = self.decode.run(&inputs)?;
                v_cache =
                    HostTensor::from_literal(&outs.pop().expect("v_cache"), kv_spec)?;
                k_cache =
                    HostTensor::from_literal(&outs.pop().expect("k_cache"), kv_spec)?;
                logits = to_f32_vec(&outs.pop().expect("logits"))?;
            }
        }
        stats.decode_micros = t1.elapsed().as_micros();
        stats.generated_tokens = generated.len();

        Ok(Generation {
            text: self.tokenizer.decode(&generated),
            token_ids: generated,
            stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut rng = Rng::new(1);
        let p = SamplingParams::greedy(8);
        assert_eq!(sample_token(&logits, &p, &mut rng), 1);
    }

    #[test]
    fn topk_restricts_support() {
        let mut logits = vec![0.0f32; 100];
        logits[7] = 5.0;
        logits[13] = 4.5;
        let p = SamplingParams { temperature: 1.0, top_k: 2, max_new_tokens: 1 };
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let t = sample_token(&logits, &p, &mut rng);
            assert!(t == 7 || t == 13, "sampled {t}");
        }
    }

    #[test]
    fn temperature_zero_equals_greedy() {
        let logits = vec![0.3, 0.1, 0.9, 0.2];
        let p = SamplingParams { temperature: 0.0, top_k: 5, max_new_tokens: 1 };
        let mut rng = Rng::new(3);
        assert_eq!(sample_token(&logits, &p, &mut rng), 2);
    }

    #[test]
    fn sampling_is_deterministic_given_seed() {
        let logits: Vec<f32> = (0..50).map(|i| ((i * 37) % 11) as f32 / 3.0).collect();
        let p = SamplingParams::default();
        let a: Vec<i32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample_token(&logits, &p, &mut rng)).collect()
        };
        let b: Vec<i32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample_token(&logits, &p, &mut rng)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let mut logits = vec![0.0f32; 10];
        logits[0] = 1.0;
        let p = SamplingParams { temperature: 100.0, top_k: 0, max_new_tokens: 1 };
        let mut rng = Rng::new(4);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..300 {
            seen.insert(sample_token(&logits, &p, &mut rng));
        }
        assert!(seen.len() >= 8, "only saw {} distinct tokens", seen.len());
    }
}
