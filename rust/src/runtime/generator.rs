//! Generator driver: the autoregressive loop over compiled prefill/decode
//! artifacts. Rust owns the loop and the sampling; the transport behind the
//! loop is pluggable (DESIGN.md §Perf L2):
//!
//! * [`ResidentBackend`] — the decode state (KV caches ‖ logits tail) lives
//!   in a single packed device buffer that each step feeds straight back
//!   into the next `run_raw` call. Only the logits (or span token ids) and
//!   the scalar step inputs ever cross the host boundary: O(vocab) per
//!   step instead of O(KV bytes).
//! * [`LiteralBackend`] — the pre-resident behavior: every step fetches the
//!   full KV tuple to host literals and re-uploads it. Kept as the
//!   automatic fallback (old artifact sets, `[runtime] device_resident =
//!   false`) and as the reference for the bit-identity gate in
//!   `rust/tests/runtime_integration.rs`.
//!
//! [`DecodeSession`] is the transport-independent state machine driving
//! sampling and the span/single-step/tail transitions; both backends must
//! produce bit-identical token streams through it.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::prefix_cache::{PrefixCache, PrefixHandle};
use super::{to_f32_vec, ExecArg, Executable, HostTensor, IoSpec, Runtime};
use crate::tokenizer::{Tokenizer, EOS_ID};
use crate::util::Rng;

#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    /// 0.0 = greedy argmax.
    pub temperature: f32,
    /// Restrict sampling to the k most likely tokens (0 = no restriction).
    pub top_k: usize,
    pub max_new_tokens: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        // "default temperature" per the paper's Table 1 — 1.0 with a top-k
        // guard keeps the untrained substrate model's output distribution
        // from degenerating into uniform noise.
        SamplingParams { temperature: 1.0, top_k: 40, max_new_tokens: 32 }
    }
}

impl SamplingParams {
    pub fn greedy(max_new_tokens: usize) -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, max_new_tokens }
    }
}

#[derive(Clone, Debug, Default)]
pub struct GenerationStats {
    pub prompt_tokens: usize,
    /// Prompt tokens restored from the cross-request KV prefix cache
    /// instead of recomputed (0 = cold prefill).
    pub restored_tokens: usize,
    pub generated_tokens: usize,
    pub prefill_micros: u128,
    pub decode_micros: u128,
    /// Which transport served the decode loop (resident vs literal).
    pub device_resident: bool,
}

#[derive(Debug)]
pub struct Generation {
    pub token_ids: Vec<i32>,
    pub text: String,
    pub stats: GenerationStats,
}

// ---------------------------------------------------------------------------
// Sampling
// ---------------------------------------------------------------------------

/// Reusable scratch for [`sample_token_with`]: the bounded top-k candidate
/// buffer and the softmax weights. One instance per decode session
/// amortizes both allocations over every sampled token (the previous
/// implementation built a full-vocab index `Vec` plus a weights `Vec` per
/// token).
#[derive(Clone, Debug, Default)]
pub struct SampleScratch {
    cand: Vec<(f32, u32)>,
    weights: Vec<f64>,
}

/// Candidate priority: higher logit wins, ties break toward the lower token
/// id. Returns true when `a` ranks strictly below `b`. (A total order —
/// unlike the old `select_nth` partial selection, whose candidate *set*
/// this reproduces but whose internal ordering was unspecified; the
/// distribution-level unit tests below hold for both.)
#[inline]
fn cand_below(a: (f32, u32), b: (f32, u32)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && a.1 > b.1)
}

/// Sample a token id from logits. Exposed for unit testing.
pub fn sample_token(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> i32 {
    sample_token_with(logits, params, rng, &mut SampleScratch::default())
}

/// Allocation-free top-k sampling: a bounded k-element min-heap over the
/// logits (k ≤ 40 on every configured path) in caller-provided scratch,
/// then an inverse-CDF draw over the k candidates in (logit desc, id asc)
/// order.
pub fn sample_token_with(
    logits: &[f32],
    params: &SamplingParams,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) -> i32 {
    debug_assert!(!logits.is_empty());
    if params.temperature <= 0.0 {
        // greedy
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        return best as i32;
    }
    let k = if params.top_k == 0 { logits.len() } else { params.top_k.min(logits.len()) };
    let cand = &mut scratch.cand;
    cand.clear();
    if k == logits.len() {
        // unrestricted sampling: every token is a candidate, natural order
        cand.extend(logits.iter().enumerate().map(|(i, &x)| (x, i as u32)));
    } else {
        // Bounded min-heap: root is the weakest of the current k candidates;
        // a new logit enters only by beating the root. O(n log k), no alloc.
        for (i, &x) in logits.iter().enumerate() {
            let c = (x, i as u32);
            if cand.len() < k {
                cand.push(c);
                let mut j = cand.len() - 1;
                while j > 0 {
                    let parent = (j - 1) / 2;
                    if cand_below(cand[j], cand[parent]) {
                        cand.swap(j, parent);
                        j = parent;
                    } else {
                        break;
                    }
                }
            } else if cand_below(cand[0], c) {
                cand[0] = c;
                let mut j = 0usize;
                loop {
                    let l = 2 * j + 1;
                    let r = l + 1;
                    let mut m = j;
                    if l < cand.len() && cand_below(cand[l], cand[m]) {
                        m = l;
                    }
                    if r < cand.len() && cand_below(cand[r], cand[m]) {
                        m = r;
                    }
                    if m == j {
                        break;
                    }
                    cand.swap(j, m);
                    j = m;
                }
            }
        }
        cand.sort_unstable_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
    }
    let max = cand.iter().map(|c| c.0).fold(f32::NEG_INFINITY, f32::max);
    let weights = &mut scratch.weights;
    weights.clear();
    weights.extend(cand.iter().map(|c| (((c.0 - max) / params.temperature) as f64).exp()));
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return cand[0].1 as i32;
    }
    cand[rng.weighted(weights)].1 as i32
}

/// The top-k baked into the decode-span artifact
/// (python/compile/model.py::SPAN_TOP_K).
pub const SPAN_TOP_K: usize = 40;

// ---------------------------------------------------------------------------
// Decode backends (transports)
// ---------------------------------------------------------------------------

/// What the decode state machine needs from a transport: one prompt pass,
/// single steps that surface logits for host-side sampling, and optionally
/// fused spans that sample in-graph. Implemented by [`LiteralBackend`],
/// [`ResidentBackend`], and by fakes in unit tests.
pub trait DecodeBackend {
    /// Fused span width, if span execution is available.
    fn span_n(&self) -> Option<usize>;

    /// Whether this transport keeps the decode state on device.
    fn device_resident(&self) -> bool {
        false
    }

    /// Run the prompt pass (`ids` padded, `len` live tokens); returns the
    /// next-token logits.
    fn prefill(&mut self, ids: &[i32], len: usize) -> Result<Vec<f32>>;

    /// Prefix lengths this transport compiled resume artifacts for
    /// (ascending; empty = cross-request prefix reuse unsupported).
    fn resume_chunks(&self) -> Vec<usize> {
        Vec::new()
    }

    /// Run the prompt pass restoring the first `prefix` positions from
    /// `state` — a packed `k ‖ v ‖ tail` snapshot of an earlier prefill
    /// whose prompt shared those tokens — and recomputing only the suffix.
    /// Bit-identical to [`Self::prefill`] by construction (gated in
    /// python/tests/test_resume.py).
    fn prefill_resumed(
        &mut self,
        _ids: &[i32],
        _len: usize,
        _state: &[f32],
        _prefix: usize,
    ) -> Result<Vec<f32>> {
        bail!("resume-capable prefill not supported by this transport")
    }

    /// Fetch the packed post-prefill state for insertion into the prefix
    /// cache; `Ok(None)` = snapshots unsupported (literal transport).
    fn snapshot_state(&mut self) -> Result<Option<Vec<f32>>> {
        Ok(None)
    }

    /// One decode step: consume `token` at position `pos`, return logits.
    fn step(&mut self, token: i32, pos: i32) -> Result<Vec<f32>>;

    /// Fused span: consume `token` at `pos`, run `u.len()` steps sampling
    /// in-graph (one uniform per token) at `temperature`; returns the
    /// sampled token ids.
    fn span(&mut self, token: i32, pos: i32, u: &[f32], temperature: f32) -> Result<Vec<i32>>;
}

/// The prefix-cache interaction for one prompt pass, shared by the
/// per-session ([`DecodeSession`]) and batched ([`BatchedDecode`])
/// admission paths: probe for the deepest resumable prefix *before* the
/// prefill, then decide whether the freshly computed state is worth
/// snapshotting back — a miss, or a hit shallower than a chunk boundary
/// the prompt covers (so the next request can resume deeper).
struct PrefixPlan<'a> {
    cache: Option<&'a Rc<RefCell<PrefixCache>>>,
    /// Pinned basis state to resume from (`None` = cold prefill).
    hit: Option<PrefixHandle>,
    /// Chunk depths to (re)insert the post-prefill snapshot at.
    insert_at: Vec<usize>,
    ids: &'a [i32],
}

impl<'a> PrefixPlan<'a> {
    /// `ids` must be the *live* prompt tokens (no padding): the radix key
    /// and the strict-prefix rule are both relative to the real length.
    fn probe(
        cache: Option<&'a Rc<RefCell<PrefixCache>>>,
        chunks: &[usize],
        ids: &'a [i32],
    ) -> PrefixPlan<'a> {
        let mut plan = PrefixPlan { cache, hit: None, insert_at: Vec::new(), ids };
        let Some(cache) = plan.cache else {
            return plan;
        };
        if chunks.is_empty() {
            // Resume-incapable transport: stay out of the cache entirely so
            // hit/miss stats keep meaning "resume served / not served".
            return plan;
        }
        plan.hit = PrefixCache::lookup_within(cache, ids, Some(chunks));
        let covered = plan.hit.as_ref().map_or(0, |h| h.depth());
        if chunks.iter().any(|&p| p < ids.len() && p > covered) {
            // One snapshot serves every chunk depth below the prompt length
            // (a resume at P reads only K/V[:, :P]), so register the shared
            // `Rc` at all of them; re-inserts only refresh LRU position.
            plan.insert_at = chunks.iter().copied().filter(|&p| p < ids.len()).collect();
        }
        plan
    }

    fn should_snapshot(&self) -> bool {
        !self.insert_at.is_empty()
    }

    fn insert(&self, state: Vec<f32>) {
        let Some(cache) = self.cache else { return };
        let rc = Rc::new(state);
        let mut c = cache.borrow_mut();
        for &p in &self.insert_at {
            c.insert(&self.ids[..p], Rc::clone(&rc));
        }
    }
}

/// Host-literal transport: the KV tuple round-trips device→host→device on
/// every step — O(KV bytes) per token. The automatic fallback when the
/// resident artifact set is absent, and the reference for the bit-identity
/// gate.
pub struct LiteralBackend {
    prefill: Arc<Executable>,
    decode: Arc<Executable>,
    span: Option<(usize, Arc<Executable>)>,
    kv_spec: IoSpec,
    k: Option<HostTensor>,
    v: Option<HostTensor>,
}

impl LiteralBackend {
    /// Pop the trailing `[.., k_cache, v_cache]` outputs into host tensors
    /// (every literal decode artifact ends its output tuple this way).
    fn store_kv(&mut self, outs: &mut Vec<xla::Literal>, what: &str) -> Result<()> {
        let v = outs.pop().with_context(|| format!("{what} missing v_cache"))?;
        let k = outs.pop().with_context(|| format!("{what} missing k_cache"))?;
        self.v = Some(HostTensor::from_literal(&v, &self.kv_spec)?);
        self.k = Some(HostTensor::from_literal(&k, &self.kv_spec)?);
        Ok(())
    }
}

impl DecodeBackend for LiteralBackend {
    fn span_n(&self) -> Option<usize> {
        self.span.as_ref().map(|(n, _)| *n)
    }

    fn prefill(&mut self, ids: &[i32], len: usize) -> Result<Vec<f32>> {
        let tok_t = HostTensor::i32(ids.to_vec(), &[ids.len()]);
        let len_t = HostTensor::i32(vec![len as i32], &[1]);
        let mut outs = self.prefill.run(&[tok_t, len_t])?;
        self.store_kv(&mut outs, "prefill")?;
        to_f32_vec(&outs.pop().context("prefill logits")?)
    }

    fn step(&mut self, token: i32, pos: i32) -> Result<Vec<f32>> {
        let k = self.k.take().context("decode step before prefill")?;
        let v = self.v.take().context("decode step before prefill")?;
        let inputs = [
            HostTensor::i32(vec![token], &[1]),
            HostTensor::i32(vec![pos], &[1]),
            k,
            v,
        ];
        let mut outs = self.decode.run(&inputs)?;
        self.store_kv(&mut outs, "decode")?;
        to_f32_vec(&outs.pop().context("decode logits")?)
    }

    fn span(&mut self, token: i32, pos: i32, u: &[f32], temperature: f32) -> Result<Vec<i32>> {
        let (_, exe) = self.span.as_ref().context("span artifact not compiled")?;
        let k = self.k.take().context("span before prefill")?;
        let v = self.v.take().context("span before prefill")?;
        let inputs = [
            HostTensor::i32(vec![token], &[1]),
            HostTensor::i32(vec![pos], &[1]),
            k,
            v,
            HostTensor::f32(u.to_vec(), &[u.len()]),
            HostTensor::f32(vec![temperature], &[1]),
        ];
        let mut outs = exe.run(&inputs)?;
        self.store_kv(&mut outs, "span")?;
        Ok(outs.pop().context("span tokens")?.to_vec::<i32>()?)
    }
}

/// The fused span pieces of a resident artifact set.
struct SpanSet {
    n: usize,
    exe: Arc<Executable>,
    /// `{model}_peek_tokens{n}`: slices the sampled ids out of the packed
    /// state — the only thing fetched per span, O(span_n).
    peek: Arc<Executable>,
}

/// The compiled artifact set for device-resident decode: single-root
/// packed-state executables (state = k ‖ v ‖ tail; see
/// python/compile/model.py `state_len`).
pub struct ResidentSet {
    prefill: Arc<Executable>,
    decode: Arc<Executable>,
    /// `{model}_peek_logits`: slices the logits tail out of the packed
    /// state — the only thing fetched per single step, O(vocab).
    peek_logits: Arc<Executable>,
    span: Option<SpanSet>,
    /// `{model}_prefill_resume{P}` executables by ascending chunk length P:
    /// prefill restoring K/V[:, :P] from a cached packed state and
    /// recomputing only the suffix. Empty on pre-resume artifact dirs.
    resume: Vec<(usize, Arc<Executable>)>,
}

/// Device-resident transport: the packed decode state lives in one PJRT
/// buffer that is fed straight back into the next step. Per-step host
/// traffic is the scalar inputs up and the logits (or span ids) down; the
/// KV cache never crosses.
///
/// The backend *owns* its state buffer (the executables are shared via
/// `Arc`), so any number of resident sessions can be in flight at once —
/// the decode scheduler interleaves them on the engine thread.
pub struct ResidentBackend {
    set: Arc<ResidentSet>,
    state: Option<xla::PjRtBuffer>,
}

impl ResidentBackend {
    fn take_output(&mut self, mut outs: Vec<xla::PjRtBuffer>, what: &str) -> Result<()> {
        if outs.is_empty() {
            bail!("{what} produced no output buffer");
        }
        // The freshly produced state replaces the previous one; dropping
        // the old buffer releases its device memory.
        self.state = Some(outs.remove(0));
        Ok(())
    }

    fn peek_logits(&self) -> Result<Vec<f32>> {
        let state = self.state.as_ref().context("no resident decode state")?;
        let outs = self.set.peek_logits.run_raw(&[ExecArg::Device(state)])?;
        let lit = outs.first().context("peek_logits produced no output")?.to_literal_sync()?;
        to_f32_vec(&lit)
    }
}

impl DecodeBackend for ResidentBackend {
    fn span_n(&self) -> Option<usize> {
        self.set.span.as_ref().map(|s| s.n)
    }

    fn device_resident(&self) -> bool {
        true
    }

    fn prefill(&mut self, ids: &[i32], len: usize) -> Result<Vec<f32>> {
        let len_in = [len as i32];
        let outs = self.set.prefill.run_raw(&[ExecArg::I32(ids), ExecArg::I32(&len_in)])?;
        self.take_output(outs, "resident prefill")?;
        self.peek_logits()
    }

    fn resume_chunks(&self) -> Vec<usize> {
        self.set.resume.iter().map(|(p, _)| *p).collect()
    }

    fn prefill_resumed(
        &mut self,
        ids: &[i32],
        len: usize,
        state: &[f32],
        prefix: usize,
    ) -> Result<Vec<f32>> {
        let exe = self
            .set
            .resume
            .iter()
            .find(|(p, _)| *p == prefix)
            .map(|(_, e)| Arc::clone(e))
            .with_context(|| format!("no resume artifact for prefix {prefix}"))?;
        let len_in = [len as i32];
        let outs = exe.run_raw(&[
            ExecArg::I32(ids),
            ExecArg::I32(&len_in),
            ExecArg::F32(state),
        ])?;
        self.take_output(outs, "resident prefill_resume")?;
        self.peek_logits()
    }

    fn snapshot_state(&mut self) -> Result<Option<Vec<f32>>> {
        let state = match self.state.as_ref() {
            Some(s) => s,
            None => return Ok(None),
        };
        // `to_literal_sync` borrows — the resident buffer stays on device.
        let lit = state.to_literal_sync()?;
        Ok(Some(to_f32_vec(&lit)?))
    }

    fn step(&mut self, token: i32, pos: i32) -> Result<Vec<f32>> {
        let state = self.state.take().context("decode step before prefill")?;
        let tok_in = [token];
        let pos_in = [pos];
        let outs = self.set.decode.run_raw(&[
            ExecArg::I32(&tok_in),
            ExecArg::I32(&pos_in),
            ExecArg::Device(&state),
        ])?;
        self.take_output(outs, "resident decode")?;
        self.peek_logits()
    }

    fn span(&mut self, token: i32, pos: i32, u: &[f32], temperature: f32) -> Result<Vec<i32>> {
        let sp = self.set.span.as_ref().context("span artifacts not compiled")?;
        let state = self.state.take().context("span before prefill")?;
        let tok_in = [token];
        let pos_in = [pos];
        let temp_in = [temperature];
        let outs = sp.exe.run_raw(&[
            ExecArg::I32(&tok_in),
            ExecArg::I32(&pos_in),
            ExecArg::Device(&state),
            ExecArg::F32(u),
            ExecArg::F32(&temp_in),
        ])?;
        self.take_output(outs, "resident span")?;
        let state = self.state.as_ref().expect("state just stored");
        let toks = sp.peek.run_raw(&[ExecArg::Device(state)])?;
        let lit = toks.first().context("peek_tokens produced no output")?.to_literal_sync()?;
        Ok(lit.to_vec::<i32>()?)
    }
}

// ---------------------------------------------------------------------------
// Slot-based batched resident decode (vLLM/Orca-style continuous batching).
//
// A [`BatchedDecode`] pool owns ONE device buffer `state[B * state_len]`
// carved into B slots. Sessions claim a slot at prefill time (the
// `{m}_prefill_scatter{B}` artifact writes their packed k ‖ v ‖ tail into
// the slot) and free it at EOS; one `{m}_decode_batch{B}_res` call per
// fairness round consumes per-slot `tokens[B]` / `pos[B]` plus an
// `active[B]` mask and advances every live slot together — O(1) device
// dispatches per round instead of O(S).
//
// The collective advance hides behind the per-session `advance()` protocol
// via *round credits*: the first session of a scheduler sweep to call
// `advance` triggers one batched round (host-sample every slot's pending
// logits, one masked batch dispatch, one O(B·vocab) logits fetch) and
// every other advanced slot banks a credit; peers' `advance` calls then
// consume their credit for free. The scheduler needs no batching-specific
// code path — its existing round-robin emerges as one dispatch per round.
// ---------------------------------------------------------------------------

/// The device transport behind a [`BatchedDecode`] pool: claim-slot prefill,
/// one masked step for all slots, and the batched logits fetch. Implemented
/// by [`PjrtBatchEngine`] over compiled artifacts and by fakes in tests
/// (which is also how dispatch counts are asserted).
pub trait BatchEngine {
    /// Number of slots (the compiled batch width B).
    fn slots(&self) -> usize;

    /// Run one prompt through prefill and scatter its packed state into
    /// `slot`. Every other slot's state is untouched.
    fn prefill(&mut self, slot: usize, ids: &[i32], len: usize) -> Result<()>;

    /// Prefix lengths this engine compiled scatter-resume artifacts for
    /// (ascending; empty = cross-request prefix reuse unsupported).
    fn resume_chunks(&self) -> Vec<usize> {
        Vec::new()
    }

    /// [`Self::prefill`] restoring the first `prefix` positions of `slot`
    /// from a cached packed single-slot `state` and recomputing only the
    /// suffix. Every other slot's state is untouched.
    fn prefill_resumed(
        &mut self,
        _slot: usize,
        _ids: &[i32],
        _len: usize,
        _state: &[f32],
        _prefix: usize,
    ) -> Result<()> {
        bail!("resume-capable prefill not supported by this engine")
    }

    /// Fetch `slot`'s packed post-prefill state for insertion into the
    /// prefix cache; `Ok(None)` = snapshots unsupported.
    fn snapshot_slot(&mut self, _slot: usize) -> Result<Option<Vec<f32>>> {
        Ok(None)
    }

    /// One masked decode step: slot `i` consumes `tokens[i]` at `pos[i]`
    /// when `active[i] != 0`, and rides through unchanged otherwise.
    fn step(&mut self, tokens: &[i32], pos: &[i32], active: &[i32]) -> Result<()>;

    /// Fetch every slot's logits tail: `[slots * vocab]`, slot-major.
    fn peek(&mut self) -> Result<Vec<f32>>;
}

/// The compiled artifact set for one batch-width bucket.
pub struct BatchArtifacts {
    /// Slot count B baked into the artifacts.
    pub batch: usize,
    /// Packed per-slot state width (k ‖ v ‖ tail).
    pub state_len: usize,
    /// Vocab width of the peeked logits rows.
    pub vocab: usize,
    prefill_scatter: Arc<Executable>,
    decode: Arc<Executable>,
    peek: Arc<Executable>,
    /// `{model}_prefill_scatter_resume{B}_{P}` executables by ascending
    /// chunk length P. Empty on pre-resume artifact dirs.
    resume: Vec<(usize, Arc<Executable>)>,
}

/// PJRT-backed [`BatchEngine`]: the batched state lives in one device
/// buffer fed straight back into the next call; per-round host traffic is
/// the scalar slot inputs up and `B * vocab` logits down.
pub struct PjrtBatchEngine {
    set: Arc<BatchArtifacts>,
    state: Option<xla::PjRtBuffer>,
}

impl PjrtBatchEngine {
    fn store(&mut self, mut outs: Vec<xla::PjRtBuffer>, what: &str) -> Result<()> {
        if outs.is_empty() {
            bail!("{what} produced no output buffer");
        }
        self.state = Some(outs.remove(0));
        Ok(())
    }
}

impl BatchEngine for PjrtBatchEngine {
    fn slots(&self) -> usize {
        self.set.batch
    }

    fn prefill(&mut self, slot: usize, ids: &[i32], len: usize) -> Result<()> {
        let len_in = [len as i32];
        let slot_in = [slot as i32];
        let outs = match self.state.take() {
            Some(state) => self.set.prefill_scatter.run_raw(&[
                ExecArg::I32(ids),
                ExecArg::I32(&len_in),
                ExecArg::I32(&slot_in),
                ExecArg::Device(&state),
            ])?,
            None => {
                // First claim ever: seed the batched state with zeros. One
                // host upload for the pool's lifetime — every later call
                // feeds the previous output buffer back.
                let zeros = vec![0.0f32; self.set.batch * self.set.state_len];
                self.set.prefill_scatter.run_raw(&[
                    ExecArg::I32(ids),
                    ExecArg::I32(&len_in),
                    ExecArg::I32(&slot_in),
                    ExecArg::F32(&zeros),
                ])?
            }
        };
        self.store(outs, "prefill_scatter")
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32], active: &[i32]) -> Result<()> {
        let state = self.state.take().context("batched step before any prefill")?;
        let outs = self.set.decode.run_raw(&[
            ExecArg::I32(tokens),
            ExecArg::I32(pos),
            ExecArg::I32(active),
            ExecArg::Device(&state),
        ])?;
        self.store(outs, "decode_batch")
    }

    fn resume_chunks(&self) -> Vec<usize> {
        self.set.resume.iter().map(|(p, _)| *p).collect()
    }

    fn prefill_resumed(
        &mut self,
        slot: usize,
        ids: &[i32],
        len: usize,
        state: &[f32],
        prefix: usize,
    ) -> Result<()> {
        let exe = self
            .set
            .resume
            .iter()
            .find(|(p, _)| *p == prefix)
            .map(|(_, e)| Arc::clone(e))
            .with_context(|| format!("no scatter-resume artifact for prefix {prefix}"))?;
        let len_in = [len as i32];
        let slot_in = [slot as i32];
        let outs = match self.state.take() {
            Some(batch) => exe.run_raw(&[
                ExecArg::I32(ids),
                ExecArg::I32(&len_in),
                ExecArg::I32(&slot_in),
                ExecArg::F32(state),
                ExecArg::Device(&batch),
            ])?,
            None => {
                // Same first-claim seeding as the cold scatter path.
                let zeros = vec![0.0f32; self.set.batch * self.set.state_len];
                exe.run_raw(&[
                    ExecArg::I32(ids),
                    ExecArg::I32(&len_in),
                    ExecArg::I32(&slot_in),
                    ExecArg::F32(state),
                    ExecArg::F32(&zeros),
                ])?
            }
        };
        self.store(outs, "prefill_scatter_resume")
    }

    fn snapshot_slot(&mut self, slot: usize) -> Result<Option<Vec<f32>>> {
        let state = match self.state.as_ref() {
            Some(s) => s,
            None => return Ok(None),
        };
        // One O(B · state_len) fetch; the device buffer stays resident.
        let lit = state.to_literal_sync()?;
        let all = to_f32_vec(&lit)?;
        let w = self.set.state_len;
        Ok(Some(all[slot * w..(slot + 1) * w].to_vec()))
    }

    fn peek(&mut self) -> Result<Vec<f32>> {
        let state = self.state.as_ref().context("no batched decode state")?;
        let outs = self.set.peek.run_raw(&[ExecArg::Device(state)])?;
        let lit = outs
            .first()
            .context("peek_logits_batch produced no output")?
            .to_literal_sync()?;
        to_f32_vec(&lit)
    }
}

/// One live slot of a [`BatchedDecode`] pool. Sampling state is fully
/// per-slot (own RNG, own scratch), so the token stream stays a pure
/// function of the request — batched ≡ sequential bit for bit.
struct SlotState {
    params: SamplingParams,
    rng: Rng,
    scratch: SampleScratch,
    prompt_len: usize,
    max_new: usize,
    generated: Vec<i32>,
    /// Logits awaiting a host-side sample (from prefill or the last round).
    pending: Option<Vec<f32>>,
    /// Rounds this slot was advanced in that its owner has not yet
    /// observed via `advance()` — the collective-advance bookkeeping.
    credits: u32,
    done: bool,
    /// Set when a collective round this slot rode failed: the packed device
    /// state can no longer be trusted for it. The owner observes the stored
    /// error on its next `advance()`/`finish()` and the slot is reclaimed.
    failed: Option<String>,
    stats: GenerationStats,
}

/// Slot pool driving B concurrent single-step decodes through one
/// [`BatchEngine`]. Sessions admit into a free slot, the owner (one
/// [`crate::llm::LlmSession`] per slot) calls `advance(slot)` round-robin,
/// and the pool turns each sweep into exactly one masked batch dispatch.
pub struct BatchedDecode<E: BatchEngine> {
    engine: E,
    vocab: usize,
    max_seq: usize,
    slots: Vec<Option<SlotState>>,
    tokens: Vec<i32>,
    pos: Vec<i32>,
    active: Vec<i32>,
    /// Lifetime batched decode dispatches (the `batched_steps` stat).
    dispatches: u64,
    /// Sum of active slot counts over all dispatches (mean occupancy).
    active_slot_sum: u64,
}

impl<E: BatchEngine> BatchedDecode<E> {
    pub fn new(engine: E, vocab: usize, max_seq: usize) -> BatchedDecode<E> {
        let b = engine.slots();
        BatchedDecode {
            engine,
            vocab,
            max_seq,
            slots: (0..b).map(|_| None).collect(),
            tokens: vec![0; b],
            pos: vec![0; b],
            active: vec![0; b],
            dispatches: 0,
            active_slot_sum: 0,
        }
    }

    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    pub fn dispatches(&self) -> u64 {
        self.dispatches
    }

    pub fn active_slot_sum(&self) -> u64 {
        self.active_slot_sum
    }

    /// The transport behind this pool (dispatch-count assertions in tests).
    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// Claim a free slot and run the prompt through the scatter prefill.
    /// Returns `None` when every slot is occupied (callers fall back to a
    /// per-session backend); admission into a freed slot can happen at any
    /// time — the next round simply includes it (mid-flight admission).
    pub fn admit(
        &mut self,
        ids: &[i32],
        prompt_len: usize,
        params: SamplingParams,
        rng: Rng,
    ) -> Result<Option<usize>> {
        self.admit_prefixed(ids, prompt_len, params, rng, None)
    }

    /// [`Self::admit`] with a cross-request KV prefix cache: a hit runs the
    /// scatter-resume artifact for the claimed slot (only the suffix is
    /// recomputed), a qualifying cold prefill snapshots the slot's packed
    /// state back into the cache. Streams are bit-identical either way
    /// (python/tests/test_resume.py).
    pub fn admit_prefixed(
        &mut self,
        ids: &[i32],
        prompt_len: usize,
        params: SamplingParams,
        rng: Rng,
        cache: Option<&Rc<RefCell<PrefixCache>>>,
    ) -> Result<Option<usize>> {
        if prompt_len == 0 {
            bail!("empty prompt");
        }
        let slot = match self.slots.iter().position(|s| s.is_none()) {
            Some(s) => s,
            None => return Ok(None),
        };
        let t0 = std::time::Instant::now();
        let chunks = self.engine.resume_chunks();
        let plan = PrefixPlan::probe(cache, &chunks, &ids[..prompt_len]);
        let restored = match &plan.hit {
            Some(h) => {
                self.engine.prefill_resumed(slot, ids, prompt_len, h.state(), h.depth())?;
                h.depth()
            }
            None => {
                self.engine.prefill(slot, ids, prompt_len)?;
                0
            }
        };
        if plan.should_snapshot() {
            if let Some(state) = self.engine.snapshot_slot(slot)? {
                plan.insert(state);
            }
        }
        drop(plan); // release the pin: the basis state has been consumed
        let all = self.engine.peek()?;
        let logits = all[slot * self.vocab..(slot + 1) * self.vocab].to_vec();
        let max_new = params.max_new_tokens.min(self.max_seq.saturating_sub(prompt_len));
        let stats = GenerationStats {
            prompt_tokens: prompt_len,
            restored_tokens: restored,
            prefill_micros: t0.elapsed().as_micros(),
            device_resident: true,
            ..Default::default()
        };
        self.slots[slot] = Some(SlotState {
            params,
            rng,
            scratch: SampleScratch::default(),
            prompt_len,
            max_new,
            generated: Vec::with_capacity(max_new),
            pending: (max_new > 0).then_some(logits),
            credits: 0,
            done: max_new == 0,
            failed: None,
            stats,
        });
        Ok(Some(slot))
    }

    fn slot_mut(&mut self, slot: usize) -> Result<&mut SlotState> {
        self.slots
            .get_mut(slot)
            .and_then(|s| s.as_mut())
            .with_context(|| format!("slot {slot} is not live"))
    }

    /// One unit of decode work for `slot`; `true` while work remains.
    ///
    /// If the slot was already advanced by a round a peer triggered this
    /// sweep, the banked credit is consumed for free; otherwise one
    /// collective round runs — every live slot gets sampled and stepped in
    /// a single batch dispatch.
    pub fn advance(&mut self, slot: usize) -> Result<bool> {
        {
            let s = self.slot_mut(slot)?;
            if let Some(msg) = &s.failed {
                bail!("{msg}");
            }
            if s.done {
                return Ok(false);
            }
            if s.credits > 0 {
                s.credits -= 1;
                return Ok(true);
            }
        }
        self.run_round()?;
        let s = self.slot_mut(slot)?;
        // The triggering slot's share of the round is this very call.
        if s.credits > 0 {
            s.credits -= 1;
        }
        Ok(!s.done)
    }

    /// One collective round: host-sample every slot holding fresh logits,
    /// then advance all still-live slots in ONE masked batch dispatch and
    /// ONE batched logits fetch.
    fn run_round(&mut self) -> Result<()> {
        let t0 = std::time::Instant::now();
        // 1) sample phase (host, per-slot RNG — order-independent)
        for s in self.slots.iter_mut().flatten() {
            if s.done {
                continue;
            }
            let logits = match s.pending.take() {
                Some(l) => l,
                None => continue,
            };
            let tok = sample_token_with(&logits, &s.params, &mut s.rng, &mut s.scratch);
            s.generated.push(tok);
            if tok == EOS_ID || s.generated.len() >= s.max_new {
                s.done = true;
            }
        }
        // 2) gather every still-live slot into the masked step inputs
        for i in 0..self.slots.len() {
            self.tokens[i] = 0;
            self.pos[i] = 0;
            self.active[i] = 0;
        }
        let mut n_active = 0u64;
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(s) = s {
                if !s.done {
                    self.tokens[i] = *s.generated.last().expect("live slot has a token");
                    self.pos[i] = (s.prompt_len + s.generated.len() - 1) as i32;
                    self.active[i] = 1;
                    n_active += 1;
                }
            }
        }
        if n_active == 0 {
            return Ok(());
        }
        // 3) one dispatch + one fetch for everyone
        let fetched = self
            .engine
            .step(&self.tokens, &self.pos, &self.active)
            .and_then(|()| self.engine.peek());
        let all = match fetched {
            Ok(all) => all,
            Err(e) => {
                // Poison every slot that rode the failed round: the packed
                // device state is stale for all of them (a collective
                // dispatch has no per-slot failure isolation). Each owner
                // observes the stored error on its next advance()/finish()
                // and its slot is reclaimed; idle slots are untouched, and
                // the next admission reseeds the device state from zeros.
                let msg = format!("batched decode round failed: {e:#}");
                for (i, s) in self.slots.iter_mut().enumerate() {
                    if self.active[i] == 0 {
                        continue;
                    }
                    let s = s.as_mut().expect("active slot is live");
                    s.done = true;
                    s.failed = Some(msg.clone());
                }
                bail!(msg);
            }
        };
        self.dispatches += 1;
        self.active_slot_sum += n_active;
        let round_micros = t0.elapsed().as_micros();
        for (i, s) in self.slots.iter_mut().enumerate() {
            if self.active[i] == 0 {
                continue;
            }
            let s = s.as_mut().expect("active slot is live");
            s.pending = Some(all[i * self.vocab..(i + 1) * self.vocab].to_vec());
            s.credits += 1;
            // Occupancy semantics (like the scheduler's gen_micros): each
            // participant shared this round's wall time.
            s.stats.decode_micros += round_micros;
        }
        Ok(())
    }

    pub fn is_done(&self, slot: usize) -> bool {
        match self.slots.get(slot).and_then(|s| s.as_ref()) {
            Some(s) => s.done,
            None => true, // free slots have no work left
        }
    }

    /// Tokens generated so far in `slot`.
    pub fn tokens(&self, slot: usize) -> &[i32] {
        match self.slots.get(slot).and_then(|s| s.as_ref()) {
            Some(s) => &s.generated,
            None => &[],
        }
    }

    /// Consume the slot into its finished stream + stats, freeing it for
    /// the next admission.
    pub fn finish(&mut self, slot: usize) -> Result<(Vec<i32>, GenerationStats)> {
        let mut s = self
            .slots
            .get_mut(slot)
            .and_then(|s| s.take())
            .with_context(|| format!("slot {slot} is not live"))?;
        // A poisoned slot still frees (the take above already reclaimed it);
        // its stream is not trustworthy, so surface the round error instead.
        if let Some(msg) = s.failed.take() {
            bail!(msg);
        }
        s.stats.generated_tokens = s.generated.len();
        Ok((s.generated, s.stats))
    }

    /// Free a slot without collecting its stream (abandoned session).
    pub fn release(&mut self, slot: usize) {
        if let Some(s) = self.slots.get_mut(slot) {
            *s = None;
        }
    }
}

/// The substrate-backed pool type the LLM layer holds.
pub type SubstrateBatch = BatchedDecode<PjrtBatchEngine>;

// ---------------------------------------------------------------------------
// Decode session (the transport-independent state machine)
// ---------------------------------------------------------------------------

enum Phase {
    /// Fresh logits pending a host-side sample.
    Sample { logits: Vec<f32> },
    /// Last token pushed; next unit of work is a span or a single step.
    Advance,
    Done,
}

/// Step-wise decode driver: sample → (span | step) → tail → EOS.
///
/// Owns the sampling scratch and the token buffer; the backend owns the
/// transport (and, for the resident backend, the device buffers).
/// [`DecodeSession::advance`] performs exactly one unit of backend work,
/// which makes a generation resumable step-wise — the hook for future
/// multi-request decode interleaving.
pub struct DecodeSession<B: DecodeBackend> {
    backend: B,
    params: SamplingParams,
    prompt_len: usize,
    max_new: usize,
    use_span: bool,
    generated: Vec<i32>,
    phase: Phase,
    scratch: SampleScratch,
    u_buf: Vec<f32>,
    stats: GenerationStats,
}

impl<B: DecodeBackend> DecodeSession<B> {
    /// Run the prompt pass and enter the sampling phase. The span path is
    /// enabled only when the sampling params match the artifact's baked-in
    /// top-k (greedy works too: temperature ~ 0 collapses the in-graph
    /// softmax onto the argmax).
    pub fn start(
        backend: B,
        params: SamplingParams,
        ids: &[i32],
        prompt_len: usize,
        max_seq: usize,
    ) -> Result<Self> {
        Self::start_opts(backend, params, ids, prompt_len, max_seq, true)
    }

    /// [`Self::start`] with span fusion optionally disabled. Batched-decode
    /// deployments pin `allow_span = false` on their per-session overflow
    /// sessions: the batched path is single-step by construction, and span
    /// vs single-step consume the RNG differently, so mixing them would
    /// make a response depend on which path happened to serve it.
    pub fn start_opts(
        backend: B,
        params: SamplingParams,
        ids: &[i32],
        prompt_len: usize,
        max_seq: usize,
        allow_span: bool,
    ) -> Result<Self> {
        Self::start_prefixed(backend, params, ids, prompt_len, max_seq, allow_span, None)
    }

    /// [`Self::start_opts`] with a cross-request KV prefix cache: when the
    /// backend can resume (`resume_chunks` non-empty), a cached prefix of
    /// the prompt is restored and only the suffix recomputed; a qualifying
    /// cold prefill snapshots its packed state back for later requests.
    /// Token streams are bit-identical either way — the resume artifacts
    /// reproduce the cold prefill state bit for bit
    /// (python/tests/test_resume.py).
    pub fn start_prefixed(
        mut backend: B,
        params: SamplingParams,
        ids: &[i32],
        prompt_len: usize,
        max_seq: usize,
        allow_span: bool,
        cache: Option<&Rc<RefCell<PrefixCache>>>,
    ) -> Result<Self> {
        if prompt_len == 0 {
            bail!("empty prompt");
        }
        let t0 = std::time::Instant::now();
        let chunks = backend.resume_chunks();
        let plan = PrefixPlan::probe(cache, &chunks, &ids[..prompt_len]);
        let (logits, restored) = match &plan.hit {
            Some(h) => {
                (backend.prefill_resumed(ids, prompt_len, h.state(), h.depth())?, h.depth())
            }
            None => (backend.prefill(ids, prompt_len)?, 0),
        };
        if plan.should_snapshot() {
            if let Some(state) = backend.snapshot_state()? {
                plan.insert(state);
            }
        }
        drop(plan); // release the pin: the basis state has been consumed
        let stats = GenerationStats {
            prompt_tokens: prompt_len,
            restored_tokens: restored,
            prefill_micros: t0.elapsed().as_micros(),
            device_resident: backend.device_resident(),
            ..Default::default()
        };
        let max_new = params.max_new_tokens.min(max_seq.saturating_sub(prompt_len));
        let use_span = allow_span
            && backend
                .span_n()
                .map(|n| {
                    max_new >= n && (params.top_k == SPAN_TOP_K || params.temperature <= 0.0)
                })
                .unwrap_or(false);
        let phase = if max_new == 0 { Phase::Done } else { Phase::Sample { logits } };
        Ok(DecodeSession {
            backend,
            params,
            prompt_len,
            max_new,
            use_span,
            generated: Vec::with_capacity(max_new),
            phase,
            scratch: SampleScratch::default(),
            u_buf: Vec::new(),
            stats,
        })
    }

    pub fn is_done(&self) -> bool {
        matches!(self.phase, Phase::Done)
    }

    /// Tokens generated so far.
    pub fn tokens(&self) -> &[i32] {
        &self.generated
    }

    /// One unit of work: sample one token from pending logits, run one
    /// fused span, or run one single decode step. Returns `true` while work
    /// remains.
    pub fn advance(&mut self, rng: &mut Rng) -> Result<bool> {
        let t0 = std::time::Instant::now();
        let phase = std::mem::replace(&mut self.phase, Phase::Done);
        match phase {
            Phase::Done => {}
            Phase::Sample { logits } => {
                let tok = sample_token_with(&logits, &self.params, rng, &mut self.scratch);
                self.generated.push(tok);
                self.phase = if tok == EOS_ID || self.generated.len() >= self.max_new {
                    Phase::Done
                } else {
                    Phase::Advance
                };
            }
            Phase::Advance => {
                let last = *self.generated.last().expect("Advance implies a token");
                let pos = (self.prompt_len + self.generated.len() - 1) as i32;
                let remaining = self.max_new - self.generated.len();
                let span_n = self.backend.span_n();
                if self.use_span && span_n.is_some_and(|n| remaining >= n) {
                    let n = span_n.expect("use_span implies span_n");
                    self.u_buf.clear();
                    for _ in 0..n {
                        self.u_buf.push(rng.f32());
                    }
                    let temp = self.params.temperature.max(0.0);
                    let tokens = self.backend.span(last, pos, &self.u_buf, temp)?;
                    let mut ended = false;
                    for t in tokens {
                        self.generated.push(t);
                        if t == EOS_ID || self.generated.len() >= self.max_new {
                            ended = true;
                            break;
                        }
                    }
                    self.phase = if ended { Phase::Done } else { Phase::Advance };
                } else {
                    // single step (also the post-span tail)
                    let logits = self.backend.step(last, pos)?;
                    self.phase = Phase::Sample { logits };
                }
            }
        }
        self.stats.decode_micros += t0.elapsed().as_micros();
        Ok(!self.is_done())
    }

    /// Drive the session to completion.
    pub fn run(&mut self, rng: &mut Rng) -> Result<()> {
        while self.advance(rng)? {}
        Ok(())
    }

    /// Finish: the token stream plus stats.
    pub fn finish(mut self) -> (Vec<i32>, GenerationStats) {
        self.stats.generated_tokens = self.generated.len();
        (self.generated, self.stats)
    }
}

// ---------------------------------------------------------------------------
// Generator facade
// ---------------------------------------------------------------------------

pub struct Generator {
    prefill: Arc<Executable>,
    decode: Arc<Executable>,
    /// Fused multi-step decode (§Perf L2): runs N steps + in-graph top-k
    /// sampling per executable call. `None` when the artifact set predates
    /// spans.
    span: Option<(usize, Arc<Executable>)>,
    /// Device-resident artifact set; `None` when the artifacts predate the
    /// packed-state convention or `[runtime] device_resident = false`.
    /// `Arc` so every live session shares one set while owning its state.
    resident: Option<Arc<ResidentSet>>,
    /// Slot-batched decode buckets (ascending batch width); empty when the
    /// artifact set predates batched decode or resident mode is pinned off.
    batched: Vec<Arc<BatchArtifacts>>,
    kv_spec: IoSpec,
    tokenizer: Tokenizer,
    pub model_name: String,
    max_prefill: usize,
    max_seq: usize,
}

/// Discover the `{model}_*_res` + `{model}_peek_*` artifact set, validating
/// that every piece agrees on the packed state width AND that the resident
/// transport mirrors the literal transport's span capability exactly —
/// asymmetric span support would consume the RNG differently and break the
/// bit-identical-stream contract. Any inconsistency falls back to the
/// literal transport (with a notice) rather than failing.
fn discover_resident(
    rt: &Runtime,
    model: &str,
    literal_span: Option<usize>,
) -> Option<ResidentSet> {
    let prefill = rt.executable(&format!("{model}_prefill_res")).ok()?;
    let decode = rt.executable(&format!("{model}_decode_res")).ok()?;
    let peek_logits = rt.executable(&format!("{model}_peek_logits")).ok()?;
    let state_len = prefill.spec.outputs.first()?.numel();
    let consistent = prefill.spec.untupled
        && decode.spec.untupled
        && peek_logits.spec.untupled
        && decode.spec.inputs.len() == 3
        && decode.spec.inputs[2].numel() == state_len
        && decode.spec.outputs.first().map(|o| o.numel()) == Some(state_len)
        && peek_logits.spec.inputs.first().map(|i| i.numel()) == Some(state_len);
    if !consistent {
        eprintln!("[runtime] {model}: resident artifacts inconsistent; using literal decode");
        return None;
    }
    let span = match literal_span {
        None => None, // neither transport spans: symmetric
        Some(n) => {
            let exe = rt.executable(&format!("{model}_decode{n}_res")).ok();
            let peek = rt.executable(&format!("{model}_peek_tokens{n}")).ok();
            let set = match (exe, peek) {
                (Some(exe), Some(peek)) => {
                    let ok = exe.spec.untupled
                        && peek.spec.untupled
                        && exe.spec.inputs.len() == 5
                        && exe.spec.inputs[2].numel() == state_len
                        && exe.spec.inputs[3].numel() == n
                        && exe.spec.outputs.first().map(|o| o.numel()) == Some(state_len)
                        && peek.spec.inputs.first().map(|i| i.numel()) == Some(state_len)
                        && peek.spec.outputs.first().map(|o| o.numel()) == Some(n);
                    ok.then_some(SpanSet { n, exe, peek })
                }
                _ => None,
            };
            if set.is_none() {
                eprintln!(
                    "[runtime] {model}: literal span({n}) has no matching resident span; \
                     using literal decode"
                );
                return None;
            }
            set
        }
    };
    // Resume-capable prefill chunks are optional sugar on top of the
    // resident set: a missing or inconsistent chunk only disables reuse at
    // that boundary (pre-resume artifact dirs yield an empty list and every
    // prefill stays cold).
    let mut resume = Vec::new();
    for p in rt.manifest.resume_chunks(model) {
        let Ok(exe) = rt.executable(&format!("{model}_prefill_resume{p}")) else {
            continue; // tolerate selective loading
        };
        let ok = exe.spec.untupled
            && exe.spec.inputs.len() == 3
            && p < exe.spec.inputs[0].numel()
            && exe.spec.inputs[1].numel() == 1
            && exe.spec.inputs[2].numel() == state_len
            && exe.spec.outputs.first().map(|o| o.numel()) == Some(state_len);
        if !ok {
            eprintln!("[runtime] {model}: resume({p}) artifact inconsistent; chunk skipped");
            continue;
        }
        resume.push((p, exe));
    }
    Some(ResidentSet { prefill, decode, peek_logits, span, resume })
}

/// Discover the `{model}_prefill_scatter{B}` / `{model}_decode_batch{B}_res`
/// / `{model}_peek_logits_batch{B}` bucket sets, validating that each bucket
/// agrees on the batched state width and the logits row width. Inconsistent
/// or incomplete buckets are skipped (with a notice) rather than failing —
/// pre-batched artifact dirs simply yield an empty list and the per-session
/// path keeps serving.
fn discover_batched(rt: &Runtime, model: &str, vocab: usize) -> Vec<Arc<BatchArtifacts>> {
    let mut out = Vec::new();
    for b in rt.manifest.batch_buckets(model) {
        let decode = rt.executable(&format!("{model}_decode_batch{b}_res")).ok();
        let scatter = rt.executable(&format!("{model}_prefill_scatter{b}")).ok();
        let peek = rt.executable(&format!("{model}_peek_logits_batch{b}")).ok();
        let (decode, scatter, peek) = match (decode, scatter, peek) {
            (Some(d), Some(s), Some(p)) => (d, s, p),
            // tolerate selective loading (tests compile only a subset)
            _ => continue,
        };
        let batch_numel = decode.spec.inputs.last().map_or(0, |i| i.numel());
        let consistent = decode.spec.untupled
            && scatter.spec.untupled
            && peek.spec.untupled
            && decode.spec.inputs.len() == 4
            && batch_numel > 0
            && batch_numel % b == 0
            && decode.spec.inputs[0].numel() == b
            && decode.spec.inputs[1].numel() == b
            && decode.spec.inputs[2].numel() == b
            && decode.spec.outputs.first().map(|o| o.numel()) == Some(batch_numel)
            && scatter.spec.inputs.len() == 4
            && scatter.spec.inputs[3].numel() == batch_numel
            && scatter.spec.outputs.first().map(|o| o.numel()) == Some(batch_numel)
            && peek.spec.inputs.first().map(|i| i.numel()) == Some(batch_numel)
            && peek.spec.outputs.first().map(|o| o.numel()) == Some(b * vocab);
        if !consistent {
            eprintln!(
                "[runtime] {model}: batch{b} artifacts inconsistent; bucket skipped"
            );
            continue;
        }
        let state_len = batch_numel / b;
        let mut resume = Vec::new();
        for p in rt.manifest.batch_resume_chunks(model, b) {
            let Ok(exe) =
                rt.executable(&format!("{model}_prefill_scatter_resume{b}_{p}"))
            else {
                continue; // tolerate selective loading
            };
            let ok = exe.spec.untupled
                && exe.spec.inputs.len() == 5
                && p < exe.spec.inputs[0].numel()
                && exe.spec.inputs[3].numel() == state_len
                && exe.spec.inputs[4].numel() == batch_numel
                && exe.spec.outputs.first().map(|o| o.numel()) == Some(batch_numel);
            if !ok {
                eprintln!(
                    "[runtime] {model}: batch{b} resume({p}) artifact inconsistent; \
                     chunk skipped"
                );
                continue;
            }
            resume.push((p, exe));
        }
        out.push(Arc::new(BatchArtifacts {
            batch: b,
            state_len,
            vocab,
            prefill_scatter: scatter,
            decode,
            peek,
            resume,
        }));
    }
    out.sort_by_key(|a| a.batch);
    out
}

impl Generator {
    /// `model` is "small" or "big" (manifest model names). Prefers the
    /// device-resident transport when its artifacts are compiled.
    pub fn new(rt: &Runtime, model: &str) -> Result<Generator> {
        Self::with_mode(rt, model, true)
    }

    /// `device_resident = false` pins the literal transport even when
    /// resident artifacts exist (`[runtime] device_resident = false`).
    pub fn with_mode(rt: &Runtime, model: &str, device_resident: bool) -> Result<Generator> {
        let spec = rt.manifest.model(model)?;
        // discover a decode-span artifact (name: {model}_decode{N}, N > 1)
        let span = rt
            .manifest
            .artifacts
            .keys()
            .filter_map(|name| {
                let n: usize = name
                    .strip_prefix(&format!("{model}_decode"))?
                    .parse()
                    .ok()?;
                (n > 1).then_some((n, name.clone()))
            })
            .max_by_key(|(n, _)| *n)
            // tolerate selective loading (tests compile only a subset)
            .and_then(|(n, name)| rt.executable(&name).ok().map(|e| (n, e)));
        let (resident, batched) = if device_resident {
            (
                discover_resident(rt, model, span.as_ref().map(|(n, _)| *n)).map(Arc::new),
                discover_batched(rt, model, rt.manifest.vocab_size),
            )
        } else {
            (None, Vec::new())
        };
        let decode = rt.executable(&format!("{model}_decode"))?;
        let kv_spec = decode.spec.inputs[2].clone();
        Ok(Generator {
            prefill: rt.executable(&format!("{model}_prefill"))?,
            decode,
            span,
            resident,
            batched,
            kv_spec,
            tokenizer: Tokenizer::new(rt.manifest.vocab_size),
            model_name: model.to_string(),
            max_prefill: spec.cfg("max_prefill")?,
            max_seq: spec.cfg("max_seq")?,
        })
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    pub fn max_prefill(&self) -> usize {
        self.max_prefill
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Whether the device-resident transport is available.
    pub fn resident_available(&self) -> bool {
        self.resident.is_some()
    }

    /// Resume-capable prefix chunk lengths of the resident transport
    /// (ascending; empty = cold prefill only).
    pub fn resume_chunks(&self) -> Vec<usize> {
        self.resident
            .as_ref()
            .map_or_else(Vec::new, |s| s.resume.iter().map(|(p, _)| *p).collect())
    }

    /// Compiled batched-decode buckets (slot counts), ascending. Empty when
    /// the artifact dir predates batched decode (per-session fallback).
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.batched.iter().map(|a| a.batch).collect()
    }

    /// Build a slot-batched decode pool using the largest compiled bucket
    /// that fits `max_slots` (`[scheduler] decode_batch`). `None` when no
    /// bucket fits or batched artifacts are absent — callers keep serving
    /// through per-session dispatch.
    pub fn begin_batch(&self, max_slots: usize) -> Option<SubstrateBatch> {
        let set = self
            .batched
            .iter()
            .filter(|a| a.batch <= max_slots)
            .max_by_key(|a| a.batch)?;
        let engine = PjrtBatchEngine { set: Arc::clone(set), state: None };
        Some(BatchedDecode::new(engine, set.vocab, self.max_seq))
    }

    /// Generate a completion for a prompt built from `segments`
    /// (BOS seg0 SEP seg1 ...). Deterministic given `rng`. Uses the
    /// device-resident transport when available, literal otherwise.
    pub fn generate(
        &self,
        segments: &[&str],
        params: &SamplingParams,
        rng: &mut Rng,
    ) -> Result<Generation> {
        self.generate_on(segments, params, rng, self.resident.is_some())
    }

    /// Generate forcing a specific transport (`resident = false` → literal
    /// path). Token streams are bit-identical across transports — gated by
    /// `rust/tests/runtime_integration.rs`.
    pub fn generate_on(
        &self,
        segments: &[&str],
        params: &SamplingParams,
        rng: &mut Rng,
        resident: bool,
    ) -> Result<Generation> {
        let mut session = self.begin_session_on(segments, params, rng.clone(), resident)?;
        while session.advance()? {}
        // Hand the advanced stream back so sequential callers see exactly
        // the pre-session RNG consumption.
        *rng = session.rng.clone();
        Ok(session.finish())
    }

    /// Start a resumable generation that *owns* everything it needs (RNG,
    /// sampling scratch, decode state buffers); the executables stay shared
    /// behind `Arc`s. Any number of sessions can be live at once — this is
    /// the substrate hook for the coordinator's decode scheduler.
    pub fn begin_session(
        &self,
        segments: &[&str],
        params: &SamplingParams,
        rng: Rng,
    ) -> Result<GenSession> {
        self.begin_session_on(segments, params, rng, self.resident.is_some())
    }

    /// `begin_session` forcing a specific transport.
    pub fn begin_session_on(
        &self,
        segments: &[&str],
        params: &SamplingParams,
        rng: Rng,
        resident: bool,
    ) -> Result<GenSession> {
        self.begin_session_opts(segments, params, rng, resident, true)
    }

    /// `begin_session_on` with span fusion optionally disabled
    /// (`allow_span = false`): the per-session overflow path of a batched
    /// deployment, where every stream must take the single-step sampling
    /// path the batch pool takes.
    pub fn begin_session_opts(
        &self,
        segments: &[&str],
        params: &SamplingParams,
        rng: Rng,
        resident: bool,
        allow_span: bool,
    ) -> Result<GenSession> {
        self.begin_session_cached(segments, params, rng, resident, allow_span, None)
    }

    /// [`Self::begin_session_opts`] with a caller-owned cross-request KV
    /// prefix cache (one per model: packed states of different models have
    /// different widths and must never mix). Only the resident transport
    /// can resume; the literal transport ignores the cache.
    pub fn begin_session_cached(
        &self,
        segments: &[&str],
        params: &SamplingParams,
        rng: Rng,
        resident: bool,
        allow_span: bool,
        cache: Option<&Rc<RefCell<PrefixCache>>>,
    ) -> Result<GenSession> {
        let (ids, len) = self.tokenizer.encode_prompt(segments, self.max_prefill);
        self.begin_session_ids(&ids, len, params, rng, resident, allow_span, cache)
    }

    /// [`Self::begin_session_cached`] for callers that already hold encoded
    /// prompt ids (e.g. a prompt built with suffix-protected encoding, or
    /// one tokenized once and shared between the pool and overflow paths).
    #[allow(clippy::too_many_arguments)]
    pub fn begin_session_ids(
        &self,
        ids: &[i32],
        len: usize,
        params: &SamplingParams,
        rng: Rng,
        resident: bool,
        allow_span: bool,
        cache: Option<&Rc<RefCell<PrefixCache>>>,
    ) -> Result<GenSession> {
        if len == 0 {
            bail!("empty prompt");
        }
        let inner = if resident {
            let set = self
                .resident
                .as_ref()
                .context("device-resident artifacts not compiled")?;
            let backend = ResidentBackend { set: Arc::clone(set), state: None };
            let s = DecodeSession::start_prefixed(
                backend,
                *params,
                ids,
                len,
                self.max_seq,
                allow_span,
                cache,
            )?;
            SessionInner::Resident(s)
        } else {
            let backend = LiteralBackend {
                prefill: Arc::clone(&self.prefill),
                decode: Arc::clone(&self.decode),
                span: self.span.clone(),
                kv_spec: self.kv_spec.clone(),
                k: None,
                v: None,
            };
            let s = DecodeSession::start_opts(
                backend,
                *params,
                ids,
                len,
                self.max_seq,
                allow_span,
            )?;
            SessionInner::Literal(s)
        };
        Ok(GenSession { inner, rng, tokenizer: self.tokenizer.clone() })
    }
}

/// Which transport a [`GenSession`] runs on (the session owns it either way).
enum SessionInner {
    Literal(DecodeSession<LiteralBackend>),
    Resident(DecodeSession<ResidentBackend>),
}

/// A live, owned, resumable generation: [`DecodeSession`] + its private RNG
/// + the tokenizer needed to render the final text. One `advance()` call is
/// one unit of backend work, so a scheduler can round-robin many sessions
/// on the engine thread without any cross-session state.
pub struct GenSession {
    inner: SessionInner,
    rng: Rng,
    tokenizer: Tokenizer,
}

impl GenSession {
    /// One unit of decode work; `true` while work remains.
    pub fn advance(&mut self) -> Result<bool> {
        match &mut self.inner {
            SessionInner::Literal(s) => s.advance(&mut self.rng),
            SessionInner::Resident(s) => s.advance(&mut self.rng),
        }
    }

    pub fn is_done(&self) -> bool {
        match &self.inner {
            SessionInner::Literal(s) => s.is_done(),
            SessionInner::Resident(s) => s.is_done(),
        }
    }

    /// Tokens generated so far (grows with each `advance`; the streaming
    /// layer reads the tail it has not yet decoded).
    pub fn tokens(&self) -> &[i32] {
        match &self.inner {
            SessionInner::Literal(s) => s.tokens(),
            SessionInner::Resident(s) => s.tokens(),
        }
    }

    /// Consume the session into the finished generation.
    pub fn finish(self) -> Generation {
        let (token_ids, stats) = match self.inner {
            SessionInner::Literal(s) => s.finish(),
            SessionInner::Resident(s) => s.finish(),
        };
        Generation {
            text: self.tokenizer.decode(&token_ids),
            token_ids,
            stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut rng = Rng::new(1);
        let p = SamplingParams::greedy(8);
        assert_eq!(sample_token(&logits, &p, &mut rng), 1);
    }

    #[test]
    fn topk_restricts_support() {
        let mut logits = vec![0.0f32; 100];
        logits[7] = 5.0;
        logits[13] = 4.5;
        let p = SamplingParams { temperature: 1.0, top_k: 2, max_new_tokens: 1 };
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let t = sample_token(&logits, &p, &mut rng);
            assert!(t == 7 || t == 13, "sampled {t}");
        }
    }

    #[test]
    fn temperature_zero_equals_greedy() {
        let logits = vec![0.3, 0.1, 0.9, 0.2];
        let p = SamplingParams { temperature: 0.0, top_k: 5, max_new_tokens: 1 };
        let mut rng = Rng::new(3);
        assert_eq!(sample_token(&logits, &p, &mut rng), 2);
    }

    #[test]
    fn sampling_is_deterministic_given_seed() {
        let logits: Vec<f32> = (0..50).map(|i| ((i * 37) % 11) as f32 / 3.0).collect();
        let p = SamplingParams::default();
        let a: Vec<i32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample_token(&logits, &p, &mut rng)).collect()
        };
        let b: Vec<i32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample_token(&logits, &p, &mut rng)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch() {
        // The bounded-heap path must be a pure function of (logits, rng):
        // reusing one scratch across calls changes nothing.
        let logits: Vec<f32> = (0..200).map(|i| ((i * 53) % 17) as f32 / 4.0).collect();
        let p = SamplingParams { temperature: 0.8, top_k: 12, max_new_tokens: 1 };
        let mut scratch = SampleScratch::default();
        let reused: Vec<i32> = {
            let mut rng = Rng::new(4);
            (0..50).map(|_| sample_token_with(&logits, &p, &mut rng, &mut scratch)).collect()
        };
        let fresh: Vec<i32> = {
            let mut rng = Rng::new(4);
            (0..50).map(|_| sample_token(&logits, &p, &mut rng)).collect()
        };
        assert_eq!(reused, fresh);
    }

    #[test]
    fn topk_candidates_are_the_k_largest() {
        // NB: the heap selection replaced select_nth; candidate sets must
        // still be exactly the k largest logits.
        let logits: Vec<f32> = (0..64).map(|i| ((i * 29) % 31) as f32).collect();
        let p = SamplingParams { temperature: 1.0, top_k: 5, max_new_tokens: 1 };
        let mut top: Vec<(f32, usize)> =
            logits.iter().copied().enumerate().map(|(i, x)| (x, i)).collect();
        top.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let allowed: std::collections::HashSet<i32> =
            top[..5].iter().map(|&(_, i)| i as i32).collect();
        let mut rng = Rng::new(8);
        for _ in 0..300 {
            let t = sample_token(&logits, &p, &mut rng);
            assert!(allowed.contains(&t), "sampled non-top-k token {t}");
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let mut logits = vec![0.0f32; 10];
        logits[0] = 1.0;
        let p = SamplingParams { temperature: 100.0, top_k: 0, max_new_tokens: 1 };
        let mut rng = Rng::new(4);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..300 {
            seen.insert(sample_token(&logits, &p, &mut rng));
        }
        assert!(seen.len() >= 8, "only saw {} distinct tokens", seen.len());
    }

    // -----------------------------------------------------------------------
    // DecodeSession state machine over a scripted fake backend (no
    // artifacts): span → tail → EOS transitions and the fallback switch.
    // -----------------------------------------------------------------------

    struct FakeBackend {
        vocab: usize,
        span_width: Option<usize>,
        /// Tokens the fake emits, in order; greedy sampling reproduces them.
        script: Vec<i32>,
        emitted: usize,
        calls: Vec<String>,
        /// Resume chunk lengths this fake pretends to have compiled.
        resume_at: Vec<usize>,
    }

    impl FakeBackend {
        fn new(span_width: Option<usize>, script: Vec<i32>) -> FakeBackend {
            FakeBackend {
                vocab: 32,
                span_width,
                script,
                emitted: 0,
                calls: Vec::new(),
                resume_at: Vec::new(),
            }
        }

        fn logits_for(&mut self) -> Vec<f32> {
            let tok = self.script[self.emitted];
            self.emitted += 1;
            let mut l = vec![0.0f32; self.vocab];
            // Spike tall enough that top-k temperature sampling is always
            // on-script (exp(-200) underflows to 0), so scripted fakes with
            // different transports stay token-for-token comparable.
            l[tok as usize] = 200.0;
            l
        }
    }

    impl DecodeBackend for FakeBackend {
        fn span_n(&self) -> Option<usize> {
            self.span_width
        }

        fn prefill(&mut self, ids: &[i32], len: usize) -> Result<Vec<f32>> {
            assert!(ids.len() >= len);
            self.calls.push(format!("prefill({len})"));
            Ok(self.logits_for())
        }

        fn resume_chunks(&self) -> Vec<usize> {
            self.resume_at.clone()
        }

        fn prefill_resumed(
            &mut self,
            ids: &[i32],
            len: usize,
            state: &[f32],
            prefix: usize,
        ) -> Result<Vec<f32>> {
            assert!(ids.len() >= len && prefix > 0 && prefix < len);
            assert!(!state.is_empty(), "resume needs a basis state");
            self.calls.push(format!("resume({len},{prefix})"));
            Ok(self.logits_for())
        }

        fn snapshot_state(&mut self) -> Result<Option<Vec<f32>>> {
            self.calls.push("snapshot".to_string());
            Ok(Some(vec![0.5; 4]))
        }

        fn step(&mut self, token: i32, pos: i32) -> Result<Vec<f32>> {
            self.calls.push(format!("step({token},{pos})"));
            Ok(self.logits_for())
        }

        fn span(
            &mut self,
            token: i32,
            pos: i32,
            u: &[f32],
            temperature: f32,
        ) -> Result<Vec<i32>> {
            self.calls.push(format!("span({token},{pos},n={})", u.len()));
            assert_eq!(Some(u.len()), self.span_width);
            assert!(temperature >= 0.0);
            let out = self.script[self.emitted..self.emitted + u.len()].to_vec();
            self.emitted += u.len();
            Ok(out)
        }
    }

    fn drive(backend: FakeBackend, params: SamplingParams) -> (Vec<i32>, Vec<String>) {
        let ids = [1, 1, 1];
        let mut s = DecodeSession::start(backend, params, &ids, 3, 64).unwrap();
        s.run(&mut Rng::new(1)).unwrap();
        // finish() consumes the session; pull the call log out via tokens
        // first (backend moves with the session).
        let tokens = s.tokens().to_vec();
        let calls = s.backend.calls.clone();
        let (toks2, stats) = s.finish();
        assert_eq!(tokens, toks2);
        assert_eq!(stats.generated_tokens, tokens.len());
        (tokens, calls)
    }

    #[test]
    fn session_single_steps_until_eos() {
        let b = FakeBackend::new(None, vec![5, 6, EOS_ID, 9]);
        let (tokens, calls) = drive(b, SamplingParams::greedy(8));
        assert_eq!(tokens, vec![5, 6, EOS_ID]);
        assert_eq!(calls, vec!["prefill(3)", "step(5,3)", "step(6,4)"]);
    }

    #[test]
    fn session_respects_max_new() {
        let b = FakeBackend::new(None, vec![5, 6, 7, 8, 9]);
        let (tokens, calls) = drive(b, SamplingParams::greedy(3));
        assert_eq!(tokens, vec![5, 6, 7]);
        // no step issued for the final sampled token
        assert_eq!(calls, vec!["prefill(3)", "step(5,3)", "step(6,4)"]);
    }

    #[test]
    fn session_span_then_tail_transition() {
        // span width 4, max_new 7: 1 sampled + 4 fused + 2 tail steps.
        let b = FakeBackend::new(Some(4), vec![10, 11, 12, 13, 14, 15, 16]);
        let (tokens, calls) = drive(b, SamplingParams::greedy(7));
        assert_eq!(tokens, vec![10, 11, 12, 13, 14, 15, 16]);
        assert_eq!(
            calls,
            vec!["prefill(3)", "span(10,3,n=4)", "step(14,7)", "step(15,8)"]
        );
    }

    #[test]
    fn session_eos_inside_span_truncates() {
        let b = FakeBackend::new(Some(4), vec![10, 11, EOS_ID, 99, 98]);
        let (tokens, calls) = drive(b, SamplingParams::greedy(8));
        assert_eq!(tokens, vec![10, 11, EOS_ID]);
        assert_eq!(calls, vec!["prefill(3)", "span(10,3,n=4)"]);
    }

    #[test]
    fn session_span_disabled_on_topk_mismatch() {
        // fallback switch: a span-capable backend with non-matching
        // sampling params must take the single-step path only.
        let b = FakeBackend::new(Some(2), vec![10, 11, 12, 13]);
        let params = SamplingParams { temperature: 1.0, top_k: 7, max_new_tokens: 4 };
        let (tokens, calls) = drive(b, params);
        assert_eq!(tokens.len(), 4);
        assert!(
            calls.iter().all(|c| !c.starts_with("span")),
            "span must not run: {calls:?}"
        );
    }

    #[test]
    fn session_transports_agree_on_token_stream() {
        // The fallback contract in miniature: two backends (with and
        // without span support) over the same model emissions produce the
        // same stream under greedy decoding.
        let script = vec![10, 11, 12, 13, 14, 15, 16, 17];
        let spanned = FakeBackend::new(Some(4), script.clone());
        let (with_span, _) = drive(spanned, SamplingParams::greedy(8));
        let (without, _) = drive(FakeBackend::new(None, script), SamplingParams::greedy(8));
        assert_eq!(with_span, without);
    }

    #[test]
    fn interleaved_sessions_match_sequential_streams() {
        // The scheduler contract: with per-session RNGs, round-robin
        // advancing N live sessions yields bit-identical token streams to
        // running each session to completion on its own.
        let params = SamplingParams { temperature: 1.0, top_k: 7, max_new_tokens: 6 };
        let scripts: [Vec<i32>; 3] = [
            vec![10, 11, 12, 13, 14, 15],
            vec![20, 21, EOS_ID, 9, 9, 9],
            vec![5, 6, 7, 8, EOS_ID, 9],
        ];
        let sequential: Vec<Vec<i32>> = scripts
            .iter()
            .enumerate()
            .map(|(i, script)| {
                let b = FakeBackend::new(None, script.clone());
                let ids = [1, 1, 1];
                let mut s = DecodeSession::start(b, params, &ids, 3, 64).unwrap();
                let mut rng = Rng::substream(7, &format!("session/{i}"));
                s.run(&mut rng).unwrap();
                s.finish().0
            })
            .collect();
        // Same sessions, interleaved one advance() at a time.
        let ids = [1, 1, 1];
        let mut live: Vec<(DecodeSession<FakeBackend>, Rng)> = scripts
            .iter()
            .enumerate()
            .map(|(i, script)| {
                let b = FakeBackend::new(None, script.clone());
                (
                    DecodeSession::start(b, params, &ids, 3, 64).unwrap(),
                    Rng::substream(7, &format!("session/{i}")),
                )
            })
            .collect();
        while live.iter().any(|(s, _)| !s.is_done()) {
            for (s, rng) in &mut live {
                if !s.is_done() {
                    s.advance(rng).unwrap();
                }
            }
        }
        let interleaved: Vec<Vec<i32>> = live.into_iter().map(|(s, _)| s.finish().0).collect();
        assert_eq!(interleaved, sequential);
    }

    // -----------------------------------------------------------------------
    // Cross-request prefix cache plumbing over resume-capable fakes: miss →
    // cold prefill + snapshot insert, hit → resumed prefill, and streams
    // bit-identical either way.
    // -----------------------------------------------------------------------

    fn resumable(script: Vec<i32>, chunks: &[usize]) -> FakeBackend {
        let mut b = FakeBackend::new(None, script);
        b.resume_at = chunks.to_vec();
        b
    }

    #[test]
    fn prefix_miss_snapshots_then_hit_resumes_identically() {
        let cache = PrefixCache::shared(1 << 20);
        let p = SamplingParams::greedy(3);
        let script = vec![5, 6, 7];
        let ids_a = [1, 2, 3, 4, 9, 9];
        let mut a = DecodeSession::start_prefixed(
            resumable(script.clone(), &[2, 4]),
            p,
            &ids_a,
            6,
            64,
            true,
            Some(&cache),
        )
        .unwrap();
        a.run(&mut Rng::new(1)).unwrap();
        assert_eq!(a.backend.calls[0], "prefill(6)");
        assert!(a.backend.calls.contains(&"snapshot".to_string()));
        let (cold, stats) = a.finish();
        assert_eq!(stats.restored_tokens, 0);

        // Same leading 4 tokens, different tail: deepest chunk hit.
        let ids_b = [1, 2, 3, 4, 8, 8];
        let mut b = DecodeSession::start_prefixed(
            resumable(script, &[2, 4]),
            p,
            &ids_b,
            6,
            64,
            true,
            Some(&cache),
        )
        .unwrap();
        b.run(&mut Rng::new(1)).unwrap();
        assert_eq!(b.backend.calls[0], "resume(6,4)");
        assert!(
            !b.backend.calls.contains(&"snapshot".to_string()),
            "a hit at the deepest covered chunk must not re-snapshot"
        );
        let (resumed, stats) = b.finish();
        assert_eq!(stats.restored_tokens, 4);
        assert_eq!(resumed, cold, "resumed stream must equal the cold stream");

        let s = cache.borrow().stats();
        assert_eq!((s.hits, s.misses, s.saved_tokens), (1, 1, 4));
    }

    #[test]
    fn shallow_hit_deepens_the_cache() {
        // A short prompt seeds only chunk 2; a longer one resumes at 2 AND
        // snapshots so chunk 4 becomes available; a third resumes at 4.
        let cache = PrefixCache::shared(1 << 20);
        let p = SamplingParams::greedy(2);
        let mut s = DecodeSession::start_prefixed(
            resumable(vec![5, 6], &[2, 4]),
            p,
            &[1, 2, 9],
            3,
            64,
            true,
            Some(&cache),
        )
        .unwrap();
        s.run(&mut Rng::new(1)).unwrap();
        assert_eq!(s.backend.calls[0], "prefill(3)");
        drop(s);
        let mut s = DecodeSession::start_prefixed(
            resumable(vec![5, 6], &[2, 4]),
            p,
            &[1, 2, 3, 4, 9, 9],
            6,
            64,
            true,
            Some(&cache),
        )
        .unwrap();
        s.run(&mut Rng::new(1)).unwrap();
        assert_eq!(s.backend.calls[0], "resume(6,2)");
        assert!(
            s.backend.calls.contains(&"snapshot".to_string()),
            "a shallow hit with a deeper covered chunk must snapshot"
        );
        let (_, stats) = s.finish();
        assert_eq!(stats.restored_tokens, 2);
        let mut s = DecodeSession::start_prefixed(
            resumable(vec![5, 6], &[2, 4]),
            p,
            &[1, 2, 3, 4, 7, 7],
            6,
            64,
            true,
            Some(&cache),
        )
        .unwrap();
        s.run(&mut Rng::new(1)).unwrap();
        assert_eq!(s.backend.calls[0], "resume(6,4)");
    }

    #[test]
    fn resume_incapable_transport_bypasses_cache() {
        // No resume chunks compiled: the cache is never consulted, so its
        // hit/miss stats keep meaning "resume served / not served".
        let cache = PrefixCache::shared(1 << 20);
        let b = FakeBackend::new(None, vec![5, 6]);
        let mut s = DecodeSession::start_prefixed(
            b,
            SamplingParams::greedy(2),
            &[1, 2, 3],
            3,
            64,
            true,
            Some(&cache),
        )
        .unwrap();
        s.run(&mut Rng::new(1)).unwrap();
        assert_eq!(s.backend.calls[0], "prefill(3)");
        let st = cache.borrow().stats();
        assert_eq!((st.hits, st.misses, st.entries), (0, 0, 0));
    }

    // -----------------------------------------------------------------------
    // BatchedDecode slot pool over a scripted fake engine: the collective
    // advance protocol (credits), O(1) dispatches per fairness round, slot
    // reuse / mid-flight admission, and batched ≡ per-session bit-identity.
    // -----------------------------------------------------------------------

    struct FakeBatchEngine {
        slots: usize,
        vocab: usize,
        /// Scripts handed out to admissions, in order.
        queue: std::collections::VecDeque<Vec<i32>>,
        scripts: Vec<Vec<i32>>,
        emitted: Vec<usize>,
        staged: Vec<f32>,
        dispatches: u64,
        prefills: u64,
        resumes: u64,
        snapshots: u64,
        /// Resume chunk lengths this fake pretends to have compiled.
        resume_at: Vec<usize>,
        /// One-shot injected fault: error the dispatch with this ordinal.
        fail_on_dispatch: Option<u64>,
    }

    impl FakeBatchEngine {
        fn new(slots: usize, scripts: Vec<Vec<i32>>) -> FakeBatchEngine {
            FakeBatchEngine {
                slots,
                vocab: 32,
                queue: scripts.into(),
                scripts: vec![Vec::new(); slots],
                emitted: vec![0; slots],
                staged: vec![0.0; slots * 32],
                dispatches: 0,
                prefills: 0,
                resumes: 0,
                snapshots: 0,
                resume_at: Vec::new(),
                fail_on_dispatch: None,
            }
        }

        /// Bind the next queued script to `slot` (cold and resumed prefill
        /// behave identically at the stream level, as on the real engine).
        fn seed_slot(&mut self, slot: usize) {
            self.scripts[slot] = self.queue.pop_front().expect("a script per admission");
            self.emitted[slot] = 0;
            self.stage(slot);
        }

        /// Stage the slot's next scripted token as a dominant logit spike
        /// (same 200.0 convention as `FakeBackend`).
        fn stage(&mut self, slot: usize) {
            let tok = self.scripts[slot]
                .get(self.emitted[slot])
                .copied()
                .unwrap_or(EOS_ID);
            let row = &mut self.staged[slot * self.vocab..(slot + 1) * self.vocab];
            row.fill(0.0);
            row[tok as usize] = 200.0;
        }
    }

    impl BatchEngine for FakeBatchEngine {
        fn slots(&self) -> usize {
            self.slots
        }

        fn prefill(&mut self, slot: usize, ids: &[i32], len: usize) -> Result<()> {
            assert!(ids.len() >= len && len > 0);
            self.prefills += 1;
            self.seed_slot(slot);
            Ok(())
        }

        fn resume_chunks(&self) -> Vec<usize> {
            self.resume_at.clone()
        }

        fn prefill_resumed(
            &mut self,
            slot: usize,
            ids: &[i32],
            len: usize,
            state: &[f32],
            prefix: usize,
        ) -> Result<()> {
            assert!(ids.len() >= len && prefix > 0 && prefix < len);
            assert!(!state.is_empty(), "resume needs a basis state");
            self.resumes += 1;
            self.seed_slot(slot);
            Ok(())
        }

        fn snapshot_slot(&mut self, _slot: usize) -> Result<Option<Vec<f32>>> {
            self.snapshots += 1;
            Ok(Some(vec![0.25; 8]))
        }

        fn step(&mut self, tokens: &[i32], pos: &[i32], active: &[i32]) -> Result<()> {
            assert_eq!(tokens.len(), self.slots);
            if self.fail_on_dispatch == Some(self.dispatches) {
                self.fail_on_dispatch = None;
                bail!("injected device fault");
            }
            self.dispatches += 1;
            for i in 0..self.slots {
                if active[i] == 0 {
                    continue;
                }
                assert_eq!(
                    tokens[i], self.scripts[i][self.emitted[i]],
                    "slot {i} echoed a token off its script"
                );
                assert!(pos[i] >= 0);
                self.emitted[i] += 1;
                self.stage(i);
            }
            Ok(())
        }

        fn peek(&mut self) -> Result<Vec<f32>> {
            Ok(self.staged.clone())
        }
    }

    /// Drive live slots the way the scheduler does: one `advance` per live
    /// slot per sweep, until everything is done.
    fn sweep_until_done(pool: &mut BatchedDecode<FakeBatchEngine>, slots: &[usize]) {
        while slots.iter().any(|&s| !pool.is_done(s)) {
            for &s in slots {
                if !pool.is_done(s) {
                    pool.advance(s).unwrap();
                }
            }
        }
    }

    #[test]
    fn batched_pool_matches_per_session_streams() {
        // The tentpole identity gate in miniature: S slots advanced
        // collectively must emit bit-identical streams to S independent
        // single-step sessions with the same per-session RNG substreams.
        let params = SamplingParams { temperature: 1.0, top_k: 7, max_new_tokens: 6 };
        let scripts: [Vec<i32>; 3] = [
            vec![10, 11, 12, 13, 14, 15],
            vec![20, 21, EOS_ID, 9, 9, 9],
            vec![5, 6, 7, 8, EOS_ID, 9],
        ];
        let ids = [1, 1, 1];
        let sequential: Vec<Vec<i32>> = scripts
            .iter()
            .enumerate()
            .map(|(i, script)| {
                let b = FakeBackend::new(None, script.clone());
                let mut s = DecodeSession::start(b, params, &ids, 3, 64).unwrap();
                s.run(&mut Rng::substream(7, &format!("session/{i}"))).unwrap();
                s.finish().0
            })
            .collect();
        let mut pool = BatchedDecode::new(FakeBatchEngine::new(4, scripts.to_vec()), 32, 64);
        let slots: Vec<usize> = (0..scripts.len())
            .map(|i| {
                pool.admit(&ids, 3, params, Rng::substream(7, &format!("session/{i}")))
                    .unwrap()
                    .expect("free slot")
            })
            .collect();
        sweep_until_done(&mut pool, &slots);
        let batched: Vec<Vec<i32>> = slots.iter().map(|&s| pool.finish(s).unwrap().0).collect();
        assert_eq!(batched, sequential);
    }

    #[test]
    fn batched_admission_resumes_from_prefix_cache() {
        // Two identical scripts: the cold admission seeds the cache, the
        // second admission (sharing a chunk-length prefix) resumes and must
        // stream identically.
        let cache = PrefixCache::shared(1 << 20);
        let p = SamplingParams::greedy(4);
        let script = vec![10, 11, 12, 13];
        let mut engine = FakeBatchEngine::new(2, vec![script.clone(), script]);
        engine.resume_at = vec![2];
        let mut pool = BatchedDecode::new(engine, 32, 64);
        let a = pool
            .admit_prefixed(&[1, 2, 3, 4], 4, p, Rng::new(1), Some(&cache))
            .unwrap()
            .expect("slot");
        let b = pool
            .admit_prefixed(&[1, 2, 7, 8], 4, p, Rng::new(1), Some(&cache))
            .unwrap()
            .expect("slot");
        assert_eq!(pool.engine().prefills, 1);
        assert_eq!(pool.engine().resumes, 1);
        assert_eq!(pool.engine().snapshots, 1);
        sweep_until_done(&mut pool, &[a, b]);
        let (tok_a, st_a) = pool.finish(a).unwrap();
        let (tok_b, st_b) = pool.finish(b).unwrap();
        assert_eq!(tok_a, tok_b, "resumed slot must stream identically");
        assert_eq!(st_a.restored_tokens, 0);
        assert_eq!(st_b.restored_tokens, 2);
        let s = cache.borrow().stats();
        assert_eq!((s.hits, s.misses, s.saved_tokens), (1, 1, 2));
    }

    #[test]
    fn fairness_round_is_one_dispatch() {
        // 4 live slots, equal-length scripts: each scheduler sweep must cost
        // exactly ONE batch dispatch — O(1), not O(slots × steps).
        // distinct per-slot token scripts, all inside the fake's 32-vocab
        let scripts: Vec<Vec<i32>> = (0..4)
            .map(|s| (0..6).map(|i| 4 + s * 6 + i).collect())
            .collect();
        let mut pool = BatchedDecode::new(FakeBatchEngine::new(4, scripts), 32, 64);
        let ids = [1, 1, 1];
        let slots: Vec<usize> = (0..4)
            .map(|i| {
                pool.admit(&ids, 3, SamplingParams::greedy(6), Rng::new(i))
                    .unwrap()
                    .expect("free slot")
            })
            .collect();
        sweep_until_done(&mut pool, &slots);
        for &s in &slots {
            assert_eq!(pool.tokens(s).len(), 6);
        }
        // 6 sampled tokens per slot = 5 steps; one dispatch per round, all
        // four slots riding each one.
        assert_eq!(pool.dispatches(), 5, "rounds, not slots × steps (= 20)");
        assert_eq!(pool.active_slot_sum(), 20);
        assert_eq!(pool.engine().prefills, 4);
    }

    #[test]
    fn slot_reuse_and_midflight_admission() {
        // A mid-batch EOS frees its slot; a third session admits into it
        // while the other slot is still decoding, and every stream is
        // exactly its script.
        let scripts = vec![
            vec![10, EOS_ID],
            vec![20, 21, 22, 23, 24, 25, 26, 27],
            vec![30, 31, EOS_ID],
        ];
        let mut pool = BatchedDecode::new(FakeBatchEngine::new(2, scripts), 32, 64);
        let ids = [1, 1, 1];
        let p = SamplingParams::greedy(8);
        let a = pool.admit(&ids, 3, p, Rng::new(1)).unwrap().expect("slot");
        let b = pool.admit(&ids, 3, p, Rng::new(2)).unwrap().expect("slot");
        assert_eq!(pool.free_slots(), 0);
        assert!(pool.admit(&ids, 3, p, Rng::new(3)).unwrap().is_none(), "pool full");
        while !pool.is_done(a) {
            pool.advance(a).unwrap();
            pool.advance(b).unwrap();
        }
        let (tok_a, stats_a) = pool.finish(a).unwrap();
        assert_eq!(tok_a, vec![10, EOS_ID]);
        assert_eq!(stats_a.generated_tokens, 2);
        assert!(stats_a.device_resident);
        let c = pool.admit(&ids, 3, p, Rng::new(3)).unwrap().expect("freed slot");
        assert_eq!(c, a, "mid-batch EOS must free its slot for reuse");
        sweep_until_done(&mut pool, &[b, c]);
        let (tok_b, _) = pool.finish(b).unwrap();
        let (tok_c, _) = pool.finish(c).unwrap();
        assert_eq!(tok_b, vec![20, 21, 22, 23, 24, 25, 26, 27]);
        assert_eq!(tok_c, vec![30, 31, EOS_ID]);
        assert_eq!(pool.free_slots(), 2);
    }

    #[test]
    fn failed_round_poisons_riders_and_reclaims_slots() {
        // A mid-round device error must neither leak slots nor hang owners:
        // every slot that rode the failed round observes the error on its
        // next advance()/finish(), frees its slot, and the pool keeps
        // serving fresh admissions afterwards.
        let scripts =
            vec![vec![10, 11, 12, 13], vec![20, 21, 22, 23], vec![5, 6, 7, 8]];
        let mut engine = FakeBatchEngine::new(2, scripts);
        engine.fail_on_dispatch = Some(1); // second collective round errors
        let mut pool = BatchedDecode::new(engine, 32, 64);
        let ids = [1, 1, 1];
        let p = SamplingParams::greedy(4);
        let a = pool.admit(&ids, 3, p, Rng::new(1)).unwrap().expect("slot");
        let b = pool.admit(&ids, 3, p, Rng::new(2)).unwrap().expect("slot");
        assert!(pool.advance(a).unwrap()); // round 0: healthy
        assert!(pool.advance(b).unwrap()); // banked credit
        let err = pool.advance(a).unwrap_err(); // round 1: injected fault
        assert!(err.to_string().contains("injected device fault"));
        // Peer b rode the same failed round: poisoned, not hung.
        let err_b = pool.advance(b).unwrap_err();
        assert!(err_b.to_string().contains("batched decode round failed"));
        assert!(pool.is_done(a) && pool.is_done(b));
        // finish() surfaces the stored error AND reclaims the slot.
        assert!(pool.finish(a).is_err());
        pool.release(b);
        assert_eq!(pool.free_slots(), 2, "failed slots must be reclaimed");
        let c = pool.admit(&ids, 3, p, Rng::new(3)).unwrap().expect("slot");
        sweep_until_done(&mut pool, &[c]);
        assert_eq!(pool.tokens(c), &[5, 6, 7, 8][..]);
    }

    #[test]
    fn batched_pool_edge_cases() {
        let mut pool = BatchedDecode::new(
            FakeBatchEngine::new(2, vec![vec![5, 6, 7]]),
            32,
            8, // max_seq
        );
        let ids8 = [1, 1, 1, 1, 1, 1, 1, 1];
        assert!(
            pool.admit(&ids8, 0, SamplingParams::greedy(4), Rng::new(1)).is_err(),
            "empty prompt must error"
        );
        // prompt_len == max_seq → zero token budget: done at admission, no
        // decode dispatch ever issued.
        let s = pool
            .admit(&ids8, 8, SamplingParams::greedy(4), Rng::new(1))
            .unwrap()
            .expect("slot");
        assert!(pool.is_done(s));
        assert!(!pool.advance(s).unwrap());
        let (toks, stats) = pool.finish(s).unwrap();
        assert!(toks.is_empty());
        assert_eq!(stats.generated_tokens, 0);
        assert_eq!(pool.dispatches(), 0);
        // operating on a free slot is an error / no-op
        assert!(pool.advance(s).is_err());
        assert!(pool.finish(s).is_err());
        assert!(pool.is_done(s), "free slots report done");
        pool.release(s); // idempotent
        assert_eq!(pool.free_slots(), 2);
    }

    #[test]
    fn session_zero_budget_generates_nothing() {
        let b = FakeBackend::new(None, vec![5]);
        let ids = [1, 1, 1];
        // prompt_len == max_seq → max_new == 0
        let mut s = DecodeSession::start(b, SamplingParams::greedy(8), &ids, 3, 3).unwrap();
        assert!(s.is_done());
        s.run(&mut Rng::new(1)).unwrap();
        let (tokens, stats) = s.finish();
        assert!(tokens.is_empty());
        assert_eq!(stats.generated_tokens, 0);
    }
}
