//! Runtime bridge: load AOT-compiled HLO-text artifacts and execute them on
//! the PJRT CPU client via the `xla` crate.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format
//! because jax ≥ 0.5 emits serialized protos with 64-bit instruction ids
//! that xla_extension 0.5.1 rejects.
//!
//! Calling convention (manifest): HLO params = [weights..., inputs...] and
//! the result is a tuple — except `untupled` artifacts (single output, bare
//! root), whose result buffer feeds straight back into the next execution:
//! the device-resident decode convention (DESIGN.md §Perf L2). Weights are
//! loaded once per model and shared across that model's executables.
//!
//! The slot-batched decode artifacts (`{m}_prefill_scatter{B}` /
//! `{m}_decode_batch{B}_res` / `{m}_peek_logits_batch{B}`) extend the same
//! convention to a `B * state_len` buffer carved into B slots; see
//! [`generator::BatchedDecode`].

pub mod embedder;
pub mod generator;
pub mod manifest;
pub mod prefix_cache;
pub mod weights;

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

pub use embedder::{Embedder, NativeBowEmbedder, TextEmbedder};
pub use generator::Generation;
pub use generator::{
    sample_token, sample_token_with, BatchEngine, BatchedDecode, DecodeBackend, DecodeSession,
    GenSession, Generator, GenerationStats, PjrtBatchEngine, SampleScratch, SamplingParams,
    SubstrateBatch,
};
pub use manifest::{ArtifactSpec, Dtype, IoSpec, Manifest};
pub use prefix_cache::{PrefixCache, PrefixCacheStats, PrefixHandle};

/// A compiled artifact plus its resident (on-device) weight arguments.
///
/// Weights are uploaded to device buffers ONCE and reused via `execute_b`.
/// This matters twice over: (a) the `xla` crate's literal-based `execute`
/// leaks every input's device buffer (`buffer.release()` in xla_rs.cc is
/// never freed), so repeated literal execution leaks the full weight set
/// per call; (b) re-uploading megabytes of weights per decode step would
/// dominate the step time. See EXPERIMENTS.md §Perf.
/// A host-side tensor destined for (or fetched from) the device.
///
/// Uploads go through `buffer_from_host_buffer`, whose
/// `kImmutableOnlyDuringCall` semantics force a synchronous copy — the only
/// safe upload path in this xla_extension build (`BufferFromHostLiteral` is
/// asynchronous and the wrapper neither awaits the transfer nor keeps the
/// literal alive: racing uploads crash in `CopyFromLiteral`).
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, dims: &[usize]) -> HostTensor {
        HostTensor::F32 { data, dims: dims.to_vec() }
    }

    pub fn i32(data: Vec<i32>, dims: &[usize]) -> HostTensor {
        HostTensor::I32 { data, dims: dims.to_vec() }
    }

    pub fn numel(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    /// Convert a fetched output literal back into a host tensor so it can
    /// be re-fed as an input (the literal-path KV-cache decode loop).
    pub fn from_literal(lit: &xla::Literal, spec: &IoSpec) -> Result<HostTensor> {
        Ok(match spec.dtype {
            Dtype::F32 => HostTensor::f32(lit.to_vec::<f32>()?, &spec.shape),
            Dtype::I32 => HostTensor::i32(lit.to_vec::<i32>()?, &spec.shape),
        })
    }
}

/// One argument to a buffer-level execution (`Executable::run_raw`): either
/// a tensor already resident on device (an output buffer fed back, the
/// decode hot path) or a borrowed host slice uploaded at call time with the
/// manifest input shape. Host variants borrow — callers reuse stack arrays
/// or scratch `Vec`s across steps instead of allocating per call.
#[derive(Clone, Copy)]
pub enum ExecArg<'a> {
    Device(&'a xla::PjRtBuffer),
    I32(&'a [i32]),
    F32(&'a [f32]),
}

impl<'a> From<&'a HostTensor> for ExecArg<'a> {
    fn from(t: &'a HostTensor) -> ExecArg<'a> {
        match t {
            HostTensor::F32 { data, .. } => ExecArg::F32(data),
            HostTensor::I32 { data, .. } => ExecArg::I32(data),
        }
    }
}

/// Weight set resident on device.
pub struct WeightSet {
    device: Vec<xla::PjRtBuffer>,
}

pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    weights: Arc<WeightSet>,
    client: xla::PjRtClient,
}

impl Executable {
    /// Literal-level execution: upload the host tensors, run, fetch every
    /// manifest output back to the host. The compatibility path — benches,
    /// tests, and the literal decode fallback all go through here.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<xla::Literal>> {
        let args: Vec<ExecArg> = inputs.iter().map(ExecArg::from).collect();
        let outs = self.run_raw(&args)?;
        self.fetch_outputs(&outs)
    }

    /// Buffer-level execution (the §Perf L2 hot path): uploads only the
    /// host-slice arguments, feeds `Device` arguments zero-copy, and
    /// returns the raw output buffers with NO device→host transfer. For a
    /// tuple-rooted artifact the result is a single tuple buffer (which
    /// this wrapper cannot untuple on device — fetch via `fetch_outputs`);
    /// for an `untupled` artifact it is the output array itself, which can
    /// be fed straight back into the next `run_raw` as `ExecArg::Device`.
    pub fn run_raw(&self, args: &[ExecArg]) -> Result<Vec<xla::PjRtBuffer>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                args.len()
            );
        }
        // Upload pass. `buffer_from_host_buffer` is the only safe upload in
        // this xla_extension build (synchronous copy; see HostTensor docs).
        let mut uploaded: Vec<xla::PjRtBuffer> = Vec::new();
        for (arg, spec) in args.iter().zip(&self.spec.inputs) {
            let buf = match *arg {
                ExecArg::Device(_) => continue,
                ExecArg::I32(d) => {
                    self.check_input(spec, d.len(), Dtype::I32)?;
                    self.client.buffer_from_host_buffer(d, &spec.shape, None)
                }
                ExecArg::F32(d) => {
                    self.check_input(spec, d.len(), Dtype::F32)?;
                    self.client.buffer_from_host_buffer(d, &spec.shape, None)
                }
            }
            .with_context(|| format!("uploading {} for {}", spec.name, self.spec.name))?;
            uploaded.push(buf);
        }
        // Assemble [weights..., inputs...] in manifest order.
        let mut refs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.weights.device.len() + args.len());
        refs.extend(self.weights.device.iter());
        let mut up = uploaded.iter();
        for arg in args {
            match *arg {
                ExecArg::Device(b) => refs.push(b),
                _ => refs.push(up.next().expect("uploaded host arg")),
            }
        }
        let mut outs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .with_context(|| format!("executing {}", self.spec.name))?;
        if outs.is_empty() {
            bail!("{}: empty execution result", self.spec.name);
        }
        Ok(outs.remove(0))
    }

    /// Validate one host argument against its manifest input spec.
    fn check_input(&self, spec: &IoSpec, got_len: usize, got_dtype: Dtype) -> Result<()> {
        if got_dtype != spec.dtype || got_len != spec.numel() {
            bail!(
                "{}: input {} has {} {:?} elements, expected {:?}[{}]",
                self.spec.name,
                spec.name,
                got_len,
                got_dtype,
                spec.dtype,
                spec.numel()
            );
        }
        Ok(())
    }

    /// Fetch every manifest output of a `run_raw` result to the host —
    /// tuple-aware: decomposes tuple roots, passes untupled roots through.
    pub fn fetch_outputs(&self, outs: &[xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let result = outs
            .first()
            .with_context(|| format!("{}: no output buffer", self.spec.name))?
            .to_literal_sync()
            .with_context(|| format!("fetching {} output", self.spec.name))?;
        if self.spec.untupled {
            // Single-output artifact without the tuple wrapper: the fetched
            // literal IS the output array.
            return Ok(vec![result]);
        }
        let parts = result
            .to_tuple()
            .with_context(|| format!("untupling {} output", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: manifest promises {} outputs, HLO returned {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        Ok(parts)
    }
}

/// Owns the PJRT client, the manifest, per-model weights and all compiled
/// executables. NOT `Sync` — the coordinator runs it on a dedicated engine
/// thread (the PJRT CPU client serializes compute anyway).
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    weights: BTreeMap<String, Arc<WeightSet>>,
    executables: BTreeMap<String, Arc<Executable>>,
}

impl Runtime {
    /// Load the manifest and eagerly compile the given artifacts (pass the
    /// empty slice to compile everything in the manifest).
    pub fn load(artifact_dir: &str, only: &[&str]) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut rt = Runtime {
            manifest,
            client,
            weights: BTreeMap::new(),
            executables: BTreeMap::new(),
        };
        let names: Vec<String> = if only.is_empty() {
            rt.manifest.artifacts.keys().cloned().collect()
        } else {
            only.iter().map(|s| s.to_string()).collect()
        };
        for name in names {
            rt.compile_artifact(&name)?;
        }
        Ok(rt)
    }

    /// Compile one artifact (idempotent), loading its weight set on demand.
    pub fn compile_artifact(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.artifact(name)?.clone();
        if spec.untupled && spec.outputs.len() != 1 {
            bail!(
                "{name}: untupled artifacts must have exactly one output, manifest lists {}",
                spec.outputs.len()
            );
        }
        let weights = match &spec.weight_set {
            Some(model) => self.model_weights(model)?,
            None => Arc::new(WeightSet { device: Vec::new() }),
        };
        if weights.device.len() != spec.n_weight_args {
            bail!(
                "{name}: weight set has {} tensors, artifact expects {}",
                weights.device.len(),
                spec.n_weight_args
            );
        }
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.executables.insert(
            name.to_string(),
            Arc::new(Executable { spec, exe, weights, client: self.client.clone() }),
        );
        Ok(())
    }

    fn model_weights(&mut self, model: &str) -> Result<Arc<WeightSet>> {
        if let Some(w) = self.weights.get(model) {
            return Ok(Arc::clone(w));
        }
        let spec = self.manifest.model(model)?.clone();
        let tensors = weights::load_weight_tensors(&self.manifest.dir, &spec)?;
        let bufs: Vec<xla::PjRtBuffer> = tensors
            .iter()
            .map(|(data, dims)| {
                self.client
                    .buffer_from_host_buffer(data, dims, None)
                    .context("uploading weights")
            })
            .collect::<Result<_>>()?;
        let arc = Arc::new(WeightSet { device: bufs });
        self.weights.insert(model.to_string(), Arc::clone(&arc));
        Ok(arc)
    }

    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        self.executables
            .get(name)
            .cloned()
            .with_context(|| format!("artifact {name:?} not compiled"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

// ---------------------------------------------------------------------------
// Extraction helpers shared by embedder & generator.
// ---------------------------------------------------------------------------

pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Integration tests that need built artifacts live in rust/tests/;
    // here we only check the pure helpers.
    #[test]
    fn host_tensor_numel() {
        let t = HostTensor::f32(vec![0.0; 6], &[2, 3]);
        assert_eq!(t.numel(), 6);
        let t = HostTensor::i32(vec![1, 2], &[2]);
        assert_eq!(t.numel(), 2);
    }
}
