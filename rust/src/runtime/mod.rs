//! Runtime bridge: load AOT-compiled HLO-text artifacts and execute them on
//! the PJRT CPU client via the `xla` crate.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format
//! because jax ≥ 0.5 emits serialized protos with 64-bit instruction ids
//! that xla_extension 0.5.1 rejects.
//!
//! Calling convention (manifest): HLO params = [weights..., inputs...] and
//! the result is a tuple. Weights are loaded once per model and shared
//! across that model's executables.

pub mod embedder;
pub mod generator;
pub mod manifest;
pub mod weights;

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

pub use embedder::{Embedder, NativeBowEmbedder, TextEmbedder};
pub use generator::Generation;
pub use generator::{Generator, SamplingParams};
pub use manifest::{ArtifactSpec, Dtype, IoSpec, Manifest};

/// A compiled artifact plus its resident (on-device) weight arguments.
///
/// Weights are uploaded to device buffers ONCE and reused via `execute_b`.
/// This matters twice over: (a) the `xla` crate's literal-based `execute`
/// leaks every input's device buffer (`buffer.release()` in xla_rs.cc is
/// never freed), so repeated literal execution leaks the full weight set
/// per call; (b) re-uploading megabytes of weights per decode step would
/// dominate the step time. See EXPERIMENTS.md §Perf.
/// A host-side tensor destined for (or fetched from) the device.
///
/// Uploads go through `buffer_from_host_buffer`, whose
/// `kImmutableOnlyDuringCall` semantics force a synchronous copy — the only
/// safe upload path in this xla_extension build (`BufferFromHostLiteral` is
/// asynchronous and the wrapper neither awaits the transfer nor keeps the
/// literal alive: racing uploads crash in `CopyFromLiteral`).
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, dims: &[usize]) -> HostTensor {
        HostTensor::F32 { data, dims: dims.to_vec() }
    }

    pub fn i32(data: Vec<i32>, dims: &[usize]) -> HostTensor {
        HostTensor::I32 { data, dims: dims.to_vec() }
    }

    pub fn numel(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    /// Convert a fetched output literal back into a host tensor so it can
    /// be re-fed as an input (the KV-cache decode loop).
    pub fn from_literal(lit: &xla::Literal, spec: &IoSpec) -> Result<HostTensor> {
        Ok(match spec.dtype {
            Dtype::F32 => HostTensor::f32(lit.to_vec::<f32>()?, &spec.shape),
            Dtype::I32 => HostTensor::i32(lit.to_vec::<i32>()?, &spec.shape),
        })
    }

    fn upload(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        match self {
            HostTensor::F32 { data, dims } => {
                Ok(client.buffer_from_host_buffer(data, dims, None)?)
            }
            HostTensor::I32 { data, dims } => {
                Ok(client.buffer_from_host_buffer(data, dims, None)?)
            }
        }
    }
}

/// Weight set resident on device.
pub struct WeightSet {
    device: Vec<xla::PjRtBuffer>,
}

pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    weights: Arc<WeightSet>,
    client: xla::PjRtClient,
}

impl Executable {
    /// Execute with the given non-weight inputs; returns the output tuple
    /// decomposed into one `Literal` per manifest output.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<xla::Literal>> {
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if t.numel() != spec.numel() {
                bail!(
                    "{}: input {} has {} elements, expected {}",
                    self.spec.name,
                    spec.name,
                    t.numel(),
                    spec.numel()
                );
            }
        }
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| t.upload(&self.client))
            .collect::<Result<_>>()
            .with_context(|| format!("uploading inputs for {}", self.spec.name))?;
        self.run_b(&bufs)
    }

    /// Execute with pre-uploaded input buffers (the zero-copy hot path).
    pub fn run_b(&self, inputs: &[xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.weights.device.len() + inputs.len());
        args.extend(self.weights.device.iter());
        args.extend(inputs.iter());
        let outs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let result = outs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} output", self.spec.name))?;
        let parts = result
            .to_tuple()
            .with_context(|| format!("untupling {} output", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: manifest promises {} outputs, HLO returned {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        Ok(parts)
    }
}

/// Owns the PJRT client, the manifest, per-model weights and all compiled
/// executables. NOT `Sync` — the coordinator runs it on a dedicated engine
/// thread (the PJRT CPU client serializes compute anyway).
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    weights: BTreeMap<String, Arc<WeightSet>>,
    executables: BTreeMap<String, Arc<Executable>>,
}

impl Runtime {
    /// Load the manifest and eagerly compile the given artifacts (pass the
    /// empty slice to compile everything in the manifest).
    pub fn load(artifact_dir: &str, only: &[&str]) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut rt = Runtime {
            manifest,
            client,
            weights: BTreeMap::new(),
            executables: BTreeMap::new(),
        };
        let names: Vec<String> = if only.is_empty() {
            rt.manifest.artifacts.keys().cloned().collect()
        } else {
            only.iter().map(|s| s.to_string()).collect()
        };
        for name in names {
            rt.compile_artifact(&name)?;
        }
        Ok(rt)
    }

    /// Compile one artifact (idempotent), loading its weight set on demand.
    pub fn compile_artifact(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let weights = match &spec.weight_set {
            Some(model) => self.model_weights(model)?,
            None => Arc::new(WeightSet { device: Vec::new() }),
        };
        if weights.device.len() != spec.n_weight_args {
            bail!(
                "{name}: weight set has {} tensors, artifact expects {}",
                weights.device.len(),
                spec.n_weight_args
            );
        }
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.executables.insert(
            name.to_string(),
            Arc::new(Executable { spec, exe, weights, client: self.client.clone() }),
        );
        Ok(())
    }

    fn model_weights(&mut self, model: &str) -> Result<Arc<WeightSet>> {
        if let Some(w) = self.weights.get(model) {
            return Ok(Arc::clone(w));
        }
        let spec = self.manifest.model(model)?.clone();
        let tensors = weights::load_weight_tensors(&self.manifest.dir, &spec)?;
        let bufs: Vec<xla::PjRtBuffer> = tensors
            .iter()
            .map(|(data, dims)| {
                self.client
                    .buffer_from_host_buffer(data, dims, None)
                    .context("uploading weights")
            })
            .collect::<Result<_>>()?;
        let arc = Arc::new(WeightSet { device: bufs });
        self.weights.insert(model.to_string(), Arc::clone(&arc));
        Ok(arc)
    }

    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        self.executables
            .get(name)
            .cloned()
            .with_context(|| format!("artifact {name:?} not compiled"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

// ---------------------------------------------------------------------------
// Extraction helpers shared by embedder & generator.
// ---------------------------------------------------------------------------

pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Integration tests that need built artifacts live in rust/tests/;
    // here we only check the pure helpers.
    #[test]
    fn host_tensor_numel() {
        let t = HostTensor::f32(vec![0.0; 6], &[2, 3]);
        assert_eq!(t.numel(), 6);
        let t = HostTensor::i32(vec![1, 2], &[2]);
        assert_eq!(t.numel(), 2);
    }
}
