//! Artifact manifest: the contract between `python/compile/aot.py` and this
//! runtime. Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            _ => bail!("unsupported dtype {s:?}"),
        }
    }
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: j.get("name")?.str()?.to_string(),
            shape: j
                .get("shape")?
                .arr()?
                .iter()
                .map(|d| d.usize())
                .collect::<Result<_>>()?,
            dtype: Dtype::parse(j.get("dtype")?.str()?)?,
        })
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub numel: usize,
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub weights_file: String,
    pub tensors: Vec<TensorSpec>,
    pub config: BTreeMap<String, f64>,
}

impl ModelSpec {
    pub fn cfg(&self, key: &str) -> Result<usize> {
        self.config
            .get(key)
            .map(|x| *x as usize)
            .with_context(|| format!("model config missing {key:?}"))
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub weight_set: Option<String>,
    pub n_weight_args: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Single-output artifacts lowered without the tuple wrapper
    /// (`return_tuple=False` in aot.py): the HLO root IS the output array,
    /// so a buffer-level execution can feed it straight back as an input —
    /// the device-resident decode convention. Absent in pre-resident
    /// manifests (defaults to false: tuple root).
    pub untupled: bool,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub vocab_size: usize,
    pub embed_dim: usize,
    pub models: BTreeMap<String, ModelSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        if j.get("format")?.str()? != "hlo-text-v1" {
            bail!("unknown manifest format");
        }

        let mut models = BTreeMap::new();
        for (name, m) in j.get("models")?.obj()? {
            let tensors = m
                .get("tensors")?
                .arr()?
                .iter()
                .map(|t| {
                    Ok(TensorSpec {
                        name: t.get("name")?.str()?.to_string(),
                        shape: t
                            .get("shape")?
                            .arr()?
                            .iter()
                            .map(|d| d.usize())
                            .collect::<Result<_>>()?,
                        offset: t.get("offset")?.usize()?,
                        numel: t.get("numel")?.usize()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let config = m
                .get("config")?
                .obj()?
                .iter()
                .filter_map(|(k, v)| v.f64().ok().map(|x| (k.clone(), x)))
                .collect();
            models.insert(
                name.clone(),
                ModelSpec {
                    weights_file: m.get("weights_file")?.str()?.to_string(),
                    tensors,
                    config,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in j.get("artifacts")?.arr()? {
            let spec = ArtifactSpec {
                name: a.get("name")?.str()?.to_string(),
                file: a.get("file")?.str()?.to_string(),
                weight_set: a
                    .opt("weight_set")
                    .map(|w| w.str().map(|s| s.to_string()))
                    .transpose()?,
                n_weight_args: a.get("n_weight_args")?.usize()?,
                inputs: a
                    .get("inputs")?
                    .arr()?
                    .iter()
                    .map(IoSpec::parse)
                    .collect::<Result<_>>()?,
                outputs: a
                    .get("outputs")?
                    .arr()?
                    .iter()
                    .map(IoSpec::parse)
                    .collect::<Result<_>>()?,
                untupled: a
                    .opt("untupled")
                    .map(|v| v.bool())
                    .transpose()?
                    .unwrap_or(false),
            };
            artifacts.insert(spec.name.clone(), spec);
        }

        Ok(Manifest {
            dir,
            vocab_size: j.get("vocab_size")?.usize()?,
            embed_dim: j.get("embed_dim")?.usize()?,
            models,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    /// Slot-batched decode bucket sizes compiled for `model` (ascending):
    /// every `B` with a `{model}_decode_batch{B}_res` manifest entry. Empty
    /// for pre-batched artifact sets — callers fall back to per-session
    /// decode dispatch.
    pub fn batch_buckets(&self, model: &str) -> Vec<usize> {
        let prefix = format!("{model}_decode_batch");
        let mut out: Vec<usize> = self
            .artifacts
            .keys()
            .filter_map(|name| name.strip_prefix(&prefix)?.strip_suffix("_res")?.parse().ok())
            .filter(|&b| b > 0)
            .collect();
        out.sort_unstable();
        out
    }

    /// Resume prefix chunk lengths compiled for `model` (ascending): every
    /// `P` with a `{model}_prefill_resume{P}` manifest entry. Empty for
    /// pre-resume artifact sets — callers fall back to cold prefill.
    pub fn resume_chunks(&self, model: &str) -> Vec<usize> {
        let prefix = format!("{model}_prefill_resume");
        let mut out: Vec<usize> = self
            .artifacts
            .keys()
            .filter_map(|name| name.strip_prefix(&prefix)?.parse().ok())
            .filter(|&p| p > 0)
            .collect();
        out.sort_unstable();
        out
    }

    /// Resume prefix chunk lengths compiled for `model`'s slot-batched
    /// prefill at bucket `batch` (ascending): every `P` with a
    /// `{model}_prefill_scatter_resume{batch}_{P}` manifest entry.
    pub fn batch_resume_chunks(&self, model: &str, batch: usize) -> Vec<usize> {
        let prefix = format!("{model}_prefill_scatter_resume{batch}_");
        let mut out: Vec<usize> = self
            .artifacts
            .keys()
            .filter_map(|name| name.strip_prefix(&prefix)?.parse().ok())
            .filter(|&p| p > 0)
            .collect();
        out.sort_unstable();
        out
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("twk-man-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":"hlo-text-v1","vocab_size":8192,"embed_dim":384,
                "models":{"m":{"weights_file":"weights/m.bin","config":{"d_model":128},
                  "tensors":[{"name":"w","shape":[2,3],"offset":0,"numel":6}]}},
                "artifacts":[{"name":"a","file":"a.hlo.txt","weight_set":"m",
                  "n_weight_args":1,
                  "inputs":[{"name":"x","shape":[4],"dtype":"int32"}],
                  "outputs":[{"name":"y","shape":[4],"dtype":"float32"}]},
                 {"name":"b","file":"b.hlo.txt","weight_set":"m",
                  "n_weight_args":1,"untupled":true,
                  "inputs":[{"name":"x","shape":[4],"dtype":"int32"}],
                  "outputs":[{"name":"y","shape":[4],"dtype":"float32"}]}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.vocab_size, 8192);
        let a = m.artifact("a").unwrap();
        assert_eq!(a.inputs[0].dtype, Dtype::I32);
        assert_eq!(a.outputs[0].numel(), 4);
        // tuple-ness: absent -> tuple root; "untupled": true -> bare root
        assert!(!a.untupled);
        assert!(m.artifact("b").unwrap().untupled);
        assert_eq!(m.model("m").unwrap().cfg("d_model").unwrap(), 128);
        assert!(m.artifact("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batch_buckets_enumerates_batched_decode_sizes() {
        let dir =
            std::env::temp_dir().join(format!("twk-man-bb-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let art = |name: &str| {
            format!(
                r#"{{"name":"{name}","file":"{name}.hlo.txt",
                    "n_weight_args":0,"untupled":true,
                    "inputs":[{{"name":"x","shape":[4],"dtype":"float32"}}],
                    "outputs":[{{"name":"y","shape":[4],"dtype":"float32"}}]}}"#
            )
        };
        std::fs::write(
            dir.join("manifest.json"),
            format!(
                r#"{{"format":"hlo-text-v1","vocab_size":8,"embed_dim":4,
                    "models":{{}},"artifacts":[{},{},{},{}]}}"#,
                art("m_decode_batch8_res"),
                art("m_decode_batch4_res"),
                art("m_decode_batchx_res"), // unparsable size: skipped
                art("m_decode"),            // per-session artifact: skipped
            ),
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.batch_buckets("m"), vec![4, 8]);
        assert!(m.batch_buckets("other").is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_chunks_enumerates_prefix_boundaries() {
        let dir =
            std::env::temp_dir().join(format!("twk-man-rc-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let art = |name: &str| {
            format!(
                r#"{{"name":"{name}","file":"{name}.hlo.txt",
                    "n_weight_args":0,"untupled":true,
                    "inputs":[{{"name":"x","shape":[4],"dtype":"float32"}}],
                    "outputs":[{{"name":"y","shape":[4],"dtype":"float32"}}]}}"#
            )
        };
        std::fs::write(
            dir.join("manifest.json"),
            format!(
                r#"{{"format":"hlo-text-v1","vocab_size":8,"embed_dim":4,
                    "models":{{}},"artifacts":[{},{},{},{},{},{}]}}"#,
                art("m_prefill_resume128"),
                art("m_prefill_resume64"),
                art("m_prefill_res"), // resident prefill, not a resume: skipped
                art("m_prefill_scatter_resume8_64"),
                art("m_prefill_scatter_resume8_128"),
                art("m_prefill_scatter_resume4_64"),
            ),
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.resume_chunks("m"), vec![64, 128]);
        assert!(m.resume_chunks("other").is_empty());
        assert_eq!(m.batch_resume_chunks("m", 8), vec![64, 128]);
        assert_eq!(m.batch_resume_chunks("m", 4), vec![64]);
        assert!(m.batch_resume_chunks("m", 2).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
