//! Weight loading: raw little-endian f32 blobs (written by
//! `python/compile/params.py::export_weights`) → per-tensor host arrays in
//! manifest order (= HLO argument order). The runtime uploads them to
//! device buffers once, via the synchronous-copy path.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::ModelSpec;

/// Read the weight file and slice it into `(data, dims)` tensors.
pub fn load_weight_tensors(
    dir: &Path,
    spec: &ModelSpec,
) -> Result<Vec<(Vec<f32>, Vec<usize>)>> {
    let path = dir.join(&spec.weights_file);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading weights {path:?}"))?;
    let expected: usize = spec.tensors.iter().map(|t| t.numel * 4).sum();
    if bytes.len() != expected {
        bail!(
            "weight file {path:?} is {} bytes, manifest expects {}",
            bytes.len(),
            expected
        );
    }
    let mut out = Vec::with_capacity(spec.tensors.len());
    for t in &spec.tensors {
        let start = t.offset;
        let end = start + t.numel * 4;
        let mut data = Vec::with_capacity(t.numel);
        for chunk in bytes[start..end].chunks_exact(4) {
            data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        out.push((data, t.shape.clone()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorSpec;
    use std::collections::BTreeMap;

    #[test]
    fn loads_and_slices() {
        let dir = std::env::temp_dir().join(format!("twk-w-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let vals: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("w.bin"), &bytes).unwrap();
        let spec = ModelSpec {
            weights_file: "w.bin".into(),
            tensors: vec![
                TensorSpec { name: "a".into(), shape: vec![2, 3], offset: 0, numel: 6 },
                TensorSpec { name: "b".into(), shape: vec![4], offset: 24, numel: 4 },
            ],
            config: BTreeMap::new(),
        };
        let tensors = load_weight_tensors(&dir, &spec).unwrap();
        assert_eq!(tensors.len(), 2);
        assert_eq!(tensors[0].0, vals[..6]);
        assert_eq!(tensors[0].1, vec![2, 3]);
        assert_eq!(tensors[1].0, vals[6..]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn size_mismatch_rejected() {
        let dir = std::env::temp_dir().join(format!("twk-w2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("w.bin"), [0u8; 8]).unwrap();
        let spec = ModelSpec {
            weights_file: "w.bin".into(),
            tensors: vec![TensorSpec {
                name: "a".into(),
                shape: vec![4],
                offset: 0,
                numel: 4,
            }],
            config: BTreeMap::new(),
        };
        assert!(load_weight_tensors(&dir, &spec).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
