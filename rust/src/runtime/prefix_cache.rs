//! Cross-request KV prefix cache: a radix tree (token trie with compressed
//! edges) mapping literal token prefixes to packed `k ‖ v ‖ tail` prefill
//! states, so a tweak prefill whose leading tokens were already prefilled by
//! an earlier request restores the cached K/V rows and recomputes only the
//! suffix (`{model}_prefill_resume{P}` artifacts).
//!
//! Keying is the literal token sequence — prompt *structure* is irrelevant,
//! which is what makes the tree correct under any prompt template as long
//! as shared content tokenizes to a shared prefix. Snapshots are stored at
//! the static chunk depths the artifacts were compiled for (the caller
//! decides the depths; the tree is depth-agnostic), and one snapshot —
//! a full packed post-prefill state — serves every chunk depth below its
//! prompt length, because a resume at depth `P` reads only K/V[:, :P].
//!
//! Lifecycle: `lookup` returns the *deepest* stored prefix strictly shorter
//! than the prompt and pins it (ref-counted [`PrefixHandle`], released on
//! drop) so an in-flight session's basis state can never be evicted under
//! it. Eviction is LRU over unpinned entries, under a byte budget
//! (`[runtime] prefix_cache_bytes`); the budget bounds resident snapshot
//! bytes, counting each entry at its full state size even when several
//! chunk depths share one snapshot `Rc` (conservative, and what keeps the
//! accounting O(1) on eviction).
//!
//! Single-threaded by design, like the rest of the substrate serving stack:
//! the engine thread owns the models, so `Rc<RefCell<PrefixCache>>` is the
//! sharing primitive (one cache per model; states of different models have
//! different widths and must never mix).

use std::cell::RefCell;
use std::rc::Rc;

/// Hit/miss/eviction counters plus saved-token accounting, surfaced through
/// `LanguageModel::prefix_stats` into `EngineStats` and the TCP `stats`
/// verb.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixCacheStats {
    /// Lookups that returned a pinned prefix.
    pub hits: u64,
    /// Lookups that found no usable prefix.
    pub misses: u64,
    /// Entries removed by the LRU to fit the byte budget.
    pub evictions: u64,
    /// Prompt tokens restored from cache instead of recomputed (sum of hit
    /// depths).
    pub saved_tokens: u64,
    /// Entries currently resident.
    pub entries: usize,
    /// Snapshot bytes currently resident.
    pub bytes: usize,
}

impl PrefixCacheStats {
    /// Combine the per-model caches for engine-level reporting.
    pub fn merge(
        a: Option<PrefixCacheStats>,
        b: Option<PrefixCacheStats>,
    ) -> Option<PrefixCacheStats> {
        match (a, b) {
            (None, None) => None,
            (Some(x), None) | (None, Some(x)) => Some(x),
            (Some(x), Some(y)) => Some(PrefixCacheStats {
                hits: x.hits + y.hits,
                misses: x.misses + y.misses,
                evictions: x.evictions + y.evictions,
                saved_tokens: x.saved_tokens + y.saved_tokens,
                entries: x.entries + y.entries,
                bytes: x.bytes + y.bytes,
            }),
        }
    }
}

/// One compressed-edge radix-tree node. `entry` holds the snapshot stored
/// at exactly this node's depth, if any.
#[derive(Default)]
struct Node {
    edges: Vec<Edge>,
    entry: Option<usize>,
}

struct Edge {
    label: Vec<i32>,
    child: usize,
}

struct Entry {
    state: Rc<Vec<f32>>,
    /// Token depth of this prefix (== resume chunk length).
    depth: usize,
    /// Owning node, so eviction can clear the back-pointer.
    node: usize,
    /// In-flight sessions holding a [`PrefixHandle`] to this entry.
    pins: u32,
    /// LRU clock value of the last lookup/insert touch.
    last_used: u64,
    bytes: usize,
}

/// The cache proper. Obtain handles through the `Rc<RefCell<_>>`-taking
/// associated functions so pins can be released on handle drop.
pub struct PrefixCache {
    budget_bytes: usize,
    nodes: Vec<Node>,
    /// Slab: evicted slots are `None` and reused.
    entries: Vec<Option<Entry>>,
    free: Vec<usize>,
    tick: u64,
    bytes: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
    saved_tokens: u64,
}

impl PrefixCache {
    pub fn new(budget_bytes: usize) -> PrefixCache {
        PrefixCache {
            budget_bytes,
            nodes: vec![Node::default()],
            entries: Vec::new(),
            free: Vec::new(),
            tick: 0,
            bytes: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            saved_tokens: 0,
        }
    }

    /// Wrap for sharing between the session layer and the backends.
    pub fn shared(budget_bytes: usize) -> Rc<RefCell<PrefixCache>> {
        Rc::new(RefCell::new(PrefixCache::new(budget_bytes)))
    }

    pub fn stats(&self) -> PrefixCacheStats {
        PrefixCacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            saved_tokens: self.saved_tokens,
            entries: self.entries.iter().flatten().count(),
            bytes: self.bytes,
        }
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Longest-prefix lookup: the deepest stored prefix of `ids` that is
    /// *strictly* shorter than `ids` (a resume needs at least one suffix
    /// token). Pins the entry; the handle unpins on drop. Counts a hit
    /// (+ saved tokens) or a miss.
    pub fn lookup(
        this: &Rc<RefCell<PrefixCache>>,
        ids: &[i32],
    ) -> Option<PrefixHandle> {
        Self::lookup_within(this, ids, None)
    }

    /// [`Self::lookup`] restricted to `allowed` depths — the chunk lengths
    /// the caller's transport actually compiled resume artifacts for. A
    /// deeper entry at an unsupported depth is passed over in favor of the
    /// deepest *usable* one. `None` = any depth.
    pub fn lookup_within(
        this: &Rc<RefCell<PrefixCache>>,
        ids: &[i32],
        allowed: Option<&[usize]>,
    ) -> Option<PrefixHandle> {
        let (id, depth, state) = {
            let mut c = this.borrow_mut();
            match c.find(ids, allowed) {
                Some(id) => {
                    let tick = c.next_tick();
                    c.hits += 1;
                    let e = c.entries[id].as_mut().expect("live entry");
                    e.pins += 1;
                    e.last_used = tick;
                    c.saved_tokens += e.depth as u64;
                    let e = c.entries[id].as_ref().expect("live entry");
                    (id, e.depth, Rc::clone(&e.state))
                }
                None => {
                    c.misses += 1;
                    return None;
                }
            }
        };
        Some(PrefixHandle { cache: Rc::clone(this), entry: id, depth, state })
    }

    /// Walk the tree; return the deepest live entry at depth < ids.len()
    /// (and, when `allowed` is given, at one of the allowed depths).
    fn find(&self, ids: &[i32], allowed: Option<&[usize]>) -> Option<usize> {
        let mut node = 0;
        let mut depth = 0;
        let mut best = None;
        loop {
            if depth < ids.len() && allowed.is_none_or(|a| a.contains(&depth)) {
                if let Some(id) = self.nodes[node].entry {
                    best = Some(id);
                }
            }
            if depth >= ids.len() {
                break;
            }
            let Some(edge) =
                self.nodes[node].edges.iter().find(|e| e.label[0] == ids[depth])
            else {
                break;
            };
            // The whole label must match: entries only live at node depths,
            // so a partial-label match cannot reach one.
            if ids.len() - depth < edge.label.len()
                || ids[depth..depth + edge.label.len()] != edge.label[..]
            {
                break;
            }
            depth += edge.label.len();
            node = edge.child;
        }
        best
    }

    /// Store a snapshot for the exact prefix `prefix` (depth =
    /// `prefix.len()`). First writer wins: re-inserting an existing prefix
    /// only refreshes its LRU position. Returns whether a new entry landed.
    /// Entries wider than the whole budget are refused.
    pub fn insert(&mut self, prefix: &[i32], state: Rc<Vec<f32>>) -> bool {
        let bytes = state.len() * std::mem::size_of::<f32>();
        if prefix.is_empty() || bytes > self.budget_bytes {
            return false;
        }
        let node = self.node_at(prefix);
        let tick = self.next_tick();
        if let Some(id) = self.nodes[node].entry {
            if let Some(e) = self.entries[id].as_mut() {
                e.last_used = tick;
            }
            return false;
        }
        let entry = Entry {
            state,
            depth: prefix.len(),
            node,
            pins: 0,
            last_used: tick,
            bytes,
        };
        let id = match self.free.pop() {
            Some(slot) => {
                self.entries[slot] = Some(entry);
                slot
            }
            None => {
                self.entries.push(Some(entry));
                self.entries.len() - 1
            }
        };
        self.nodes[node].entry = Some(id);
        self.bytes += bytes;
        self.evict_to_budget();
        true
    }

    /// Walk to (creating / splitting as needed) the node at exactly
    /// `prefix`'s depth.
    fn node_at(&mut self, prefix: &[i32]) -> usize {
        let mut node = 0;
        let mut i = 0;
        while i < prefix.len() {
            let rest = &prefix[i..];
            let Some(ei) =
                self.nodes[node].edges.iter().position(|e| e.label[0] == rest[0])
            else {
                let child = self.nodes.len();
                self.nodes.push(Node::default());
                self.nodes[node].edges.push(Edge { label: rest.to_vec(), child });
                return child;
            };
            let label_len = self.nodes[node].edges[ei].label.len();
            let common = self.nodes[node].edges[ei]
                .label
                .iter()
                .zip(rest)
                .take_while(|(a, b)| a == b)
                .count();
            if common == label_len {
                node = self.nodes[node].edges[ei].child;
            } else {
                // Split the edge at the divergence point: parent -> mid
                // keeps label[..common], mid -> old child the remainder.
                let mid = self.nodes.len();
                self.nodes.push(Node::default());
                let edge = &mut self.nodes[node].edges[ei];
                let tail = edge.label.split_off(common);
                let old_child = std::mem::replace(&mut edge.child, mid);
                self.nodes[mid].edges.push(Edge { label: tail, child: old_child });
                node = mid;
            }
            i += common;
        }
        node
    }

    /// Evict least-recently-used *unpinned* entries until within budget.
    /// Pinned entries are invisible to the LRU scan — the pinning
    /// invariant — so the cache can transiently exceed its budget while
    /// every resident prefix is in flight.
    fn evict_to_budget(&mut self) {
        while self.bytes > self.budget_bytes {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter_map(|(id, e)| e.as_ref().map(|e| (id, e)))
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(id, _)| id);
            let Some(id) = victim else {
                break;
            };
            let e = self.entries[id].take().expect("victim is live");
            self.nodes[e.node].entry = None;
            self.bytes -= e.bytes;
            self.free.push(id);
            self.evictions += 1;
        }
    }

    fn unpin(&mut self, id: usize) {
        if let Some(e) = self.entries[id].as_mut() {
            e.pins = e.pins.saturating_sub(1);
        }
        // A release may make room the last over-budget insert could not.
        self.evict_to_budget();
    }
}

/// A pinned prefix snapshot held by an in-flight session. Keeps the state
/// `Rc` alive and the entry unevictable until dropped.
pub struct PrefixHandle {
    cache: Rc<RefCell<PrefixCache>>,
    entry: usize,
    depth: usize,
    state: Rc<Vec<f32>>,
}

impl PrefixHandle {
    /// Token depth of the restored prefix (the resume chunk length).
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// The packed `k ‖ v ‖ tail` state to feed the resume artifact.
    pub fn state(&self) -> &[f32] {
        &self.state
    }
}

impl Drop for PrefixHandle {
    fn drop(&mut self) {
        self.cache.borrow_mut().unpin(self.entry);
    }
}

impl std::fmt::Debug for PrefixHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PrefixHandle")
            .field("entry", &self.entry)
            .field("depth", &self.depth)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(n: usize, fill: f32) -> Rc<Vec<f32>> {
        Rc::new(vec![fill; n])
    }

    fn toks(ids: &[i32]) -> Vec<i32> {
        ids.to_vec()
    }

    #[test]
    fn longest_prefix_lookup_is_strict_and_deepest() {
        let c = PrefixCache::shared(1 << 20);
        c.borrow_mut().insert(&toks(&[1, 2, 3]), state(8, 3.0));
        c.borrow_mut().insert(&toks(&[1, 2, 3, 4, 5]), state(8, 5.0));

        let h = PrefixCache::lookup(&c, &[1, 2, 3, 4, 5, 9]).expect("deep hit");
        assert_eq!(h.depth(), 5);
        assert_eq!(h.state()[0], 5.0);
        drop(h);

        let h = PrefixCache::lookup(&c, &[1, 2, 3, 9]).expect("shallow hit");
        assert_eq!(h.depth(), 3);
        assert_eq!(h.state()[0], 3.0);
        drop(h);

        // Exact-length match is useless for a resume (no suffix): strict.
        assert!(PrefixCache::lookup(&c, &[1, 2, 3]).is_none());
        // Deeper entry unusable, shallower one still strict-shorter.
        let h = PrefixCache::lookup(&c, &[1, 2, 3, 4, 5]).expect("fallback");
        assert_eq!(h.depth(), 3);
        drop(h);
        assert!(PrefixCache::lookup(&c, &[2, 2, 3, 4]).is_none());

        let s = c.borrow().stats();
        assert_eq!(s.hits, 3);
        assert_eq!(s.misses, 2);
        assert_eq!(s.saved_tokens, 5 + 3 + 3);
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn lookup_within_restricts_to_allowed_depths() {
        // A transport only resumes at its compiled chunk lengths: a deeper
        // entry at an unsupported depth must be passed over.
        let c = PrefixCache::shared(1 << 20);
        c.borrow_mut().insert(&toks(&[1, 2]), state(4, 2.0));
        c.borrow_mut().insert(&toks(&[1, 2, 3, 4]), state(4, 4.0));
        let h = PrefixCache::lookup_within(&c, &[1, 2, 3, 4, 5], Some(&[2])).unwrap();
        assert_eq!((h.depth(), h.state()[0]), (2, 2.0));
        drop(h);
        assert!(PrefixCache::lookup_within(&c, &[1, 2, 3, 4, 5], Some(&[8])).is_none());
        let h = PrefixCache::lookup_within(&c, &[1, 2, 3, 4, 5], None).unwrap();
        assert_eq!(h.depth(), 4);
        drop(h);
        let s = c.borrow().stats();
        assert_eq!((s.hits, s.misses, s.saved_tokens), (2, 1, 6));
    }

    #[test]
    fn edge_splitting_keeps_divergent_prefixes_apart() {
        let c = PrefixCache::shared(1 << 20);
        c.borrow_mut().insert(&toks(&[1, 2, 3, 4]), state(4, 1.0));
        // Diverges mid-edge: forces a split at depth 2.
        c.borrow_mut().insert(&toks(&[1, 2, 9, 9]), state(4, 2.0));
        c.borrow_mut().insert(&toks(&[1, 2]), state(4, 0.5));

        let h = PrefixCache::lookup(&c, &[1, 2, 3, 4, 7]).unwrap();
        assert_eq!((h.depth(), h.state()[0]), (4, 1.0));
        drop(h);
        let h = PrefixCache::lookup(&c, &[1, 2, 9, 9, 7]).unwrap();
        assert_eq!((h.depth(), h.state()[0]), (4, 2.0));
        drop(h);
        let h = PrefixCache::lookup(&c, &[1, 2, 8]).unwrap();
        assert_eq!((h.depth(), h.state()[0]), (2, 0.5));
    }

    #[test]
    fn reinsert_refreshes_but_does_not_replace() {
        let c = PrefixCache::shared(1 << 20);
        assert!(c.borrow_mut().insert(&toks(&[1, 2]), state(4, 1.0)));
        assert!(!c.borrow_mut().insert(&toks(&[1, 2]), state(4, 9.0)));
        let h = PrefixCache::lookup(&c, &[1, 2, 3]).unwrap();
        assert_eq!(h.state()[0], 1.0, "first writer wins");
        let s = c.borrow().stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 16);
    }

    #[test]
    fn lru_evicts_least_recently_used_under_byte_budget() {
        // Budget fits exactly two 40-byte entries.
        let c = PrefixCache::shared(80);
        c.borrow_mut().insert(&toks(&[1]), state(10, 1.0));
        c.borrow_mut().insert(&toks(&[2]), state(10, 2.0));
        // Touch [1] so [2] becomes the LRU victim.
        drop(PrefixCache::lookup(&c, &[1, 7]).unwrap());
        c.borrow_mut().insert(&toks(&[3]), state(10, 3.0));

        assert!(PrefixCache::lookup(&c, &[1, 7]).is_some());
        assert!(PrefixCache::lookup(&c, &[2, 7]).is_none(), "LRU victim");
        assert!(PrefixCache::lookup(&c, &[3, 7]).is_some());
        let s = c.borrow().stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        assert_eq!(s.bytes, 80);
    }

    #[test]
    fn oversized_entry_is_refused() {
        let c = PrefixCache::shared(16);
        assert!(!c.borrow_mut().insert(&toks(&[1]), state(10, 1.0)));
        assert_eq!(c.borrow().stats().bytes, 0);
    }

    #[test]
    fn pinned_entries_survive_eviction_pressure() {
        let c = PrefixCache::shared(40);
        c.borrow_mut().insert(&toks(&[1]), state(10, 1.0));
        let pinned = PrefixCache::lookup(&c, &[1, 7]).expect("pin it");
        // Over budget with the pinned entry resident: the new entry is the
        // only unpinned one, so IT gets evicted, never the pinned basis.
        c.borrow_mut().insert(&toks(&[2]), state(10, 2.0));
        assert!(PrefixCache::lookup(&c, &[2, 7]).is_none());
        assert_eq!(pinned.state()[0], 1.0);

        // Releasing the pin lets the next pressure evict it normally.
        drop(pinned);
        c.borrow_mut().insert(&toks(&[3]), state(10, 3.0));
        assert!(PrefixCache::lookup(&c, &[3, 7]).is_some());
        assert!(PrefixCache::lookup(&c, &[1, 7]).is_none());
    }

    #[test]
    fn everything_pinned_transiently_exceeds_budget_then_recovers() {
        let c = PrefixCache::shared(40);
        c.borrow_mut().insert(&toks(&[1]), state(10, 1.0));
        let pin = PrefixCache::lookup(&c, &[1, 9]).unwrap();
        c.borrow_mut().insert(&toks(&[2]), state(5, 2.0));
        let pin2 = PrefixCache::lookup(&c, &[2, 9]).unwrap();
        assert!(c.borrow().stats().bytes > 40, "both pinned: over budget");
        drop(pin);
        // Unpin triggers deferred eviction back under budget.
        assert!(c.borrow().stats().bytes <= 40);
        drop(pin2);
    }

    #[test]
    fn one_snapshot_serves_multiple_chunk_depths() {
        // The generator registers a single post-prefill snapshot Rc at
        // every supported chunk boundary below the prompt length.
        let c = PrefixCache::shared(1 << 20);
        let snap = state(16, 7.0);
        let ids: Vec<i32> = (0..6).collect();
        c.borrow_mut().insert(&ids[..2], Rc::clone(&snap));
        c.borrow_mut().insert(&ids[..4], Rc::clone(&snap));
        let h = PrefixCache::lookup(&c, &ids[..5]).unwrap();
        assert_eq!(h.depth(), 4);
        drop(h);
        // A prompt diverging after depth 2 still reuses the shallow entry.
        let h = PrefixCache::lookup(&c, &[0, 1, 99, 99]).unwrap();
        assert_eq!(h.depth(), 2);
        assert_eq!(h.state()[0], 7.0);
    }
}
