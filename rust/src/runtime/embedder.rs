//! Embedder driver: text → 384-d L2-normalized embedding via the compiled
//! `embed_b{1,8,32}` artifacts.
//!
//! The dynamic batcher hands us up to 32 texts; we pick the smallest
//! compiled batch variant that fits and pad the remainder with empty rows
//! (their outputs are discarded). One executable per variant — XLA shapes
//! are static.

use anyhow::{bail, Result};

use super::{to_f32_vec, ExecArg, Executable, Runtime};
use crate::tokenizer::Tokenizer;

/// Anything that maps text to a fixed-dim L2-normalized vector.
///
/// Two implementations: [`Embedder`] runs the compiled `embed_b*` artifacts
/// (the production path — this is what every figure bench uses), and
/// [`NativeBowEmbedder`] is a pure-Rust bag-of-words random projection used
/// by unit tests that must run without artifacts and by scale smoke-tests.
/// The two agree qualitatively by construction: the compiled encoder is
/// deliberately bag-of-embeddings-dominant (see python/compile/configs.py).
// NB: deliberately NOT `Send` — the compiled implementation wraps PJRT
// handles (`Rc` internally). The engine thread constructs and owns it.
pub trait TextEmbedder {
    fn out_dim(&self) -> usize;

    /// Embed a batch of borrowed texts. `&[&str]` (not `&[String]`) so hot
    /// callers — `Engine::flush` re-embeds every queued query each batch —
    /// never clone the query strings just to build the argument.
    fn embed_batch(&self, texts: &[&str]) -> Result<Vec<Vec<f32>>>;

    fn embed(&self, text: &str) -> Result<Vec<f32>> {
        Ok(self.embed_batch(&[text])?.remove(0))
    }
}

pub struct Embedder {
    variants: Vec<(usize, std::sync::Arc<Executable>)>, // sorted by batch
    tokenizer: Tokenizer,
    max_seq: usize,
    out_dim: usize,
    /// Reusable upload staging for the token/length tensors — the batcher
    /// calls `embed_batch` on every flush, so the per-chunk `Vec` churn is
    /// hot-path allocation (same treatment as the decode scratch).
    tok_scratch: std::cell::RefCell<Vec<i32>>,
    len_scratch: std::cell::RefCell<Vec<i32>>,
}

impl Embedder {
    pub fn new(rt: &Runtime) -> Result<Embedder> {
        let enc = rt.manifest.model("encoder")?;
        let max_seq = enc.cfg("max_seq")?;
        let out_dim = enc.cfg("out_dim")?;
        let mut variants = Vec::new();
        for (name, spec) in &rt.manifest.artifacts {
            if let Some(b) = name.strip_prefix("embed_b") {
                if let Ok(batch) = b.parse::<usize>() {
                    debug_assert_eq!(spec.inputs[0].shape[0], batch);
                    variants.push((batch, rt.executable(name)?));
                }
            }
        }
        if variants.is_empty() {
            bail!("no embed_b* artifacts compiled");
        }
        variants.sort_by_key(|(b, _)| *b);
        Ok(Embedder {
            variants,
            tokenizer: Tokenizer::new(rt.manifest.vocab_size),
            max_seq,
            out_dim,
            tok_scratch: std::cell::RefCell::new(Vec::new()),
            len_scratch: std::cell::RefCell::new(Vec::new()),
        })
    }

    pub fn max_batch(&self) -> usize {
        self.variants.last().map(|(b, _)| *b).unwrap_or(1)
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    fn embed_chunk(&self, texts: &[&str]) -> Result<Vec<Vec<f32>>> {
        let (batch, exe) = self
            .variants
            .iter()
            .find(|(b, _)| *b >= texts.len())
            .unwrap_or_else(|| self.variants.last().unwrap());
        let batch = *batch;
        let mut tokens = self.tok_scratch.borrow_mut();
        let mut lengths = self.len_scratch.borrow_mut();
        tokens.clear();
        lengths.clear();
        tokens.reserve(batch * self.max_seq);
        for i in 0..batch {
            let text = texts.get(i).copied().unwrap_or("");
            let (ids, len) = self.tokenizer.encode_padded(text, self.max_seq);
            tokens.extend(ids);
            lengths.push(len as i32);
        }
        // Buffer-level execution with a single fetch of the embeddings
        // output (the decode hot path's logits-only treatment: untupled
        // artifacts skip the host-side tuple decomposition entirely).
        let outs = exe.run_raw(&[ExecArg::I32(&tokens), ExecArg::I32(&lengths)])?;
        let outputs = exe.fetch_outputs(&outs)?;
        let flat = to_f32_vec(&outputs[0])?;
        debug_assert_eq!(flat.len(), batch * self.out_dim);
        Ok(texts
            .iter()
            .enumerate()
            .map(|(i, _)| flat[i * self.out_dim..(i + 1) * self.out_dim].to_vec())
            .collect())
    }
}

impl TextEmbedder for Embedder {
    fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Embed up to `max_batch()` texts per executable call; larger slices
    /// are chunked.
    fn embed_batch(&self, texts: &[&str]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(texts.len());
        for chunk in texts.chunks(self.max_batch()) {
            out.extend(self.embed_chunk(chunk)?);
        }
        Ok(out)
    }
}

/// Pure-Rust bag-of-words embedder: each word hashes to a deterministic
/// random unit vector; a sentence is the mean of its word vectors plus a
/// small word-order perturbation, L2-normalized. Mirrors the compiled
/// encoder's similarity structure (token overlap → high cosine) without
/// requiring artifacts. Used in unit tests and very-large-N smoke sweeps.
pub struct NativeBowEmbedder {
    dim: usize,
    seed: u64,
}

impl NativeBowEmbedder {
    pub fn new(dim: usize, seed: u64) -> Self {
        NativeBowEmbedder { dim, seed }
    }

    fn word_vec(&self, word: &str, out: &mut [f32], scale: f32) {
        let mut rng = crate::util::Rng::new(
            crate::util::rng::hash_bytes(word.as_bytes()) ^ self.seed,
        );
        for o in out.iter_mut() {
            *o += scale * rng.normal() as f32;
        }
    }
}

impl TextEmbedder for NativeBowEmbedder {
    fn out_dim(&self) -> usize {
        self.dim
    }

    fn embed_batch(&self, texts: &[&str]) -> Result<Vec<Vec<f32>>> {
        Ok(texts
            .iter()
            .map(|t| {
                let words = Tokenizer::words(t);
                let mut v = vec![0.0f32; self.dim];
                for (i, w) in words.iter().enumerate() {
                    // mirror the compiled encoder's IDF downweighting
                    let scale = if crate::tokenizer::is_function_word(w) {
                        0.22
                    } else {
                        1.0
                    };
                    self.word_vec(w, &mut v, scale);
                    // mild positional salt so pure reorders aren't cos=1.0
                    self.word_vec(&format!("{w}@{i}"), &mut v, 0.18 * scale);
                }
                if words.is_empty() {
                    v[0] = 1.0;
                }
                crate::util::normalize(&mut v);
                v
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bow_unit_norm_and_deterministic() {
        let e = NativeBowEmbedder::new(64, 7);
        let a = e.embed("why is rust fast").unwrap();
        let b = e.embed("why is rust fast").unwrap();
        assert_eq!(a, b);
        let n: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((n - 1.0).abs() < 1e-5);
    }

    #[test]
    fn bow_paraphrase_closer_than_unrelated() {
        let e = NativeBowEmbedder::new(128, 7);
        let base = e.embed("why is coffee good for health").unwrap();
        let para = e.embed("why is coffee great for health").unwrap();
        let unrel = e.embed("draft an email to my landlord").unwrap();
        let cos = |a: &[f32], b: &[f32]| crate::util::dot(a, b);
        assert!(cos(&base, &para) > cos(&base, &unrel));
        assert!(cos(&base, &para) > 0.6);
    }

    #[test]
    fn bow_polarity_flip_is_still_close() {
        // the false-positive regime the paper critiques: one-word flips
        // stay above typical thresholds
        let e = NativeBowEmbedder::new(128, 7);
        let good = e.embed("why is coffee good for health ?").unwrap();
        let bad = e.embed("why is coffee bad for health ?").unwrap();
        assert!(crate::util::dot(&good, &bad) > 0.55);
    }
}

