//! Per-request span tracing across the serving pipeline.
//!
//! Every request admitted by the engine gets a trace id and an ordered span
//! tree: ingest → batcher-wait → embed → search → route → queue-wait →
//! prefill → decode (with per-fairness-round child spans carrying slot
//! occupancy) → cache-insert → reply. The route span carries the similarity
//! score of the routing decision; the finished trace carries the pathway tag
//! (exact hit / tweak hit / miss / coalesced follower).
//!
//! Cost discipline: a [`TraceBuilder`] is a per-request arena — a `Vec` of
//! `(stage, start_us, end_us, value)` records plus two `Instant`s. Disabled
//! builders (tracing off) allocate nothing and every recording call is an
//! early-return. Completed traces land in [`TraceHub`]: a fixed-capacity
//! ring buffer, a threshold-gated slow-request list, and log-bucketed
//! per-stage × per-pathway histograms ([`LogHistogram`]) — all bounded
//! memory regardless of uptime.

use std::collections::VecDeque;
use std::fs::OpenOptions;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

use crate::config::TraceConfig;
use crate::metrics::LogHistogram;
use crate::util::Json;

/// Per-fairness-round child spans kept per trace; rounds beyond this are
/// counted (`decode_rounds`) but not materialized, bounding the arena.
pub const MAX_ROUND_SPANS: usize = 128;

/// Slow-request retention list capacity.
const SLOW_CAP: usize = 64;

/// Query text retained per trace (chars).
const QUERY_CAP: usize = 96;

/// Pipeline stages a span can describe. `DecodeRound` spans are children of
/// the `Decode` span (one per fairness round); everything else is depth 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Channel transit: `EngineHandle::request` send → engine thread pickup.
    Ingest,
    /// Time parked in the dynamic batcher awaiting batch-mates.
    BatcherWait,
    /// Embedding forward pass (batched: same interval for batch-mates).
    Embed,
    /// Vector index search.
    Search,
    /// Routing decision (threshold compare; exact-match lookup on hits).
    /// `value` = similarity score of the decision.
    Route,
    /// Scheduler admission queue (or, for coalesced followers, the wait for
    /// the leader's generation).
    QueueWait,
    /// Session start: prompt build + prefill dispatch. `value` = prompt
    /// tokens actually recomputed (total minus tokens restored from the
    /// cross-request KV prefix cache; equal to the prompt length on a cold
    /// prefill).
    Prefill,
    /// Generation: first decode step → EOS. `value` = generator-reported
    /// decode compute micros (the wall interval additionally contains
    /// fairness-round interleaving).
    Decode,
    /// One fairness-round turn within `Decode`. `value` = sessions active
    /// in that round (batch-slot occupancy).
    DecodeRound,
    /// Cache insert (embedding + response row append).
    CacheInsert,
    /// Response accounting + reply-channel send.
    Reply,
    /// Time-to-first-token: `[0, ttft]` wall offset of the first streamed
    /// delta leaving the engine (recorded once per trace; `value` = TTFT
    /// micros). Depth 2 — it overlays the depth-1 stage timeline rather
    /// than partitioning it.
    FirstToken,
    /// Cluster router: pick the shard owner, forward the request, and (on
    /// owner failure) fall back to the replica or the degradation ladder.
    /// `value` = shard index the request hashed to. Only present on traces
    /// recorded by the cluster front end.
    ShardRoute,
}

impl Stage {
    pub const ALL: [Stage; 13] = [
        Stage::Ingest,
        Stage::BatcherWait,
        Stage::Embed,
        Stage::Search,
        Stage::Route,
        Stage::QueueWait,
        Stage::Prefill,
        Stage::Decode,
        Stage::DecodeRound,
        Stage::CacheInsert,
        Stage::Reply,
        Stage::FirstToken,
        Stage::ShardRoute,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Ingest => "ingest",
            Stage::BatcherWait => "batcher_wait",
            Stage::Embed => "embed",
            Stage::Search => "search",
            Stage::Route => "route",
            Stage::QueueWait => "queue_wait",
            Stage::Prefill => "prefill",
            Stage::Decode => "decode",
            Stage::DecodeRound => "decode_round",
            Stage::CacheInsert => "cache_insert",
            Stage::Reply => "reply",
            Stage::FirstToken => "first_token",
            Stage::ShardRoute => "shard_route",
        }
    }

    fn index(self) -> usize {
        self as usize
    }

    /// Nesting depth in the span tree (DecodeRound nests under Decode;
    /// FirstToken overlays the whole pre-first-delta timeline).
    pub fn depth(self) -> usize {
        if self == Stage::DecodeRound || self == Stage::FirstToken {
            2
        } else {
            1
        }
    }
}

/// Pathway tag on a finished trace. Mirrors `coordinator::Pathway` plus the
/// coalesced-follower case (followers reuse the leader's generation, so the
/// response-level pathway hides that they waited instead of routing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceTag {
    ExactHit,
    TweakHit,
    Miss,
    Coalesced,
    /// Degradation-ladder outcome: the tweak step failed (error, timeout,
    /// deadline, or open breaker) and the raw cached response was served.
    DegradedHit,
    /// Terminal failure: the request was answered with a structured error
    /// (shed past its deadline, or every generation attempt failed).
    Failed,
}

impl TraceTag {
    pub const ALL: [TraceTag; 6] = [
        TraceTag::ExactHit,
        TraceTag::TweakHit,
        TraceTag::Miss,
        TraceTag::Coalesced,
        TraceTag::DegradedHit,
        TraceTag::Failed,
    ];

    pub fn name(self) -> &'static str {
        match self {
            TraceTag::ExactHit => "exact_hit",
            TraceTag::TweakHit => "tweak_hit",
            TraceTag::Miss => "miss",
            TraceTag::Coalesced => "coalesced",
            TraceTag::DegradedHit => "degraded_hit",
            TraceTag::Failed => "failed",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

/// One timed interval. Offsets are micros since the trace start (request
/// enqueue), so a span never needs an `Instant` once recorded.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub stage: Stage,
    pub start_us: u64,
    pub end_us: u64,
    /// Stage-specific payload (route similarity, round occupancy, decode
    /// compute micros); NaN = none.
    pub value: f32,
}

/// Per-request span arena. Obtained from [`TraceHub::begin`]; a disabled
/// builder (tracing off, or `Default`) never allocates and ignores all
/// recording calls.
#[derive(Debug)]
pub struct TraceBuilder {
    enabled: bool,
    id: u64,
    query: String,
    start: Instant,
    last_end: Instant,
    spans: Vec<Span>,
    similarity: f32,
    prefill_us: u64,
    decode_us: u64,
    prefill_tokens: u32,
    prefill_recomputed: u32,
    rounds: u32,
}

impl Default for TraceBuilder {
    fn default() -> Self {
        TraceBuilder::disabled()
    }
}

impl TraceBuilder {
    /// A no-op builder: recording calls return immediately, nothing is kept.
    pub fn disabled() -> TraceBuilder {
        let now = Instant::now();
        TraceBuilder {
            enabled: false,
            id: 0,
            query: String::new(),
            start: now,
            last_end: now,
            spans: Vec::new(),
            similarity: f32::NAN,
            prefill_us: 0,
            decode_us: 0,
            prefill_tokens: 0,
            prefill_recomputed: 0,
            rounds: 0,
        }
    }

    fn live(id: u64, query: String, start: Instant) -> TraceBuilder {
        TraceBuilder {
            enabled: true,
            id,
            query,
            start,
            last_end: start,
            spans: Vec::with_capacity(12),
            similarity: f32::NAN,
            prefill_us: 0,
            decode_us: 0,
            prefill_tokens: 0,
            prefill_recomputed: 0,
            rounds: 0,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Trace id (0 for disabled builders). Surfaced on responses so clients
    /// can correlate a streamed reply with its server-side trace.
    pub fn id(&self) -> u64 {
        self.id
    }

    fn us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.start).as_micros() as u64
    }

    /// Record a span over an explicit interval.
    pub fn span_at(&mut self, stage: Stage, begin: Instant, end: Instant, value: f32) {
        if !self.enabled {
            return;
        }
        let start_us = self.us(begin);
        let end_us = self.us(end).max(start_us);
        self.spans.push(Span { stage, start_us, end_us, value });
        if end > self.last_end {
            self.last_end = end;
        }
    }

    /// Record a span from `begin` to now.
    pub fn span_from(&mut self, stage: Stage, begin: Instant) {
        self.span_at(stage, begin, Instant::now(), f32::NAN);
    }

    /// Record a span from `begin` to now carrying `value`.
    pub fn span_from_value(&mut self, stage: Stage, begin: Instant, value: f32) {
        self.span_at(stage, begin, Instant::now(), value);
    }

    /// Record a span covering the gap since the previous span's end (the
    /// trace start if none) — used for wait stages measured by exclusion.
    pub fn span_since_last(&mut self, stage: Stage) {
        if !self.enabled {
            return;
        }
        let begin = self.last_end;
        self.span_at(stage, begin, Instant::now(), f32::NAN);
    }

    /// Record one fairness-round turn (child of `Decode`). Rounds past
    /// [`MAX_ROUND_SPANS`] are counted but not materialized.
    pub fn decode_round(&mut self, begin: Instant, occupancy: f32) {
        if !self.enabled {
            return;
        }
        self.rounds += 1;
        if self.rounds as usize <= MAX_ROUND_SPANS {
            self.span_at(Stage::DecodeRound, begin, Instant::now(), occupancy);
        }
    }

    /// Similarity score of the routing decision (also on the route span).
    pub fn set_similarity(&mut self, s: f32) {
        if self.enabled {
            self.similarity = s;
        }
    }

    /// Generator-reported prefill/decode compute micros (IC-Cache-style
    /// split; the wall-clock spans include interleaving on top).
    pub fn set_compute(&mut self, prefill_us: u128, decode_us: u128) {
        if self.enabled {
            self.prefill_us = prefill_us as u64;
            self.decode_us = decode_us as u64;
        }
    }

    /// Prompt token accounting for the prefill: `total` prompt tokens, of
    /// which `recomputed` actually ran through the model (the rest were
    /// restored from the KV prefix cache).
    pub fn set_prefill_tokens(&mut self, total: usize, recomputed: usize) {
        if self.enabled {
            self.prefill_tokens = total as u32;
            self.prefill_recomputed = recomputed as u32;
        }
    }

    /// Set the payload of the most recent `stage` span — for values only
    /// known after the interval was recorded (the prefill span is stamped
    /// at session start; its recomputed-token count arrives with the
    /// finished response).
    pub fn set_span_value(&mut self, stage: Stage, value: f32) {
        if let Some(s) = self.spans.iter_mut().rev().find(|s| s.stage == stage) {
            s.value = value;
        }
    }

    /// Record time-to-first-token: the wall offset of the first streamed
    /// delta, exactly once per trace (later calls are no-ops). The span
    /// covers `[0, ttft]` so the histogram row aggregates TTFT per pathway.
    /// Deliberately does NOT advance `last_end`: the Reply span is measured
    /// by exclusion and must not shrink because a delta streamed early.
    pub fn first_token(&mut self) {
        if !self.enabled || self.spans.iter().any(|s| s.stage == Stage::FirstToken) {
            return;
        }
        let end_us = self.us(Instant::now());
        self.spans.push(Span {
            stage: Stage::FirstToken,
            start_us: 0,
            end_us,
            value: end_us as f32,
        });
    }
}

/// A completed, immutable trace.
#[derive(Clone, Debug)]
pub struct FinishedTrace {
    pub id: u64,
    pub tag: TraceTag,
    pub query: String,
    /// Route similarity; NaN when no candidate was scored (cold miss).
    pub similarity: f32,
    /// Router threshold at completion time (for score-vs-threshold reads).
    pub threshold: f32,
    pub total_us: u64,
    /// Fairness rounds the decode took (0 on non-generating pathways).
    pub decode_rounds: u32,
    pub gen_prefill_us: u64,
    pub gen_decode_us: u64,
    /// Prompt tokens of the generation (0 on non-generating pathways).
    pub prefill_tokens: u32,
    /// Prompt tokens recomputed; `< prefill_tokens` when the KV prefix
    /// cache restored the difference.
    pub prefill_recomputed: u32,
    /// Spans sorted by (start, depth): parents precede their children.
    pub spans: Vec<Span>,
}

impl FinishedTrace {
    pub fn span(&self, stage: Stage) -> Option<&Span> {
        self.spans.iter().find(|s| s.stage == stage)
    }

    pub fn to_json(&self) -> Json {
        let spans = self
            .spans
            .iter()
            .map(|s| {
                let mut kv = vec![
                    ("stage", Json::s(s.stage.name())),
                    ("start_us", Json::num(s.start_us as f64)),
                    ("end_us", Json::num(s.end_us as f64)),
                ];
                if s.value.is_finite() {
                    kv.push(("value", Json::num(s.value as f64)));
                }
                Json::obj_from(kv)
            })
            .collect();
        Json::obj_from(vec![
            ("id", Json::num(self.id as f64)),
            ("pathway", Json::s(self.tag.name())),
            ("query", Json::s(self.query.clone())),
            (
                "similarity",
                if self.similarity.is_finite() {
                    Json::num(self.similarity as f64)
                } else {
                    Json::Null
                },
            ),
            ("threshold", Json::num(self.threshold as f64)),
            ("total_us", Json::num(self.total_us as f64)),
            ("decode_rounds", Json::num(self.decode_rounds as f64)),
            ("gen_prefill_us", Json::num(self.gen_prefill_us as f64)),
            ("gen_decode_us", Json::num(self.gen_decode_us as f64)),
            ("prefill_tokens", Json::num(self.prefill_tokens as f64)),
            (
                "prefill_recomputed",
                Json::num(self.prefill_recomputed as f64),
            ),
            ("spans", Json::Arr(spans)),
        ])
    }
}

/// Per-stage × per-pathway latency quantiles from the hub's histograms.
#[derive(Clone, Debug)]
pub struct StageSummary {
    pub stage: &'static str,
    pub pathway: &'static str,
    pub n: u64,
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
}

/// Snapshot returned by the `trace` server verb.
#[derive(Clone, Debug, Default)]
pub struct TraceReport {
    /// Most recent first.
    pub traces: Vec<FinishedTrace>,
    pub slow: Vec<FinishedTrace>,
    pub finished: u64,
    pub dropped: u64,
}

/// Owner of completed traces: ring buffer + slow list + histograms + export.
pub struct TraceHub {
    cfg: TraceConfig,
    next_id: u64,
    finished: u64,
    ring: VecDeque<FinishedTrace>,
    slow: VecDeque<FinishedTrace>,
    /// `(Stage::ALL.len() + 1) × TraceTag::ALL.len()` histograms; the extra
    /// row holds per-pathway request totals. DecodeRound spans are not
    /// aggregated (they would swamp the decode row).
    hist: Vec<LogHistogram>,
    export: Option<BufWriter<std::fs::File>>,
}

const TOTAL_ROW: usize = Stage::ALL.len();

impl TraceHub {
    pub fn new(cfg: TraceConfig) -> TraceHub {
        let export = if cfg.enabled && !cfg.export_dir.is_empty() {
            match Self::open_export(&cfg.export_dir) {
                Ok(w) => Some(w),
                Err(e) => {
                    eprintln!("[trace] JSONL export disabled: {e:#}");
                    None
                }
            }
        } else {
            None
        };
        TraceHub {
            cfg,
            next_id: 0,
            finished: 0,
            ring: VecDeque::new(),
            slow: VecDeque::new(),
            hist: vec![LogHistogram::new(); (TOTAL_ROW + 1) * TraceTag::ALL.len()],
            export,
        }
    }

    fn open_export(dir: &str) -> anyhow::Result<BufWriter<std::fs::File>> {
        std::fs::create_dir_all(dir)?;
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(Path::new(dir).join("traces.jsonl"))?;
        Ok(BufWriter::new(file))
    }

    fn slot(row: usize, tag: TraceTag) -> usize {
        row * TraceTag::ALL.len() + tag.index()
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Start a trace for a request. No-op (disabled builder) if tracing is
    /// off. `start` should be the request's enqueue instant so span offsets
    /// line up with `total_micros`.
    pub fn begin(&mut self, query: &str, start: Instant) -> TraceBuilder {
        if !self.cfg.enabled {
            return TraceBuilder::disabled();
        }
        self.next_id += 1;
        TraceBuilder::live(self.next_id, query.chars().take(QUERY_CAP).collect(), start)
    }

    /// Seal a builder into the ring/slow list/histograms. Takes the builder
    /// by `&mut` and leaves a disabled one behind, so callers can finish
    /// mid-method without fighting the borrow checker.
    pub fn finish(
        &mut self,
        trace: &mut TraceBuilder,
        tag: TraceTag,
        total_us: u64,
        threshold: f32,
    ) {
        let tb = std::mem::take(trace);
        if !tb.enabled {
            return;
        }
        let mut spans = tb.spans;
        spans.sort_by_key(|s| (s.start_us, s.stage.depth()));
        for s in &spans {
            if s.stage != Stage::DecodeRound {
                self.hist[Self::slot(s.stage.index(), tag)].record((s.end_us - s.start_us) as f64);
            }
        }
        self.hist[Self::slot(TOTAL_ROW, tag)].record(total_us as f64);
        let ft = FinishedTrace {
            id: tb.id,
            tag,
            query: tb.query,
            similarity: tb.similarity,
            threshold,
            total_us,
            decode_rounds: tb.rounds,
            gen_prefill_us: tb.prefill_us,
            gen_decode_us: tb.decode_us,
            prefill_tokens: tb.prefill_tokens,
            prefill_recomputed: tb.prefill_recomputed,
            spans,
        };
        if let Some(w) = &mut self.export {
            let mut line = ft.to_json().to_string();
            line.push('\n');
            if w.write_all(line.as_bytes()).and_then(|()| w.flush()).is_err() {
                eprintln!("[trace] JSONL export write failed; disabling export");
                self.export = None;
            }
        }
        if self.cfg.slow_threshold_ms > 0.0
            && total_us as f64 >= self.cfg.slow_threshold_ms * 1_000.0
        {
            if self.slow.len() == SLOW_CAP {
                self.slow.pop_front();
            }
            self.slow.push_back(ft.clone());
        }
        if self.ring.len() >= self.cfg.ring_capacity.max(1) {
            self.ring.pop_front();
        }
        self.ring.push_back(ft);
        self.finished += 1;
    }

    pub fn finished(&self) -> u64 {
        self.finished
    }

    /// Traces evicted from the ring (still counted in histograms).
    pub fn dropped(&self) -> u64 {
        self.finished - self.ring.len() as u64
    }

    /// Last `n` completed traces, most recent first.
    pub fn recent(&self, n: usize) -> Vec<FinishedTrace> {
        self.ring.iter().rev().take(n).cloned().collect()
    }

    /// Slow-request list, most recent first.
    pub fn slow(&self) -> Vec<FinishedTrace> {
        self.slow.iter().rev().cloned().collect()
    }

    pub fn report(&self, n: usize) -> TraceReport {
        TraceReport {
            traces: self.recent(n),
            slow: self.slow(),
            finished: self.finished,
            dropped: self.dropped(),
        }
    }

    /// Requests finished per pathway (from the total-row histograms).
    pub fn pathway_counts(&self) -> Vec<(&'static str, u64)> {
        TraceTag::ALL
            .iter()
            .map(|&t| (t.name(), self.hist[Self::slot(TOTAL_ROW, t)].count()))
            .collect()
    }

    /// Non-empty per-stage × per-pathway quantile rows ("total" row last).
    pub fn stage_summaries(&self) -> Vec<StageSummary> {
        let names = Stage::ALL.iter().map(|s| s.name()).chain(std::iter::once("total"));
        let mut out = Vec::new();
        for (row, stage) in names.enumerate() {
            for &tag in &TraceTag::ALL {
                let h = &self.hist[Self::slot(row, tag)];
                if h.count() == 0 {
                    continue;
                }
                out.push(StageSummary {
                    stage,
                    pathway: tag.name(),
                    n: h.count(),
                    p50_us: h.quantile(0.50),
                    p90_us: h.quantile(0.90),
                    p99_us: h.quantile(0.99),
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn hub(ring: usize) -> TraceHub {
        TraceHub::new(TraceConfig {
            enabled: true,
            ring_capacity: ring,
            slow_threshold_ms: 0.5,
            export_dir: String::new(),
        })
    }

    fn finish_one(h: &mut TraceHub, tag: TraceTag, total_us: u64) {
        let t0 = Instant::now();
        let mut tb = h.begin("q", t0);
        tb.span_at(Stage::Search, t0, t0 + Duration::from_micros(5), f32::NAN);
        tb.span_at(
            Stage::Route,
            t0 + Duration::from_micros(5),
            t0 + Duration::from_micros(6),
            0.9,
        );
        h.finish(&mut tb, tag, total_us, 0.7);
    }

    #[test]
    fn disabled_builder_records_nothing() {
        let mut tb = TraceBuilder::disabled();
        tb.span_from(Stage::Embed, Instant::now());
        tb.decode_round(Instant::now(), 3.0);
        tb.set_similarity(0.5);
        assert!(tb.spans.is_empty());
        assert_eq!(tb.rounds, 0);
        assert!(tb.similarity.is_nan());
    }

    #[test]
    fn disabled_hub_yields_disabled_builders() {
        let mut h = TraceHub::new(TraceConfig { enabled: false, ..TraceConfig::default() });
        let mut tb = h.begin("q", Instant::now());
        assert!(!tb.is_enabled());
        h.finish(&mut tb, TraceTag::Miss, 100, 0.7);
        assert_eq!(h.finished(), 0);
        assert!(h.stage_summaries().is_empty());
    }

    #[test]
    fn spans_are_ordered_and_bounded() {
        let t0 = Instant::now();
        let mut h = hub(8);
        let mut tb = h.begin("hello world", t0);
        let t1 = t0 + Duration::from_micros(10);
        let t2 = t0 + Duration::from_micros(30);
        tb.span_at(Stage::Embed, t0, t1, f32::NAN);
        tb.span_at(Stage::Search, t1, t2, f32::NAN);
        // out-of-order recording still sorts by start
        tb.span_at(Stage::Ingest, t0, t0, f32::NAN);
        h.finish(&mut tb, TraceTag::Miss, 50, 0.7);
        let ft = &h.recent(1)[0];
        for w in ft.spans.windows(2) {
            assert!(w[0].start_us <= w[1].start_us, "spans sorted by start");
        }
        for s in &ft.spans {
            assert!(s.end_us >= s.start_us);
            assert!(s.end_us <= ft.total_us);
        }
        let mut depth1 = 0u64;
        for s in ft.spans.iter().filter(|s| s.stage.depth() == 1) {
            depth1 += s.end_us - s.start_us;
        }
        assert!(depth1 <= ft.total_us, "stage sum {depth1} > total {}", ft.total_us);
    }

    #[test]
    fn round_spans_cap_but_count() {
        let mut h = hub(8);
        let mut tb = h.begin("q", Instant::now());
        let d0 = Instant::now();
        for _ in 0..(MAX_ROUND_SPANS + 10) {
            tb.decode_round(Instant::now(), 2.0);
        }
        tb.span_at(Stage::Decode, d0, Instant::now(), f32::NAN);
        h.finish(&mut tb, TraceTag::Miss, 1_000, 0.7);
        let ft = &h.recent(1)[0];
        assert_eq!(ft.decode_rounds as usize, MAX_ROUND_SPANS + 10);
        let rounds = ft.spans.iter().filter(|s| s.stage == Stage::DecodeRound).count();
        assert_eq!(rounds, MAX_ROUND_SPANS);
        // children nest inside the decode parent
        let d = ft.span(Stage::Decode).unwrap();
        for s in ft.spans.iter().filter(|s| s.stage == Stage::DecodeRound) {
            assert!(s.start_us >= d.start_us && s.end_us <= d.end_us);
        }
    }

    #[test]
    fn ring_evicts_slow_retains() {
        let mut h = hub(4);
        for i in 0..10 {
            // 600us total >= 0.5ms slow threshold for even ids
            let total = if i % 2 == 0 { 600 } else { 100 };
            finish_one(&mut h, TraceTag::TweakHit, total);
        }
        assert_eq!(h.finished(), 10);
        assert_eq!(h.recent(100).len(), 4);
        assert_eq!(h.dropped(), 6);
        let slow = h.slow();
        assert_eq!(slow.len(), 5);
        assert!(slow.iter().all(|t| t.total_us >= 500));
        // most recent first
        assert!(h.recent(100)[0].id > h.recent(100)[3].id);
    }

    #[test]
    fn histograms_aggregate_per_pathway() {
        let mut h = hub(8);
        finish_one(&mut h, TraceTag::TweakHit, 100);
        finish_one(&mut h, TraceTag::Miss, 200);
        finish_one(&mut h, TraceTag::Miss, 300);
        let counts = h.pathway_counts();
        let get = |name: &str| counts.iter().find(|(n, _)| *n == name).unwrap().1;
        assert_eq!(get("tweak_hit"), 1);
        assert_eq!(get("miss"), 2);
        assert_eq!(get("exact_hit"), 0);
        let rows = h.stage_summaries();
        assert!(rows.iter().any(|r| r.stage == "search" && r.pathway == "miss" && r.n == 2));
        assert!(rows.iter().any(|r| r.stage == "total" && r.pathway == "miss" && r.n == 2));
        assert!(!rows.iter().any(|r| r.pathway == "exact_hit"));
    }

    #[test]
    fn first_token_records_once_and_aggregates() {
        let mut h = hub(8);
        let t0 = Instant::now();
        let mut tb = h.begin("q", t0);
        tb.span_at(Stage::Search, t0, t0 + Duration::from_micros(5), f32::NAN);
        tb.first_token();
        tb.first_token(); // only the FIRST delta defines TTFT
        tb.span_since_last(Stage::Reply);
        h.finish(&mut tb, TraceTag::TweakHit, 1_000, 0.7);
        let ft = &h.recent(1)[0];
        let spans: Vec<_> = ft.spans.iter().filter(|s| s.stage == Stage::FirstToken).collect();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].start_us, 0);
        assert!((spans[0].value - spans[0].end_us as f32).abs() < 1.0);
        // Reply is measured by exclusion from the last depth-1 span end;
        // the depth-2 TTFT overlay must not have shrunk it below the gap
        // after Search.
        let reply = ft.span(Stage::Reply).unwrap();
        assert_eq!(reply.start_us, 5, "reply must start at the Search span end");
        let rows = h.stage_summaries();
        assert!(rows
            .iter()
            .any(|r| r.stage == "first_token" && r.pathway == "tweak_hit" && r.n == 1));
    }

    #[test]
    fn json_shape_and_nan_similarity() {
        let mut h = hub(8);
        let t0 = Instant::now();
        let mut tb = h.begin("q", t0);
        tb.span_at(Stage::Search, t0, t0 + Duration::from_micros(5), f32::NAN);
        h.finish(&mut tb, TraceTag::Miss, 42, 0.7);
        let j = h.recent(1)[0].to_json();
        let text = j.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("pathway").unwrap().str().unwrap(), "miss");
        assert!(parsed.opt("similarity").is_none(), "NaN similarity must serialize as null");
        let spans = parsed.get("spans").unwrap().arr().unwrap();
        assert_eq!(spans[0].get("stage").unwrap().str().unwrap(), "search");
        assert!(spans[0].opt("value").is_none());
    }

    #[test]
    fn jsonl_export_writes_parseable_lines() {
        let dir = std::env::temp_dir().join(format!("tweakllm_trace_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let mut h = TraceHub::new(TraceConfig {
                enabled: true,
                ring_capacity: 8,
                slow_threshold_ms: 0.0,
                export_dir: dir.to_string_lossy().into_owned(),
            });
            finish_one(&mut h, TraceTag::ExactHit, 10);
            finish_one(&mut h, TraceTag::Miss, 20);
        }
        let text = std::fs::read_to_string(dir.join("traces.jsonl")).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert!(j.get("total_us").unwrap().f64().unwrap() > 0.0);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
