//! LLM facades: the Big and Small models behind a common interface, plus
//! the tweak-prompt template (paper Appendix A).
//!
//! Two call shapes per model:
//! * the **blocking** API (`respond`/`tweak`) drives a generation to
//!   completion in place;
//! * the **session** API (`begin_respond`/`begin_tweak`) returns a live
//!   [`LlmSession`] whose `advance()` performs one unit of decode work, so
//!   the coordinator's scheduler can interleave many generations (Big-LLM
//!   misses next to Small-LLM tweaks) on the engine thread.
//!
//! The blocking API is implemented *on top of* the session API, so a
//! request costs exactly the same work — and, for the substrate models,
//! consumes exactly the same RNG stream — whichever shape serves it.

use anyhow::Result;

use crate::cost::TokenUsage;
use crate::runtime::{GenSession, Generator, Runtime, SamplingParams};
use crate::util::rng::hash_bytes;
use crate::util::Rng;

pub mod prompts;

pub use prompts::TweakPrompt;

/// A model that turns a prompt into a response (the compiled substrate
/// decoders at runtime; the quality-model mocks in eval/tests).
///
/// NB: deliberately NOT `Send` — the substrate implementation wraps PJRT
/// handles (`Rc` internally). The engine thread constructs and owns it.
pub trait LanguageModel {
    fn name(&self) -> &str;

    /// Respond to a raw user query.
    fn respond(&mut self, query: &str) -> Result<LlmResponse>;

    /// Tweak a cached response for a new query (Appendix A pathway).
    fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse>;

    /// Begin a resumable generation for a raw query. The default wraps the
    /// blocking call (the whole generation happens at `begin` time), which
    /// preserves semantics for implementations that cannot pause; models
    /// that can decode step-wise override this to return a live session.
    fn begin_respond(&mut self, query: &str) -> Result<Box<dyn LlmSession>> {
        Ok(Box::new(EagerSession(self.respond(query)?)))
    }

    /// Begin a resumable tweak generation; see [`Self::begin_respond`].
    fn begin_tweak(&mut self, prompt: &TweakPrompt) -> Result<Box<dyn LlmSession>> {
        Ok(Box::new(EagerSession(self.tweak(prompt)?)))
    }
}

/// A live generation owned by the caller (the decode scheduler): each
/// `advance()` performs one unit of decode work. Sessions are independent —
/// they own their RNG, sampling scratch, and decode state — so any number
/// can be interleaved without changing any of their token streams.
pub trait LlmSession {
    /// One unit of work; `true` while more remains.
    fn advance(&mut self) -> Result<bool>;

    fn is_done(&self) -> bool;

    /// Consume the session into the finished response.
    fn finish(self: Box<Self>) -> Result<LlmResponse>;
}

/// Fallback session for models without step-wise decode: the response was
/// fully computed at `begin` time.
pub struct EagerSession(pub LlmResponse);

impl LlmSession for EagerSession {
    fn advance(&mut self) -> Result<bool> {
        Ok(false)
    }

    fn is_done(&self) -> bool {
        true
    }

    fn finish(self: Box<Self>) -> Result<LlmResponse> {
        Ok(self.0)
    }
}

#[derive(Clone, Debug)]
pub struct LlmResponse {
    pub text: String,
    pub usage: TokenUsage,
    pub prefill_micros: u128,
    pub decode_micros: u128,
}

/// Compiled-artifact-backed model.
pub struct SubstrateLlm {
    gen: Generator,
    params: SamplingParams,
    /// Master seed: every request derives an independent RNG substream from
    /// (seed, model, prompt), so a generation's token stream depends only on
    /// its own request — never on how many generations ran before it or how
    /// they were interleaved. This is what makes scheduler-interleaved
    /// decoding bit-identical to sequential serving.
    seed: u64,
}

impl SubstrateLlm {
    pub fn new(rt: &Runtime, model: &str, params: SamplingParams, seed: u64) -> Result<Self> {
        Self::new_with(rt, model, params, seed, true)
    }

    /// `device_resident = false` pins the literal KV transport
    /// (`[runtime] device_resident` in the config); `true` uses the
    /// device-resident decode path when its artifacts are compiled.
    pub fn new_with(
        rt: &Runtime,
        model: &str,
        params: SamplingParams,
        seed: u64,
        device_resident: bool,
    ) -> Result<Self> {
        Ok(SubstrateLlm {
            gen: Generator::with_mode(rt, model, device_resident)?,
            params,
            seed,
        })
    }

    /// Per-request RNG substream; a pure function of (seed, model, prompt).
    fn session_rng(&self, segments: &[&str]) -> Rng {
        let mut bytes = Vec::new();
        for seg in segments {
            bytes.extend_from_slice(seg.as_bytes());
            bytes.push(0x1f); // unit separator: ["ab","c"] != ["a","bc"]
        }
        let tag = format!("llm/{}/{:016x}", self.gen.model_name, hash_bytes(&bytes));
        Rng::substream(self.seed, &tag)
    }

    fn begin(&mut self, segments: &[&str]) -> Result<Box<dyn LlmSession>> {
        let rng = self.session_rng(segments);
        let session = self.gen.begin_session(segments, &self.params, rng)?;
        Ok(Box::new(SubstrateSession { session }))
    }

    fn run(&mut self, segments: &[&str]) -> Result<LlmResponse> {
        let mut session = self.begin(segments)?;
        while session.advance()? {}
        session.finish()
    }
}

/// Substrate decode session: a [`GenSession`] rendered to an [`LlmResponse`]
/// at completion.
struct SubstrateSession {
    session: GenSession,
}

impl LlmSession for SubstrateSession {
    fn advance(&mut self) -> Result<bool> {
        self.session.advance()
    }

    fn is_done(&self) -> bool {
        self.session.is_done()
    }

    fn finish(self: Box<Self>) -> Result<LlmResponse> {
        let g = self.session.finish();
        Ok(LlmResponse {
            text: g.text,
            usage: TokenUsage {
                input_tokens: g.stats.prompt_tokens,
                output_tokens: g.stats.generated_tokens,
            },
            prefill_micros: g.stats.prefill_micros,
            decode_micros: g.stats.decode_micros,
        })
    }
}

impl LanguageModel for SubstrateLlm {
    fn name(&self) -> &str {
        &self.gen.model_name
    }

    fn respond(&mut self, query: &str) -> Result<LlmResponse> {
        self.run(&[query])
    }

    fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse> {
        let segs = prompt.segments();
        self.run(&segs.iter().map(|s| s.as_str()).collect::<Vec<_>>())
    }

    fn begin_respond(&mut self, query: &str) -> Result<Box<dyn LlmSession>> {
        self.begin(&[query])
    }

    fn begin_tweak(&mut self, prompt: &TweakPrompt) -> Result<Box<dyn LlmSession>> {
        let segs = prompt.segments();
        self.begin(&segs.iter().map(|s| s.as_str()).collect::<Vec<_>>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tweak_prompt_orders_new_query_first() {
        let p = TweakPrompt {
            new_query: "why is rust fast?".into(),
            cached_query: "why is rust safe?".into(),
            cached_response: "because borrow checker".into(),
        };
        let segs = p.segments();
        assert_eq!(segs[0], "why is rust fast?");
        assert_eq!(segs.len(), 3);
    }

    #[test]
    fn eager_session_yields_response() {
        let resp = LlmResponse {
            text: "canned".into(),
            usage: TokenUsage::default(),
            prefill_micros: 1,
            decode_micros: 2,
        };
        let mut s: Box<dyn LlmSession> = Box::new(EagerSession(resp));
        assert!(s.is_done());
        assert!(!s.advance().unwrap());
        assert_eq!(s.finish().unwrap().text, "canned");
    }

    #[test]
    fn default_begin_wraps_blocking_call() {
        // A session-unaware model still works through the session API.
        struct Plain;
        impl LanguageModel for Plain {
            fn name(&self) -> &str {
                "plain"
            }
            fn respond(&mut self, query: &str) -> Result<LlmResponse> {
                Ok(LlmResponse {
                    text: format!("re: {query}"),
                    usage: TokenUsage::default(),
                    prefill_micros: 0,
                    decode_micros: 0,
                })
            }
            fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse> {
                self.respond(&prompt.new_query)
            }
        }
        let mut m = Plain;
        let mut s = m.begin_respond("hello").unwrap();
        while s.advance().unwrap() {}
        assert_eq!(s.finish().unwrap().text, "re: hello");
    }
}
