//! LLM facades: the Big and Small models behind a common interface, plus
//! the tweak-prompt template (paper Appendix A).

use anyhow::Result;

use crate::cost::TokenUsage;
use crate::runtime::{Generation, Generator, Runtime, SamplingParams};
use crate::util::Rng;

pub mod prompts;

pub use prompts::TweakPrompt;

/// A model that turns a prompt into a response (the compiled substrate
/// decoders at runtime; the quality-model mocks in eval/tests).
///
/// NB: deliberately NOT `Send` — the substrate implementation wraps PJRT
/// handles (`Rc` internally). The engine thread constructs and owns it.
pub trait LanguageModel {
    fn name(&self) -> &str;

    /// Respond to a raw user query.
    fn respond(&mut self, query: &str) -> Result<LlmResponse>;

    /// Tweak a cached response for a new query (Appendix A pathway).
    fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse>;
}

#[derive(Clone, Debug)]
pub struct LlmResponse {
    pub text: String,
    pub usage: TokenUsage,
    pub prefill_micros: u128,
    pub decode_micros: u128,
}

/// Compiled-artifact-backed model.
pub struct SubstrateLlm {
    gen: Generator,
    params: SamplingParams,
    rng: Rng,
}

impl SubstrateLlm {
    pub fn new(rt: &Runtime, model: &str, params: SamplingParams, seed: u64) -> Result<Self> {
        Self::new_with(rt, model, params, seed, true)
    }

    /// `device_resident = false` pins the literal KV transport
    /// (`[runtime] device_resident` in the config); `true` uses the
    /// device-resident decode path when its artifacts are compiled.
    pub fn new_with(
        rt: &Runtime,
        model: &str,
        params: SamplingParams,
        seed: u64,
        device_resident: bool,
    ) -> Result<Self> {
        Ok(SubstrateLlm {
            gen: Generator::with_mode(rt, model, device_resident)?,
            params,
            rng: Rng::substream(seed, &format!("llm/{model}")),
        })
    }

    fn run(&mut self, segments: &[&str]) -> Result<LlmResponse> {
        let g: Generation = self.gen.generate(segments, &self.params, &mut self.rng)?;
        Ok(LlmResponse {
            text: g.text,
            usage: TokenUsage {
                input_tokens: g.stats.prompt_tokens,
                output_tokens: g.stats.generated_tokens,
            },
            prefill_micros: g.stats.prefill_micros,
            decode_micros: g.stats.decode_micros,
        })
    }
}

impl LanguageModel for SubstrateLlm {
    fn name(&self) -> &str {
        &self.gen.model_name
    }

    fn respond(&mut self, query: &str) -> Result<LlmResponse> {
        self.run(&[query])
    }

    fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse> {
        let segs = prompt.segments();
        self.run(&segs.iter().map(|s| s.as_str()).collect::<Vec<_>>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tweak_prompt_orders_new_query_first() {
        let p = TweakPrompt {
            new_query: "why is rust fast?".into(),
            cached_query: "why is rust safe?".into(),
            cached_response: "because borrow checker".into(),
        };
        let segs = p.segments();
        assert_eq!(segs[0], "why is rust fast?");
        assert_eq!(segs.len(), 3);
    }
}
