//! LLM facades: the Big and Small models behind a common interface, plus
//! the tweak-prompt template (paper Appendix A).
//!
//! Two call shapes per model:
//! * the **blocking** API (`respond`/`tweak`) drives a generation to
//!   completion in place;
//! * the **session** API (`begin_respond`/`begin_tweak`) returns a live
//!   [`LlmSession`] whose `advance()` performs one unit of decode work, so
//!   the coordinator's scheduler can interleave many generations (Big-LLM
//!   misses next to Small-LLM tweaks) on the engine thread.
//!
//! The blocking API is implemented *on top of* the session API, so a
//! request costs exactly the same work — and, for the substrate models,
//! consumes exactly the same RNG stream — whichever shape serves it.
//!
//! With slot-batched decode artifacts compiled (`decode_batch > 0`),
//! sessions of one model *advance collectively*: they claim slots in a
//! shared [`SubstrateBatch`] pool, and one masked device dispatch per
//! fairness round moves every live slot one token — the scheduler's
//! round-robin costs O(1) dispatches per round instead of O(S). Overflow
//! sessions (pool full) fall back to the per-session backend with span
//! fusion disabled, so a response never depends on which path served it.

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::cost::TokenUsage;
use crate::runtime::{
    GenSession, Generator, PrefixCache, PrefixCacheStats, Runtime, SamplingParams, SubstrateBatch,
};
use crate::util::rng::hash_bytes;
use crate::util::Rng;

pub mod prompts;

pub use prompts::TweakPrompt;

/// A model that turns a prompt into a response (the compiled substrate
/// decoders at runtime; the quality-model mocks in eval/tests).
///
/// NB: deliberately NOT `Send` — the substrate implementation wraps PJRT
/// handles (`Rc` internally). The engine thread constructs and owns it.
pub trait LanguageModel {
    fn name(&self) -> &str;

    /// Respond to a raw user query.
    fn respond(&mut self, query: &str) -> Result<LlmResponse>;

    /// Tweak a cached response for a new query (Appendix A pathway).
    fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse>;

    /// Begin a resumable generation for a raw query. The default wraps the
    /// blocking call (the whole generation happens at `begin` time), which
    /// preserves semantics for implementations that cannot pause; models
    /// that can decode step-wise override this to return a live session.
    fn begin_respond(&mut self, query: &str) -> Result<Box<dyn LlmSession>> {
        Ok(Box::new(EagerSession(self.respond(query)?)))
    }

    /// Begin a resumable tweak generation; see [`Self::begin_respond`].
    fn begin_tweak(&mut self, prompt: &TweakPrompt) -> Result<Box<dyn LlmSession>> {
        Ok(Box::new(EagerSession(self.tweak(prompt)?)))
    }

    /// Lifetime counters of this model's collective (slot-batched) decode
    /// pool; `None` for models without one. Feeds the engine's
    /// `batched_steps` / `mean_active_slots` observability.
    fn batch_stats(&self) -> Option<BatchDecodeStats> {
        None
    }

    /// Lifetime counters of this model's cross-request KV prefix cache;
    /// `None` when prefix reuse is disabled or unsupported.
    fn prefix_stats(&self) -> Option<PrefixCacheStats> {
        None
    }
}

/// Occupancy counters of a slot-batched decode pool (per model, lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchDecodeStats {
    /// Batched decode dispatches issued (each advances every active slot).
    pub dispatches: u64,
    /// Sum of active slot counts over those dispatches;
    /// `active_slot_sum / dispatches` = mean batch occupancy.
    pub active_slot_sum: u64,
    /// Slot count of the pool.
    pub slots: usize,
}

impl BatchDecodeStats {
    /// Merge counters across models (big + small pools).
    pub fn merge(a: Option<BatchDecodeStats>, b: Option<BatchDecodeStats>) -> Option<Self> {
        match (a, b) {
            (None, x) | (x, None) => x,
            (Some(a), Some(b)) => Some(BatchDecodeStats {
                dispatches: a.dispatches + b.dispatches,
                active_slot_sum: a.active_slot_sum + b.active_slot_sum,
                slots: a.slots + b.slots,
            }),
        }
    }
}

/// A live generation owned by the caller (the decode scheduler): each
/// `advance()` performs one unit of decode work. Sessions are independent —
/// they own their RNG, sampling scratch, and decode state — so any number
/// can be interleaved without changing any of their token streams.
pub trait LlmSession {
    /// One unit of work; `true` while more remains.
    fn advance(&mut self) -> Result<bool>;

    fn is_done(&self) -> bool;

    /// Text decoded since the last call — always a prefix-continuation of
    /// the final response text, UTF-8-complete at every boundary. Sessions
    /// that cannot decode incrementally return an empty string; the reply
    /// path streams the remainder at completion, so concatenated deltas
    /// always equal the blocking text regardless.
    fn take_delta(&mut self) -> String {
        String::new()
    }

    /// Consume the session into the finished response.
    fn finish(self: Box<Self>) -> Result<LlmResponse>;
}

/// Fallback session for models without step-wise decode: the response was
/// fully computed at `begin` time.
pub struct EagerSession(pub LlmResponse);

impl LlmSession for EagerSession {
    fn advance(&mut self) -> Result<bool> {
        Ok(false)
    }

    fn is_done(&self) -> bool {
        true
    }

    fn finish(self: Box<Self>) -> Result<LlmResponse> {
        Ok(self.0)
    }
}

#[derive(Clone, Debug)]
pub struct LlmResponse {
    pub text: String,
    pub usage: TokenUsage,
    /// Prompt tokens restored from the KV prefix cache instead of
    /// recomputed (0 = cold prefill). `input_tokens - restored_tokens` is
    /// the prefill work actually performed for this response.
    pub restored_tokens: usize,
    pub prefill_micros: u128,
    pub decode_micros: u128,
}

/// Compiled-artifact-backed model.
pub struct SubstrateLlm {
    gen: Generator,
    params: SamplingParams,
    /// Master seed: every request derives an independent RNG substream from
    /// (seed, model, prompt), so a generation's token stream depends only on
    /// its own request — never on how many generations ran before it or how
    /// they were interleaved. This is what makes scheduler-interleaved
    /// decoding bit-identical to sequential serving.
    seed: u64,
    /// Slot-batched decode pool shared by this model's sessions (`None`:
    /// per-session dispatch — batched artifacts absent or `decode_batch`
    /// disabled). `Rc` because every live slot session holds the pool too;
    /// everything stays on the engine thread (the model is !Send anyway).
    batch: Option<Rc<RefCell<SubstrateBatch>>>,
    /// Span fusion permission for per-session backends. Pinned `false` in
    /// batched deployments: the batched path samples single-step, and span
    /// fusion consumes the RNG differently — a request's response must not
    /// depend on whether it decoded in a slot or in the overflow path.
    allow_span: bool,
    /// Cross-request KV prefix cache (`[runtime] prefix_cache_bytes`);
    /// `None` = cold prefill every session. One cache per model — packed
    /// states of different models have different widths and must never mix.
    prefix: Option<Rc<RefCell<PrefixCache>>>,
    /// Token ids of [`prompts::TWEAK_TEMPLATE`], memoized at construction:
    /// the static head of every tweak prompt is tokenized once per model,
    /// not once per request.
    tweak_head_ids: Vec<i32>,
}

impl SubstrateLlm {
    pub fn new(rt: &Runtime, model: &str, params: SamplingParams, seed: u64) -> Result<Self> {
        Self::new_with(rt, model, params, seed, true)
    }

    /// `device_resident = false` pins the literal KV transport
    /// (`[runtime] device_resident` in the config); `true` uses the
    /// device-resident decode path when its artifacts are compiled.
    pub fn new_with(
        rt: &Runtime,
        model: &str,
        params: SamplingParams,
        seed: u64,
        device_resident: bool,
    ) -> Result<Self> {
        let gen = Generator::with_mode(rt, model, device_resident)?;
        let tweak_head_ids = gen.tokenizer().encode(prompts::TWEAK_TEMPLATE);
        Ok(SubstrateLlm {
            gen,
            params,
            seed,
            batch: None,
            allow_span: true,
            prefix: None,
            tweak_head_ids,
        })
    }

    /// Enable cross-request KV prefix reuse under an LRU byte budget
    /// (`[runtime] prefix_cache_bytes`; 0 disables). Left off, with a
    /// notice, when the artifact set has no resume-capable prefill chunks —
    /// a cache no lookup can ever be served from would only burn memory on
    /// snapshots.
    pub fn with_prefix_cache(mut self, budget_bytes: usize) -> Self {
        if budget_bytes == 0 {
            return self;
        }
        if self.gen.resume_chunks().is_empty() {
            eprintln!(
                "[llm] {}: no resume-capable prefill artifacts \
                 (run `make artifacts`); prefix cache disabled",
                self.gen.model_name
            );
            return self;
        }
        self.prefix = Some(PrefixCache::shared(budget_bytes));
        self
    }

    /// Enable slot-batched decode with up to `max_slots` concurrent slots
    /// (`[scheduler] decode_batch`). Builds a pool from the largest compiled
    /// batch bucket that fits; falls back to per-session dispatch (with a
    /// notice) when the artifact set predates batched decode.
    pub fn with_decode_batch(self, max_slots: usize) -> Self {
        self.with_decode_batch_opts(max_slots, true)
    }

    /// [`Self::with_decode_batch`] with pool construction optionally
    /// suppressed (`build_pool = false`: the router's scheduler-off A/B
    /// configuration, where a pool would only ever hold one live slot
    /// while paying the full batch-width compute).
    ///
    /// Span fusion is pinned off whenever the artifact set CAN batch at
    /// this slot budget — pool built or not — because the batched sampling
    /// path is single-step and span fusion consumes the RNG differently: a
    /// response must not depend on slot placement or on the scheduler A/B.
    /// Artifact sets with no batch buckets keep span fusion (and today's
    /// outputs) untouched — outputs already track compiled capabilities.
    pub fn with_decode_batch_opts(mut self, max_slots: usize, build_pool: bool) -> Self {
        if max_slots == 0 {
            return self;
        }
        if !self.gen.batch_sizes().iter().any(|&b| b <= max_slots) {
            eprintln!(
                "[llm] {}: no batched decode artifacts ≤ {max_slots} slots \
                 (run `make artifacts`); keeping per-session dispatch + span fusion",
                self.gen.model_name
            );
            return self;
        }
        self.allow_span = false;
        if build_pool {
            let pool = self.gen.begin_batch(max_slots).expect("bucket fits");
            self.batch = Some(Rc::new(RefCell::new(pool)));
        }
        self
    }

    /// Whether the slot-batched decode pool is live.
    pub fn batched(&self) -> bool {
        self.batch.is_some()
    }

    /// Per-request RNG substream; a pure function of (seed, model, prompt).
    fn session_rng(&self, segments: &[&str]) -> Rng {
        let mut bytes = Vec::new();
        for seg in segments {
            bytes.extend_from_slice(seg.as_bytes());
            bytes.push(0x1f); // unit separator: ["ab","c"] != ["a","bc"]
        }
        let tag = format!("llm/{}/{:016x}", self.gen.model_name, hash_bytes(&bytes));
        Rng::substream(self.seed, &tag)
    }

    fn begin(&mut self, segments: &[&str]) -> Result<Box<dyn LlmSession>> {
        let rng = self.session_rng(segments);
        let (ids, len) = self
            .gen
            .tokenizer()
            .encode_prompt(segments, self.gen.max_prefill());
        self.begin_ids(ids, len, rng)
    }

    /// Begin a tweak session. Unlike `begin`, the prompt is encoded with
    /// suffix protection: the static template (memoized ids) + cached query
    /// + cached response form a bit-stable prefix truncated at a FIXED
    /// boundary, and the new query rides in the reserved tail — so every
    /// tweak against one cache entry shares a prefix the KV cache can serve.
    fn begin_tweak_session(&mut self, prompt: &TweakPrompt) -> Result<Box<dyn LlmSession>> {
        let segs = prompt.segments();
        let seg_refs: Vec<&str> = segs.iter().map(|s| s.as_str()).collect();
        let rng = self.session_rng(&seg_refs);
        let (ids, len) = self.gen.tokenizer().encode_prompt_suffixed(
            &self.tweak_head_ids,
            &[&prompt.cached_query, &prompt.cached_response],
            &prompt.new_query,
            self.gen.max_prefill(),
            prompts::TWEAK_SUFFIX_RESERVE,
        );
        self.begin_ids(ids, len, rng)
    }

    /// Start a session from already-encoded prompt ids: a slot of the
    /// batched pool when one is free, the per-session overflow backend
    /// otherwise. Both paths probe the prefix cache, so a request's
    /// restored-token count doesn't depend on slot placement.
    fn begin_ids(&mut self, ids: Vec<i32>, len: usize, rng: Rng) -> Result<Box<dyn LlmSession>> {
        if len == 0 {
            bail!("empty prompt");
        }
        if let Some(pool) = &self.batch {
            if pool.borrow().free_slots() > 0 {
                let slot = pool
                    .borrow_mut()
                    .admit_prefixed(&ids, len, self.params, rng, self.prefix.as_ref())?
                    .expect("a free slot was just observed");
                return Ok(Box::new(BatchedLlmSession {
                    pool: Rc::clone(pool),
                    slot: Some(slot),
                    tokenizer: self.gen.tokenizer().clone(),
                    decoder: self.gen.tokenizer().stream_decoder(),
                    consumed: 0,
                }));
            }
            // Every slot occupied: overflow onto a per-session backend
            // (single-step, same sampling path as the pool).
        }
        let session = self.gen.begin_session_ids(
            &ids,
            len,
            &self.params,
            rng,
            self.gen.resident_available(),
            self.allow_span,
            self.prefix.as_ref(),
        )?;
        Ok(Box::new(SubstrateSession {
            session,
            decoder: self.gen.tokenizer().stream_decoder(),
            consumed: 0,
        }))
    }

    fn run(&mut self, segments: &[&str]) -> Result<LlmResponse> {
        let mut session = self.begin(segments)?;
        while session.advance()? {}
        session.finish()
    }
}

/// A slot of the model's shared [`SubstrateBatch`] pool, behind the same
/// per-session `advance()` protocol the scheduler already drives: the first
/// session of a fairness round to advance triggers ONE masked batch
/// dispatch for every live slot; its peers' `advance` calls consume the
/// round credit for free. Dropping an unfinished session frees its slot.
struct BatchedLlmSession {
    pool: Rc<RefCell<SubstrateBatch>>,
    /// `None` once finished (so Drop doesn't free a re-admitted slot).
    slot: Option<usize>,
    tokenizer: crate::tokenizer::Tokenizer,
    /// Incremental view of the slot's token stream for `take_delta`.
    decoder: crate::tokenizer::StreamDecoder,
    consumed: usize,
}

impl LlmSession for BatchedLlmSession {
    fn advance(&mut self) -> Result<bool> {
        let slot = self.slot.expect("advance after finish");
        self.pool.borrow_mut().advance(slot)
    }

    fn is_done(&self) -> bool {
        match self.slot {
            Some(slot) => self.pool.borrow().is_done(slot),
            None => true,
        }
    }

    fn take_delta(&mut self) -> String {
        let Some(slot) = self.slot else {
            return String::new();
        };
        let pool = self.pool.borrow();
        let toks = pool.tokens(slot);
        if self.consumed >= toks.len() {
            return String::new();
        }
        let delta = self.decoder.push_ids(&toks[self.consumed..]);
        self.consumed = toks.len();
        delta
    }

    fn finish(mut self: Box<Self>) -> Result<LlmResponse> {
        let slot = self.slot.take().expect("finish twice");
        let (token_ids, stats) = self.pool.borrow_mut().finish(slot)?;
        Ok(LlmResponse {
            text: self.tokenizer.decode(&token_ids),
            usage: TokenUsage {
                input_tokens: stats.prompt_tokens,
                output_tokens: stats.generated_tokens,
            },
            restored_tokens: stats.restored_tokens,
            prefill_micros: stats.prefill_micros,
            decode_micros: stats.decode_micros,
        })
    }
}

impl Drop for BatchedLlmSession {
    fn drop(&mut self) {
        if let Some(slot) = self.slot.take() {
            self.pool.borrow_mut().release(slot);
        }
    }
}

/// Substrate decode session: a [`GenSession`] rendered to an [`LlmResponse`]
/// at completion.
struct SubstrateSession {
    session: GenSession,
    /// Incremental view of the generated token stream for `take_delta`.
    decoder: crate::tokenizer::StreamDecoder,
    consumed: usize,
}

impl LlmSession for SubstrateSession {
    fn advance(&mut self) -> Result<bool> {
        self.session.advance()
    }

    fn is_done(&self) -> bool {
        self.session.is_done()
    }

    fn take_delta(&mut self) -> String {
        let toks = self.session.tokens();
        if self.consumed >= toks.len() {
            return String::new();
        }
        let delta = self.decoder.push_ids(&toks[self.consumed..]);
        self.consumed = toks.len();
        delta
    }

    fn finish(self: Box<Self>) -> Result<LlmResponse> {
        let g = self.session.finish();
        Ok(LlmResponse {
            text: g.text,
            usage: TokenUsage {
                input_tokens: g.stats.prompt_tokens,
                output_tokens: g.stats.generated_tokens,
            },
            restored_tokens: g.stats.restored_tokens,
            prefill_micros: g.stats.prefill_micros,
            decode_micros: g.stats.decode_micros,
        })
    }
}

impl LanguageModel for SubstrateLlm {
    fn name(&self) -> &str {
        &self.gen.model_name
    }

    fn respond(&mut self, query: &str) -> Result<LlmResponse> {
        self.run(&[query])
    }

    fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse> {
        let mut session = self.begin_tweak_session(prompt)?;
        while session.advance()? {}
        session.finish()
    }

    fn begin_respond(&mut self, query: &str) -> Result<Box<dyn LlmSession>> {
        self.begin(&[query])
    }

    fn begin_tweak(&mut self, prompt: &TweakPrompt) -> Result<Box<dyn LlmSession>> {
        self.begin_tweak_session(prompt)
    }

    fn batch_stats(&self) -> Option<BatchDecodeStats> {
        self.batch.as_ref().map(|pool| {
            let pool = pool.borrow();
            BatchDecodeStats {
                dispatches: pool.dispatches(),
                active_slot_sum: pool.active_slot_sum(),
                slots: pool.slot_count(),
            }
        })
    }

    fn prefix_stats(&self) -> Option<PrefixCacheStats> {
        self.prefix.as_ref().map(|c| c.borrow().stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tweak_prompt_orders_new_query_last() {
        // Static template first, new query last: the leading tokens of a
        // tweak are a pure function of the cache entry (prefix reuse), and
        // suffix-protected encoding keeps the query from being truncated.
        let p = TweakPrompt {
            new_query: "why is rust fast?".into(),
            cached_query: "why is rust safe?".into(),
            cached_response: "because borrow checker".into(),
        };
        let segs = p.segments();
        assert_eq!(segs.len(), 4);
        assert_eq!(segs[0], prompts::TWEAK_TEMPLATE);
        assert_eq!(segs[3], "why is rust fast?");
    }

    #[test]
    fn eager_session_yields_response() {
        let resp = LlmResponse {
            text: "canned".into(),
            usage: TokenUsage::default(),
            restored_tokens: 0,
            prefill_micros: 1,
            decode_micros: 2,
        };
        let mut s: Box<dyn LlmSession> = Box::new(EagerSession(resp));
        assert!(s.is_done());
        assert!(!s.advance().unwrap());
        assert_eq!(s.finish().unwrap().text, "canned");
    }

    #[test]
    fn default_begin_wraps_blocking_call() {
        // A session-unaware model still works through the session API.
        struct Plain;
        impl LanguageModel for Plain {
            fn name(&self) -> &str {
                "plain"
            }
            fn respond(&mut self, query: &str) -> Result<LlmResponse> {
                Ok(LlmResponse {
                    text: format!("re: {query}"),
                    usage: TokenUsage::default(),
                    restored_tokens: 0,
                    prefill_micros: 0,
                    decode_micros: 0,
                })
            }
            fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse> {
                self.respond(&prompt.new_query)
            }
        }
        let mut m = Plain;
        let mut s = m.begin_respond("hello").unwrap();
        while s.advance().unwrap() {}
        assert_eq!(s.finish().unwrap().text, "re: hello");
    }
}
