//! `tweakllm` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   config                         Print the (Table 1) configuration.
//!   serve  [--addr 127.0.0.1:7411] Start the engine + TCP front-end.
//!          [--data-dir DIR]        Durable cache (WAL + snapshots): recover
//!                                  on start, snapshot on graceful stop.
//!   query  --addr .. "text"        Send one query to a running server.
//!   snapshot [--addr ..]           Ask a running server to snapshot now.
//!   demo   [--n 12]                Self-contained routing demo on a trace.
//!
//! Figure/table reproduction lives in `cargo bench` (see DESIGN.md);
//! examples/ hold the end-to-end drivers.

use std::thread;

use anyhow::Result;

use tweakllm::baselines::MockLlm;
use tweakllm::cluster::{ClusterServer, HealthState, ReplicaListener, Shipper, Topology};
use tweakllm::config::Config;
use tweakllm::coordinator::{Engine, Router};
use tweakllm::datasets::{ChatTrace, TraceProfile};
use tweakllm::runtime::{NativeBowEmbedder, Runtime, TextEmbedder};
use tweakllm::server::{pathway_str, Client, HttpServer, Server};
use tweakllm::util::{Args, Json};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "usage: tweakllm <config|serve|query|snapshot|demo> [--flags]\n\
     \n\
     config                          print the active configuration (Table 1)\n\
     serve  [--addr HOST:PORT]       start engine + TCP front-end\n\
            [--config FILE] [--threshold T] [--exact-fast-path BOOL]\n\
            [--data-dir DIR]         durable cache: replay WAL+snapshot on\n\
                                     start, snapshot on graceful shutdown\n\
            [--trace-dir DIR]        export completed request traces as\n\
                                     JSONL to DIR/traces.jsonl\n\
            [--http-port PORT]       also serve OpenAI-compatible\n\
                                     /v1/chat/completions (SSE streaming)\n\
            [--mock=true]            mock models + native embedder (no\n\
                                     artifacts; cluster drills and CI)\n\
            [--ship-to ADDR]         shard owner: stream WAL records to a\n\
                                     replica's --replication-listen ADDR\n\
                                     (requires --data-dir)\n\
            [--replication-listen ADDR]  replica: apply a shipped WAL while\n\
                                     serving replica reads on --addr\n\
            [--cluster FILE]         router: fan requests to shard owners\n\
                                     per FILE (topology.toml), with\n\
                                     breaker-gated replica failover\n\
     query  [--addr HOST:PORT] TEXT  send one query to a running server\n\
     snapshot [--addr HOST:PORT]     force a cache snapshot + WAL rotation\n\
     demo   [--n N] [--threshold T]  route a small synthetic trace and report\n"
}

fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.opt_str("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::paper(),
    };
    if let Some(t) = args.opt_str("threshold") {
        cfg.set("router.similarity_threshold", t)?;
    }
    if let Some(b) = args.opt_str("exact-fast-path") {
        cfg.set("router.exact_match_fast_path", b)?;
    }
    if let Some(d) = args.opt_str("artifacts") {
        cfg.set("runtime.artifact_dir", d)?;
    }
    if let Some(d) = args.opt_str("data-dir") {
        cfg.set("persist.data_dir", d)?;
    }
    if let Some(d) = args.opt_str("trace-dir") {
        cfg.set("trace.export_dir", d)?;
    }
    if let Some(p) = args.opt_str("http-port") {
        cfg.set("server.http_port", p)?;
    }
    Ok(cfg)
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "config" => {
            let cfg = load_config(&args)?;
            println!("TweakLLM configuration (cf. paper Table 1)");
            println!("{:-<72}", "");
            for (k, v) in cfg.table() {
                println!("{k:<24} {v}");
            }
            Ok(())
        }
        "serve" => {
            let cfg = load_config(&args)?;
            let addr = args.str("addr", "127.0.0.1:7411");
            if let Some(topology_file) = args.opt_str("cluster") {
                // Router role: no engine of its own — shard the key space
                // across the topology's owners and fail over to replicas
                // under the bounded-staleness rule.
                let topology = Topology::from_file(topology_file)?;
                let cluster = ClusterServer::bind(&addr, topology, &cfg)?;
                eprintln!("[tweakllm] cluster router on {}", cluster.local_addr()?);
                return cluster.serve();
            }
            // Captured before cfg moves into the engine factory closure.
            let http_port = cfg.server.http_port;
            let data_dir = cfg.persist.data_dir.clone();
            let mock = args.bool("mock", false)?;
            let ship_to = args.opt_str("ship-to").map(str::to_string);
            let replication_listen = args.opt_str("replication-listen").map(str::to_string);
            if ship_to.is_some() && data_dir.is_empty() {
                anyhow::bail!("--ship-to requires --data-dir (the WAL is what ships)");
            }
            let role = if replication_listen.is_some() {
                "replica"
            } else if ship_to.is_some() {
                "owner"
            } else {
                "standalone"
            };
            let health = HealthState::new(role);
            let (_engine, handle) = Engine::start(move || {
                let mut router = if mock {
                    let embedder: Box<dyn TextEmbedder> =
                        Box::new(NativeBowEmbedder::new(128, 7));
                    let mut r = Router::with_models(
                        embedder,
                        Box::new(MockLlm::new("big")),
                        Box::new(MockLlm::new("small")),
                        cfg,
                    );
                    r.enable_persistence()?;
                    r
                } else {
                    eprintln!("[tweakllm] loading artifacts from {} ...", cfg.artifact_dir);
                    let rt = Runtime::load(&cfg.artifact_dir, &[])?;
                    eprintln!("[tweakllm] platform: {}", rt.platform());
                    Router::from_runtime(&rt, cfg)?
                };
                if let Some(r) = &router.recovery {
                    eprintln!(
                        "[tweakllm] recovered {} cache entries (generation {}, {} WAL ops replayed{})",
                        r.recovered_entries,
                        r.generation,
                        r.replayed_ops,
                        if r.torn_tail { ", torn WAL tail dropped" } else { "" }
                    );
                }
                Ok(router)
            })?;
            let _replication = match &replication_listen {
                Some(listen) => {
                    let l = ReplicaListener::start(listen, handle.clone(), health.clone())?;
                    eprintln!("[tweakllm] replication intake on {}", l.local_addr());
                    Some(l)
                }
                None => None,
            };
            let _shipper = ship_to.as_ref().map(|target| {
                eprintln!("[tweakllm] shipping WAL from {data_dir} to {target}");
                Shipper::start(data_dir.clone(), target, health.clone())
            });
            let server = Server::bind(&addr, handle.clone())?.with_health(health.extra());
            eprintln!("[tweakllm] serving on {} ({role})", server.local_addr()?);
            if http_port != 0 {
                let host = addr.rsplit_once(':').map(|(h, _)| h).unwrap_or("127.0.0.1");
                let http = HttpServer::bind(&format!("{host}:{http_port}"), handle)?
                    .with_health(health.extra());
                eprintln!(
                    "[tweakllm] OpenAI-compatible endpoint on http://{}/v1/chat/completions",
                    http.local_addr()?
                );
                thread::spawn(move || {
                    if let Err(e) = http.serve() {
                        eprintln!("[tweakllm] http front end exited: {e:#}");
                    }
                });
            }
            server.serve()
        }
        "query" => {
            let addr = args.str("addr", "127.0.0.1:7411");
            let text = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("query: missing TEXT argument"))?;
            let mut client = Client::connect(&addr)?;
            let resp = client.query(text)?;
            println!("{}", resp.to_string());
            Ok(())
        }
        "snapshot" => {
            let addr = args.str("addr", "127.0.0.1:7411");
            let mut client = Client::connect(&addr)?;
            let resp = client.snapshot()?;
            println!("{}", resp.to_string());
            Ok(())
        }
        "demo" => {
            let cfg = load_config(&args)?;
            let n = args.usize("n", 12)?;
            eprintln!("[demo] loading artifacts from {} ...", cfg.artifact_dir);
            let rt = Runtime::load(&cfg.artifact_dir, &[])?;
            let mut router = Router::from_runtime(&rt, cfg)?;
            let trace = ChatTrace::generate(TraceProfile::lmsys(), n, 7);
            println!(
                "{:<10} {:>6} {:>9}  {}",
                "pathway", "sim", "us", "query"
            );
            for q in &trace.queries {
                let r = router.handle(&q.text)?;
                println!(
                    "{:<10} {:>6} {:>9}  {}",
                    pathway_str(r.pathway),
                    r.similarity.map(|s| format!("{s:.3}")).unwrap_or_else(|| "-".into()),
                    r.total_micros,
                    &q.text[..q.text.len().min(56)]
                );
            }
            let stats = Json::obj_from(vec![
                ("requests", Json::num(router.counters.get("requests") as f64)),
                ("tweak_hits", Json::num(router.counters.get("tweak_hits") as f64)),
                ("misses", Json::num(router.counters.get("misses") as f64)),
                ("hit_rate", Json::num(router.hit_rate())),
                ("cost_dollars", Json::num(router.ledger.dollars(&router.config.cost))),
                (
                    "baseline_dollars",
                    Json::num(router.ledger.baseline_dollars(&router.config.cost)),
                ),
            ]);
            println!("\nstats: {}", stats.to_string());
            println!("\nlatency breakdown:\n{}", router.latency.table());
            Ok(())
        }
        _ => {
            print!("{}", usage());
            Ok(())
        }
    }
}
