//! Cluster topology file (`serve --cluster topology.toml`).
//!
//! The main config parser handles flat `section.key = value` tables only,
//! so the shard list gets its own tiny parser here. Format:
//!
//! ```toml
//! [cluster]
//! max_staleness_ms = 500   # replica hits allowed while lag <= this
//! epoch = 1                # bump when the shard list changes
//! vnodes = 128             # virtual nodes per shard on the hash ring
//!
//! [[shard]]                # one table per shard, ring position = order
//! owner = "127.0.0.1:7501"
//! replica = "127.0.0.1:7502"     # optional; omit for no failover target
//!
//! [[shard]]
//! owner = "127.0.0.1:7511"
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::ring::DEFAULT_VNODES;

#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardSpec {
    /// TCP line-protocol address of the shard owner.
    pub owner: String,
    /// Line-protocol address of the replica's front end (failover reads).
    pub replica: Option<String>,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Serve replica hits only while replication lag is at or under this;
    /// beyond it the router degrades to a cache-bypass miss instead.
    pub max_staleness_ms: u64,
    /// Shard-map epoch, reported by the health verb on every node.
    pub epoch: u64,
    /// Virtual nodes per shard on the consistent-hash ring.
    pub vnodes: usize,
    pub shards: Vec<ShardSpec>,
}

impl Topology {
    pub fn from_file(path: impl AsRef<Path>) -> Result<Topology> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading topology {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing topology {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Topology> {
        let mut topo = Topology {
            max_staleness_ms: 500,
            epoch: 1,
            vnodes: DEFAULT_VNODES,
            shards: Vec::new(),
        };
        #[derive(PartialEq)]
        enum Section {
            None,
            Cluster,
            Shard,
        }
        let mut section = Section::None;
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line == "[[shard]]" {
                topo.shards.push(ShardSpec::default());
                section = Section::Shard;
                continue;
            }
            if line == "[cluster]" {
                section = Section::Cluster;
                continue;
            }
            if line.starts_with('[') {
                bail!("line {}: unknown section {line}", ln + 1);
            }
            let (key, value) = match line.split_once('=') {
                Some((k, v)) => (k.trim(), v.trim().trim_matches('"')),
                None => bail!("line {}: expected key = value, got {line:?}", ln + 1),
            };
            match (&section, key) {
                (Section::Cluster, "max_staleness_ms") => {
                    topo.max_staleness_ms =
                        value.parse().with_context(|| format!("line {}", ln + 1))?;
                }
                (Section::Cluster, "epoch") => {
                    topo.epoch = value.parse().with_context(|| format!("line {}", ln + 1))?;
                }
                (Section::Cluster, "vnodes") => {
                    topo.vnodes = value.parse().with_context(|| format!("line {}", ln + 1))?;
                }
                (Section::Shard, "owner") => {
                    topo.shards.last_mut().unwrap().owner = value.to_string();
                }
                (Section::Shard, "replica") => {
                    topo.shards.last_mut().unwrap().replica = Some(value.to_string());
                }
                _ => bail!("line {}: unknown key {key:?} in this section", ln + 1),
            }
        }
        if topo.shards.is_empty() {
            bail!("topology has no [[shard]] tables");
        }
        if topo.vnodes == 0 {
            bail!("vnodes must be >= 1");
        }
        for (i, s) in topo.shards.iter().enumerate() {
            if s.owner.is_empty() {
                bail!("shard {i} has no owner address");
            }
        }
        Ok(topo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_topology() {
        let t = Topology::parse(
            r#"
            [cluster]
            max_staleness_ms = 250  # half the default
            epoch = 7

            [[shard]]
            owner = "127.0.0.1:7501"
            replica = "127.0.0.1:7502"

            [[shard]]
            owner = "127.0.0.1:7511"
            "#,
        )
        .unwrap();
        assert_eq!(t.max_staleness_ms, 250);
        assert_eq!(t.epoch, 7);
        assert_eq!(t.vnodes, DEFAULT_VNODES);
        assert_eq!(t.shards.len(), 2);
        assert_eq!(t.shards[0].owner, "127.0.0.1:7501");
        assert_eq!(t.shards[0].replica.as_deref(), Some("127.0.0.1:7502"));
        assert_eq!(t.shards[1].replica, None);
    }

    #[test]
    fn rejects_empty_and_malformed() {
        assert!(Topology::parse("[cluster]\nepoch = 1\n").is_err()); // no shards
        assert!(Topology::parse("[[shard]]\nreplica = \"x\"\n").is_err()); // no owner
        assert!(Topology::parse("[[shard]]\nowner = \"x\"\nbogus\n").is_err());
        assert!(Topology::parse("[wrong]\n").is_err());
        assert!(Topology::parse("[[shard]]\nowner = \"x\"\n[cluster]\nvnodes = 0\n").is_err());
    }
}
