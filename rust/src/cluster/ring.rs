//! Consistent-hash shard ring with virtual nodes.
//!
//! Each shard contributes `vnodes` points on a 64-bit ring; a key routes
//! to the owner of the first point at or after its hash (wrapping). The
//! point set is a pure function of `(shards, vnodes)` — no RNG, no state —
//! so routing is stable across process restarts, and growing the ring from
//! N to N+1 shards only reassigns the keys that fall between the new
//! shard's points and their predecessors (~1/(N+1) of the key space).
//!
//! Ties (two shards hashing a vnode to the same point — vanishingly rare
//! with 64-bit hashes, but the ring must be deterministic even then) are
//! broken rendezvous-style: the key is routed to whichever colliding shard
//! maximizes `hash(key ‖ shard)`, which is still restart-stable.

use crate::util::rng::hash_bytes;

/// Virtual nodes per shard when the topology doesn't override it. Enough
/// to keep the max/min shard-load ratio near 1 at single-digit shard
/// counts without making ring construction measurable.
pub const DEFAULT_VNODES: usize = 128;

pub struct ShardRing {
    /// `(point, shard)` sorted by point (then shard, for determinism).
    points: Vec<(u64, usize)>,
    shards: usize,
}

impl ShardRing {
    pub fn new(shards: usize, vnodes: usize) -> ShardRing {
        assert!(shards >= 1, "ring needs at least one shard");
        assert!(vnodes >= 1, "ring needs at least one vnode per shard");
        let mut points = Vec::with_capacity(shards * vnodes);
        for s in 0..shards {
            for v in 0..vnodes {
                points.push((hash_bytes(format!("shard-{s}-vnode-{v}").as_bytes()), s));
            }
        }
        points.sort_unstable();
        ShardRing { points, shards }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Shard owning `key` (use [`crate::cache::query_key`] for query text,
    /// so the router and every owner's exact-match path agree on identity).
    pub fn route(&self, key: u64) -> usize {
        let n = self.points.len();
        let mut i = self.points.partition_point(|(p, _)| *p < key);
        if i == n {
            i = 0;
        }
        let point = self.points[i].0;
        // Rendezvous tie-break across every shard colliding on this point.
        let mut best = self.points[i].1;
        let mut best_weight = Self::weight(key, best);
        let mut j = i + 1;
        while j < n && self.points[j].0 == point {
            let w = Self::weight(key, self.points[j].1);
            if w > best_weight {
                best = self.points[j].1;
                best_weight = w;
            }
            j += 1;
        }
        best
    }

    fn weight(key: u64, shard: usize) -> u64 {
        let mut buf = [0u8; 16];
        buf[..8].copy_from_slice(&key.to_le_bytes());
        buf[8..].copy_from_slice(&(shard as u64).to_le_bytes());
        hash_bytes(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_are_deterministic_across_reconstruction() {
        let a = ShardRing::new(4, DEFAULT_VNODES);
        let b = ShardRing::new(4, DEFAULT_VNODES);
        for k in 0..1000u64 {
            let key = hash_bytes(&k.to_le_bytes());
            assert_eq!(a.route(key), b.route(key));
        }
    }

    #[test]
    fn all_shards_receive_load() {
        let ring = ShardRing::new(4, DEFAULT_VNODES);
        let mut counts = [0usize; 4];
        for k in 0..4000u64 {
            counts[ring.route(hash_bytes(&k.to_le_bytes()))] += 1;
        }
        for (s, c) in counts.iter().enumerate() {
            assert!(*c > 0, "shard {s} got no keys");
        }
    }

    #[test]
    fn single_shard_takes_everything() {
        let ring = ShardRing::new(1, 8);
        for k in 0..100u64 {
            assert_eq!(ring.route(hash_bytes(&k.to_le_bytes())), 0);
        }
    }
}
