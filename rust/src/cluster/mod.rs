//! Cluster mode: consistent-hash cache sharding with WAL-shipping replicas.
//!
//! Three process roles built from today's single-node engine:
//!
//! * **Shard owner** — a normal `serve` process that owns one shard of the
//!   embedding-keyed cache. With `--ship-to ADDR` it streams every WAL
//!   record its [`crate::cache::persist`] layer writes to a follower.
//! * **Replica** — a `serve --replication-listen ADDR` process that applies
//!   the shipped records continuously through the existing recovery path
//!   ([`crate::coordinator::ReplicaBatch`]) and acks its applied position,
//!   so the owner can expose measured replication lag.
//! * **Router** — `serve --cluster topology.toml`: a thin front end that
//!   hashes each query onto the shard ring ([`ring::ShardRing`]) and fans
//!   it to the owner over the TCP line protocol. Owner failures (detected
//!   by a per-shard [`crate::faults::CircuitBreaker`]) fail over to the
//!   replica under a bounded-staleness rule: replica hits are served only
//!   while replication lag ≤ `[cluster] max_staleness_ms`, otherwise the
//!   request degrades to a cache-bypass miss — stale text is never served.
//!
//! The WAL ship protocol lives in [`ship`]; the topology file format in
//! [`topology`]; the failure drills in `rust/tests/cluster.rs` and
//! `benches/cluster_failover.rs`. See DESIGN.md, "Cluster mode &
//! replication".

pub mod ring;
pub mod router;
pub mod ship;
pub mod topology;

pub use ring::ShardRing;
pub use router::ClusterServer;
pub use ship::{ReplicaListener, Shipper};
pub use topology::{ShardSpec, Topology};

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::server::HealthExtra;
use crate::util::Json;

/// Role + replication position shared between the shipping / applying
/// threads and the health verb (`{"admin": "health"}`, `GET /healthz`).
#[derive(Clone, Debug, Default)]
pub struct HealthSnapshot {
    /// "standalone", "owner", "replica", or "router".
    pub role: String,
    /// Shard-map epoch from the topology file (0 = not clustered).
    pub shard_epoch: u64,
    /// Owner side: last WAL position handed to the socket.
    pub shipped_gen: u64,
    pub shipped_seq: u64,
    /// Owner side: last position the replica acked, and how far behind the
    /// newest shipped record that ack is.
    pub acked_gen: u64,
    pub acked_seq: u64,
    pub ack_lag_ms: u64,
    /// Owner side: a replica connection is currently attached.
    pub connected: bool,
    /// Replica side: last WAL position applied to the local cache.
    pub applied_gen: u64,
    pub applied_seq: u64,
    /// Replica side: shipped records are known to exist past the applied
    /// position since this instant (None = caught up).
    pub behind_since: Option<Instant>,
    /// Replica side: record application is paused (lag-injection drills).
    pub apply_paused: bool,
}

impl HealthSnapshot {
    /// Bounded-staleness input: 0 while caught up, else time spent behind.
    pub fn staleness_ms(&self) -> u64 {
        self.behind_since.map(|t| t.elapsed().as_millis() as u64).unwrap_or(0)
    }
}

/// Shared, thread-safe [`HealthSnapshot`]. Cloning shares the state.
#[derive(Clone, Default)]
pub struct HealthState(Arc<Mutex<HealthSnapshot>>);

impl HealthState {
    pub fn new(role: &str) -> HealthState {
        let state = HealthState::default();
        state.update(|h| h.role = role.to_string());
        state
    }

    pub fn update(&self, f: impl FnOnce(&mut HealthSnapshot)) {
        f(&mut self.0.lock().unwrap());
    }

    pub fn snapshot(&self) -> HealthSnapshot {
        self.0.lock().unwrap().clone()
    }

    /// The `"replication"` object merged into health replies.
    pub fn to_json(&self) -> Json {
        let h = self.snapshot();
        Json::obj_from(vec![
            ("role", Json::s(h.role.clone())),
            ("shard_epoch", Json::num(h.shard_epoch as f64)),
            (
                "replication",
                Json::obj_from(vec![
                    ("connected", Json::Bool(h.connected)),
                    ("shipped_gen", Json::num(h.shipped_gen as f64)),
                    ("shipped_seq", Json::num(h.shipped_seq as f64)),
                    ("acked_gen", Json::num(h.acked_gen as f64)),
                    ("acked_seq", Json::num(h.acked_seq as f64)),
                    ("ack_lag_ms", Json::num(h.ack_lag_ms as f64)),
                    ("applied_gen", Json::num(h.applied_gen as f64)),
                    ("applied_seq", Json::num(h.applied_seq as f64)),
                    ("staleness_ms", Json::num(h.staleness_ms() as f64)),
                    ("apply_paused", Json::Bool(h.apply_paused)),
                ]),
            ),
        ])
    }

    /// Adapter for [`crate::server::Server::with_health`].
    pub fn extra(&self) -> HealthExtra {
        let state = self.clone();
        Arc::new(move || state.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staleness_zero_when_caught_up() {
        let h = HealthState::new("replica");
        assert_eq!(h.snapshot().staleness_ms(), 0);
        h.update(|s| {
            s.behind_since = Some(Instant::now() - std::time::Duration::from_millis(250))
        });
        assert!(h.snapshot().staleness_ms() >= 250);
        h.update(|s| s.behind_since = None);
        assert_eq!(h.snapshot().staleness_ms(), 0);
    }

    #[test]
    fn health_json_shape() {
        let h = HealthState::new("owner");
        h.update(|s| {
            s.shard_epoch = 3;
            s.shipped_gen = 1;
            s.shipped_seq = 42;
            s.connected = true;
        });
        let j = h.to_json();
        assert_eq!(j.get("role").unwrap().str().unwrap(), "owner");
        assert_eq!(j.get("shard_epoch").unwrap().usize().unwrap(), 3);
        let r = j.get("replication").unwrap();
        assert_eq!(r.get("shipped_seq").unwrap().usize().unwrap(), 42);
        assert!(r.get("connected").unwrap().bool().unwrap());
    }
}
