//! The cluster router front end (`serve --cluster topology.toml`).
//!
//! Speaks the same line protocol as the single-node [`crate::server`], but
//! instead of owning an engine it hashes each query onto the shard ring
//! and forwards it to the owning shard's `serve` process. Failure handling
//! per shard:
//!
//! 1. **Owner healthy** — forward, relay the reply verbatim (plus `shard`
//!    and `served_by` fields). Structured error replies from a live owner
//!    (shed, terminal failure) are relayed as-is: the owner's own
//!    degradation ladder already ran.
//! 2. **Owner unreachable** (connect/write/read error, or its circuit
//!    breaker is open) — probe the replica's measured replication lag. If
//!    `staleness_ms <= max_staleness_ms`, serve the read from the replica
//!    in `replica_read` mode (hits allowed, no cache mutation). Otherwise
//!    degrade to a `bypass` read — a fresh uncached generation — so stale
//!    cache text is never served.
//! 3. **No replica / replica also down** — structured error reply. The
//!    request still gets exactly one reply and one finished trace.
//!
//! Every request records a [`Stage::ShardRoute`] span (value = shard
//! index) in the router's own [`TraceHub`], so one-reply-one-trace can be
//! asserted end-to-end in the kill drills.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cache::query_key;
use crate::config::Config;
use crate::faults::CircuitBreaker;
use crate::server::{
    accept_loop, error_reply, send_reply, Shutdown, MAX_LINE_BYTES, READ_POLL_INTERVAL,
    WRITE_TIMEOUT,
};
use crate::trace::{Stage, TraceHub, TraceTag};
use crate::util::Json;

use super::ring::ShardRing;
use super::topology::Topology;
use super::HealthState;

/// Bound on one forwarded request (the backend may be mid-generation).
const BACKEND_READ_TIMEOUT: Duration = Duration::from_secs(30);

struct ShardState {
    owner: String,
    replica: Option<String>,
    breaker: Mutex<CircuitBreaker>,
}

#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    owner_served: AtomicU64,
    replica_served: AtomicU64,
    bypass_served: AtomicU64,
    failovers: AtomicU64,
    errors: AtomicU64,
}

struct ClusterInner {
    topology: Topology,
    ring: ShardRing,
    shards: Vec<ShardState>,
    traces: Mutex<TraceHub>,
    threshold: f32,
    counters: Counters,
    health: HealthState,
}

pub struct ClusterServer {
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    inner: Arc<ClusterInner>,
}

impl ClusterServer {
    /// `cfg` supplies the per-shard breaker thresholds (`[faults]`) and the
    /// router's trace settings (`[trace]`); the shard list comes from the
    /// topology file.
    pub fn bind(addr: &str, topology: Topology, cfg: &Config) -> Result<ClusterServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding cluster {addr}"))?;
        let ring = ShardRing::new(topology.shards.len(), topology.vnodes);
        let shards = topology
            .shards
            .iter()
            .map(|s| ShardState {
                owner: s.owner.clone(),
                replica: s.replica.clone(),
                breaker: Mutex::new(CircuitBreaker::from_config(&cfg.faults)),
            })
            .collect();
        let health = HealthState::new("router");
        health.update(|h| h.shard_epoch = topology.epoch);
        let inner = Arc::new(ClusterInner {
            topology,
            ring,
            shards,
            traces: Mutex::new(TraceHub::new(cfg.trace.clone())),
            threshold: cfg.similarity_threshold,
            counters: Counters::default(),
            health,
        });
        Ok(ClusterServer { listener, stop: Arc::new(AtomicBool::new(false)), inner })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn shutdown_handle(&self) -> Result<Shutdown> {
        Ok(Shutdown::new(Arc::clone(&self.stop), self.listener.local_addr()?))
    }

    /// Serve until [`Shutdown::signal`]. Blocks the calling thread.
    pub fn serve(&self) -> Result<()> {
        accept_loop(&self.listener, &self.stop, |stream| {
            let inner = Arc::clone(&self.inner);
            let stop = Arc::clone(&self.stop);
            thread::spawn(move || {
                let _ = handle_router_connection(stream, inner, stop);
            });
        })
    }
}

/// Line-protocol connection to one backend process; reconnected lazily by
/// [`backend_roundtrip`] after any failure.
struct Backend {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Backend {
    fn connect(addr: &str) -> Result<Backend> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(BACKEND_READ_TIMEOUT))?;
        stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
        Ok(Backend { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    fn roundtrip(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            bail!("backend closed the connection");
        }
        Json::parse(&line)
    }
}

/// Send one request on a cached backend connection, dialing (or redialing
/// after a previous failure) on demand. Any error drops the cached
/// connection so the next attempt starts clean.
fn backend_roundtrip(
    conns: &mut HashMap<String, Backend>,
    addr: &str,
    req: &Json,
) -> Result<Json> {
    if !conns.contains_key(addr) {
        conns.insert(addr.to_string(), Backend::connect(addr)?);
    }
    let result = conns.get_mut(addr).unwrap().roundtrip(req);
    if result.is_err() {
        conns.remove(addr);
    }
    result
}

fn handle_router_connection(
    stream: TcpStream,
    inner: Arc<ClusterInner>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(READ_POLL_INTERVAL))?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // Backend connections are per client connection: no shared mutable
    // state on the forward path, so one slow backend never holds a lock
    // other clients need.
    let mut conns: HashMap<String, Backend> = HashMap::new();
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {
                if line.len() > MAX_LINE_BYTES {
                    send_reply(
                        &mut writer,
                        &error_reply(format!("request line exceeds {MAX_LINE_BYTES} bytes")),
                    )?;
                    break;
                }
                if !line.trim().is_empty() {
                    let reply = process_router_line(&line, &inner, &mut conns);
                    send_reply(&mut writer, &reply)?;
                }
                line.clear();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if line.len() > MAX_LINE_BYTES {
                    send_reply(
                        &mut writer,
                        &error_reply(format!("request line exceeds {MAX_LINE_BYTES} bytes")),
                    )?;
                    break;
                }
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                send_reply(&mut writer, &error_reply("request is not valid UTF-8".into()))?;
                line.clear();
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

fn process_router_line(
    line: &str,
    inner: &ClusterInner,
    conns: &mut HashMap<String, Backend>,
) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return error_reply(format!("bad json: {e}")),
    };
    if req.opt("stats").is_some() {
        return inner.stats_json();
    }
    if let Some(admin) = req.opt("admin") {
        return match admin.str() {
            Ok("health") => inner.health_json(),
            Ok("trace") => {
                let n = req.opt("n").and_then(|v| v.usize().ok()).unwrap_or(16);
                let r = inner.traces.lock().unwrap().report(n);
                Json::obj_from(vec![
                    ("traces", Json::Arr(r.traces.iter().map(|t| t.to_json()).collect())),
                    ("slow", Json::Arr(r.slow.iter().map(|t| t.to_json()).collect())),
                    ("finished", Json::num(r.finished as f64)),
                    ("dropped", Json::num(r.dropped as f64)),
                ])
            }
            _ => error_reply(
                "unknown admin command (expected \"health\" or \"trace\")".into(),
            ),
        };
    }
    let query = match req.opt("query").and_then(|q| q.str().ok()) {
        Some(q) => q.to_string(),
        None => {
            return error_reply("expected {\"query\": ...} or {\"stats\": true}".into())
        }
    };
    inner.handle_query(&query, conns)
}

impl ClusterInner {
    fn handle_query(&self, query: &str, conns: &mut HashMap<String, Backend>) -> Json {
        let t0 = Instant::now();
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        let mut trace = self.traces.lock().unwrap().begin(query, t0);
        let shard = self.ring.route(query_key(query));
        let (mut reply, served_by, staleness) = self.dispatch(shard, query, conns);
        // One span covering pick + forward (+ fallback); value = shard.
        trace.span_at(Stage::ShardRoute, t0, Instant::now(), shard as f32);
        let tag = match reply.opt("pathway").and_then(|p| p.str().ok()) {
            Some("exact_hit") => TraceTag::ExactHit,
            Some("tweak_hit") => TraceTag::TweakHit,
            Some("degraded_hit") => TraceTag::DegradedHit,
            Some("miss") => TraceTag::Miss,
            _ => TraceTag::Failed,
        };
        if tag == TraceTag::Failed {
            self.counters.errors.fetch_add(1, Ordering::Relaxed);
        }
        let total_us = t0.elapsed().as_micros() as u64;
        self.traces.lock().unwrap().finish(&mut trace, tag, total_us, self.threshold);
        if let Json::Obj(m) = &mut reply {
            m.insert("shard".into(), Json::num(shard as f64));
            m.insert("served_by".into(), Json::s(served_by));
            if let Some(ms) = staleness {
                m.insert("staleness_ms".into(), Json::num(ms as f64));
            }
        }
        reply
    }

    /// Owner-first, breaker-gated forward with bounded-staleness fallback.
    fn dispatch(
        &self,
        shard: usize,
        query: &str,
        conns: &mut HashMap<String, Backend>,
    ) -> (Json, &'static str, Option<u64>) {
        let st = &self.shards[shard];
        let req = Json::obj_from(vec![("query", Json::s(query))]);
        if st.breaker.lock().unwrap().allow(Instant::now()) {
            match backend_roundtrip(conns, &st.owner, &req) {
                Ok(reply) => {
                    // The owner responded — even a structured error means
                    // the process is alive and ran its own ladder.
                    st.breaker.lock().unwrap().record_success(Instant::now());
                    self.counters.owner_served.fetch_add(1, Ordering::Relaxed);
                    return (reply, "owner", None);
                }
                Err(_) => {
                    st.breaker.lock().unwrap().record_failure(Instant::now());
                }
            }
        }
        self.counters.failovers.fetch_add(1, Ordering::Relaxed);
        let Some(replica) = &st.replica else {
            return (
                error_reply(format!("shard {shard} owner unavailable and has no replica")),
                "none",
                None,
            );
        };
        // Bounded staleness: ask the replica how far behind it is. An
        // unreachable replica reads as infinitely stale.
        let staleness = backend_roundtrip(
            conns,
            replica,
            &Json::obj_from(vec![("admin", Json::s("health"))]),
        )
        .ok()
        .and_then(|h| {
            h.opt("replication")?.opt("staleness_ms").and_then(|v| v.usize().ok())
        })
        .map(|ms| ms as u64)
        .unwrap_or(u64::MAX);
        let (mode, served_by) = if staleness <= self.topology.max_staleness_ms {
            ("replica_read", "replica")
        } else {
            // Too stale for cache hits: a fresh uncached generation keeps
            // the request available without serving stale text.
            ("bypass", "replica_bypass")
        };
        let req = Json::obj_from(vec![("query", Json::s(query)), ("mode", Json::s(mode))]);
        match backend_roundtrip(conns, replica, &req) {
            Ok(reply) => {
                let ctr = if mode == "bypass" {
                    &self.counters.bypass_served
                } else {
                    &self.counters.replica_served
                };
                ctr.fetch_add(1, Ordering::Relaxed);
                (reply, served_by, Some(staleness))
            }
            Err(e) => (
                error_reply(format!("shard {shard}: owner and replica unavailable: {e:#}")),
                "none",
                Some(staleness),
            ),
        }
    }

    fn shard_rows(&self) -> Json {
        Json::Arr(
            self.shards
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let b = s.breaker.lock().unwrap();
                    Json::obj_from(vec![
                        ("shard", Json::num(i as f64)),
                        ("owner", Json::s(s.owner.clone())),
                        (
                            "replica",
                            s.replica
                                .clone()
                                .map(Json::s)
                                .unwrap_or(Json::Null),
                        ),
                        ("breaker", Json::s(b.state().name())),
                        ("trips", Json::num(b.trips() as f64)),
                    ])
                })
                .collect(),
        )
    }

    fn stats_json(&self) -> Json {
        let c = &self.counters;
        Json::obj_from(vec![
            ("requests", Json::num(c.requests.load(Ordering::Relaxed) as f64)),
            ("owner_served", Json::num(c.owner_served.load(Ordering::Relaxed) as f64)),
            ("replica_served", Json::num(c.replica_served.load(Ordering::Relaxed) as f64)),
            ("bypass_served", Json::num(c.bypass_served.load(Ordering::Relaxed) as f64)),
            ("failovers", Json::num(c.failovers.load(Ordering::Relaxed) as f64)),
            ("errors", Json::num(c.errors.load(Ordering::Relaxed) as f64)),
            (
                "traces_finished",
                Json::num(self.traces.lock().unwrap().finished() as f64),
            ),
            ("shards", self.shard_rows()),
        ])
    }

    fn health_json(&self) -> Json {
        let mut j = self.health.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("ok".into(), Json::Bool(true));
            m.insert(
                "max_staleness_ms".into(),
                Json::num(self.topology.max_staleness_ms as f64),
            );
            m.insert("shards".into(), self.shard_rows());
        }
        j
    }
}
