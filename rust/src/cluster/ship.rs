//! WAL shipping: owner → replica replication over TCP.
//!
//! Frame format (all integers little-endian):
//!
//! ```text
//! kind u8 | gen u64 | seq u64 | len u32 | payload[len]
//! ```
//!
//! | kind | dir | meaning |
//! |------|-----|---------|
//! | `HELLO`     | replica → owner | applied position; sent once on connect |
//! | `BOOTSTRAP` | owner → replica | raw snapshot bytes for `gen` (empty = start fresh at `gen`) |
//! | `RECORD`    | owner → replica | one raw WAL record frame at (`gen`, `seq`) |
//! | `HEARTBEAT` | owner → replica | owner's WAL end position (staleness signal) |
//! | `ACK`       | replica → owner | applied position (drives measured lag) |
//!
//! The shipper tails the owner's live WAL with [`WalTailer`], which only
//! surfaces complete checksummed records — exactly the prefix crash
//! recovery would replay — so replication and recovery can never disagree
//! about what a generation contains. On resume the replica's HELLO names
//! its applied position; if the owner can no longer serve it (compaction
//! moved on, or the tailer loses the log) the shipper falls back to a full
//! snapshot BOOTSTRAP and re-tails from that generation's start.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cache::persist::{bootstrap_view, decode_snapshot, decode_wal_record, WalTailer};
use crate::coordinator::{EngineHandle, ReplicaBatch};
use crate::server::{accept_loop, READ_POLL_INTERVAL};

use super::HealthState;

pub const FRAME_HELLO: u8 = 0;
pub const FRAME_BOOTSTRAP: u8 = 1;
pub const FRAME_RECORD: u8 = 2;
pub const FRAME_HEARTBEAT: u8 = 3;
pub const FRAME_ACK: u8 = 4;

/// Header = kind + gen + seq + len.
const FRAME_HEADER_LEN: usize = 1 + 8 + 8 + 4;

/// Sanity bound on one frame payload (a snapshot can be large, garbage on
/// the wire should not allocate unbounded).
const MAX_FRAME_PAYLOAD: u32 = 1 << 30;

/// How long the shipper sleeps between WAL polls when idle, and how often
/// it heartbeats its end position to the replica.
const POLL_INTERVAL: Duration = Duration::from_millis(15);

/// Backoff between reconnect attempts to an unreachable replica.
const RECONNECT_BACKOFF: Duration = Duration::from_millis(100);

#[derive(Clone, Debug)]
pub struct Frame {
    pub kind: u8,
    pub gen: u64,
    pub seq: u64,
    pub payload: Vec<u8>,
}

/// Encode and send one frame as a single write.
pub fn write_frame(
    w: &mut impl Write,
    kind: u8,
    gen: u64,
    seq: u64,
    payload: &[u8],
) -> Result<()> {
    let mut buf = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    buf.push(kind);
    buf.extend_from_slice(&gen.to_le_bytes());
    buf.extend_from_slice(&seq.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(payload);
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Fill `buf` completely, treating read timeouts as stop-flag poll points
/// (partial fills are kept, so a timeout mid-frame never desyncs framing).
/// `Ok(false)` = clean end: EOF on a frame boundary, or stop requested.
fn read_full(stream: &mut TcpStream, buf: &mut [u8], stop: &AtomicBool) -> Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        if stop.load(Ordering::Relaxed) {
            return Ok(false);
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                bail!("peer closed mid-frame ({filled}/{} bytes)", buf.len());
            }
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

/// Read one frame. The stream must have a read timeout set (the poll
/// points above observe `stop`). `Ok(None)` = clean end of stream / stop.
pub fn read_frame(stream: &mut TcpStream, stop: &AtomicBool) -> Result<Option<Frame>> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    if !read_full(stream, &mut header, stop)? {
        return Ok(None);
    }
    let kind = header[0];
    let gen = u64::from_le_bytes(header[1..9].try_into().unwrap());
    let seq = u64::from_le_bytes(header[9..17].try_into().unwrap());
    let len = u32::from_le_bytes(header[17..21].try_into().unwrap());
    if len > MAX_FRAME_PAYLOAD {
        bail!("frame payload {len} exceeds {MAX_FRAME_PAYLOAD} bytes");
    }
    let mut payload = vec![0u8; len as usize];
    if !read_full(stream, &mut payload, stop)? {
        bail!("stream ended mid-payload");
    }
    Ok(Some(Frame { kind, gen, seq, payload }))
}

// ---------------------------------------------------------------------------
// Owner side: the shipper
// ---------------------------------------------------------------------------

/// Background thread on a shard owner that streams the data directory's
/// WAL to one replica, reconnecting (with resume-or-bootstrap negotiation)
/// whenever the connection drops.
pub struct Shipper {
    stop: Arc<AtomicBool>,
    thread: Option<thread::JoinHandle<()>>,
}

impl Shipper {
    pub fn start(data_dir: impl Into<PathBuf>, target: &str, health: HealthState) -> Shipper {
        let dir = data_dir.into();
        let target = target.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                if let Ok(stream) = TcpStream::connect(&target) {
                    health.update(|h| h.connected = true);
                    if let Err(e) = ship_session(&dir, stream, &health, &stop2) {
                        eprintln!("[ship] session to {target} ended: {e:#}");
                    }
                    health.update(|h| h.connected = false);
                }
                if !stop2.load(Ordering::Relaxed) {
                    thread::sleep(RECONNECT_BACKOFF);
                }
            }
        });
        Shipper { stop, thread: Some(thread) }
    }

    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Shipper {
    fn drop(&mut self) {
        self.halt();
    }
}

/// One connected replication session: HELLO → (resume | BOOTSTRAP) →
/// RECORD/HEARTBEAT stream, with an ack-reader thread measuring lag.
fn ship_session(
    dir: &Path,
    mut stream: TcpStream,
    health: &HealthState,
    stop: &Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(READ_POLL_INTERVAL))?;
    let session_stop = Arc::new(AtomicBool::new(false));

    let hello = match read_frame(&mut stream, stop)? {
        Some(f) if f.kind == FRAME_HELLO => f,
        Some(f) => bail!("expected HELLO, got frame kind {}", f.kind),
        None => return Ok(()), // stopped / replica went away before HELLO
    };

    // In-flight records awaiting ack: (gen, seq, send instant).
    let sent: Arc<Mutex<VecDeque<(u64, u64, Instant)>>> = Arc::default();

    let mut tailer = match WalTailer::resume(dir, hello.gen, hello.seq) {
        Ok(t) => t,
        Err(_) => send_bootstrap(dir, &mut stream, &sent)?,
    };
    let (g, s) = tailer.position();
    health.update(|h| {
        h.shipped_gen = g;
        h.shipped_seq = s;
    });

    // Acks arrive on the same socket; a dedicated reader keeps the ship
    // loop free to tail the WAL and lets lag be measured off real acks.
    let ack_thread = {
        let mut rd = stream.try_clone()?;
        let sent = Arc::clone(&sent);
        let health = health.clone();
        let session_stop = Arc::clone(&session_stop);
        let outer_stop = Arc::clone(stop);
        thread::spawn(move || {
            loop {
                if outer_stop.load(Ordering::Relaxed) {
                    break;
                }
                match read_frame(&mut rd, &session_stop) {
                    Ok(Some(f)) if f.kind == FRAME_ACK => {
                        let mut lag_ms = 0;
                        {
                            let mut q = sent.lock().unwrap();
                            while let Some(&(g, s, at)) = q.front() {
                                if (g, s) > (f.gen, f.seq) {
                                    break;
                                }
                                lag_ms = at.elapsed().as_millis() as u64;
                                q.pop_front();
                            }
                        }
                        health.update(|h| {
                            h.acked_gen = f.gen;
                            h.acked_seq = f.seq;
                            h.ack_lag_ms = lag_ms;
                        });
                    }
                    Ok(Some(f)) => {
                        eprintln!("[ship] unexpected frame kind {} from replica", f.kind);
                        break;
                    }
                    Ok(None) | Err(_) => break,
                }
            }
            session_stop.store(true, Ordering::Relaxed);
        })
    };

    let result = (|| -> Result<()> {
        loop {
            if stop.load(Ordering::Relaxed) || session_stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            let records = match tailer.poll() {
                Ok(r) => r,
                // Tailer lost the log (file vanished/shrank): start over
                // from the newest snapshot.
                Err(_) => {
                    tailer = send_bootstrap(dir, &mut stream, &sent)?;
                    continue;
                }
            };
            if records.is_empty() {
                // Fall-behind check: compaction can advance the on-disk
                // generation without this tailer ever seeing a GenBump
                // record (crash between snapshot rename and bump append).
                let (disk_gen, _) = bootstrap_view(dir)?;
                if disk_gen > tailer.position().0 {
                    tailer = send_bootstrap(dir, &mut stream, &sent)?;
                    continue;
                }
                let (g, s) = tailer.position();
                write_frame(&mut stream, FRAME_HEARTBEAT, g, s, &[])?;
                thread::sleep(POLL_INTERVAL);
                continue;
            }
            for r in records {
                write_frame(&mut stream, FRAME_RECORD, r.generation, r.seq, &r.frame)?;
                sent.lock().unwrap().push_back((r.generation, r.seq, Instant::now()));
                health.update(|h| {
                    h.shipped_gen = r.generation;
                    h.shipped_seq = r.seq;
                });
            }
        }
    })();
    session_stop.store(true, Ordering::Relaxed);
    stream.shutdown(std::net::Shutdown::Both).ok();
    let _ = ack_thread.join();
    result
}

/// Ship the newest snapshot (or "fresh at generation g" when none exists)
/// and return a tailer positioned at that generation's WAL start.
fn send_bootstrap(
    dir: &Path,
    stream: &mut TcpStream,
    sent: &Arc<Mutex<VecDeque<(u64, u64, Instant)>>>,
) -> Result<WalTailer> {
    let (gen, snap) = bootstrap_view(dir)?;
    write_frame(stream, FRAME_BOOTSTRAP, gen, 0, snap.as_deref().unwrap_or(&[]))?;
    sent.lock().unwrap().clear();
    Ok(WalTailer::from_generation_start(dir, gen))
}

// ---------------------------------------------------------------------------
// Replica side: the listener
// ---------------------------------------------------------------------------

/// Replication intake on a replica: accepts a shipper connection, applies
/// BOOTSTRAP/RECORD frames through the engine's replication entry point
/// (the same code path crash recovery uses), and acks each applied
/// position. The replica's normal front end keeps serving reads while
/// this runs — that is the whole point.
pub struct ReplicaListener {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
    health: HealthState,
    thread: Option<thread::JoinHandle<()>>,
}

impl ReplicaListener {
    pub fn start(addr: &str, engine: EngineHandle, health: HealthState) -> Result<ReplicaListener> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding replication {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let session_health = health.clone();
        let thread = thread::spawn(move || {
            let result = accept_loop(&listener, &accept_stop, |stream| {
                let engine = engine.clone();
                let health = session_health.clone();
                let stop = Arc::clone(&accept_stop);
                thread::spawn(move || {
                    if let Err(e) = replica_session(stream, &engine, &health, &stop) {
                        eprintln!("[replica] session ended: {e:#}");
                    }
                });
            });
            if let Err(e) = result {
                eprintln!("[replica] listener exited: {e:#}");
            }
        });
        Ok(ReplicaListener { stop, addr: local, health, thread: Some(thread) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Lag injection for drills: while paused, shipped records queue
    /// unapplied and measured staleness grows.
    pub fn set_apply_paused(&self, paused: bool) {
        self.health.update(|h| h.apply_paused = paused);
    }

    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept (same trick as server::Shutdown).
        let mut addr = self.addr;
        if addr.ip().is_unspecified() {
            match addr {
                SocketAddr::V4(_) => {
                    addr.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST))
                }
                SocketAddr::V6(_) => {
                    addr.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST))
                }
            }
        }
        if let Ok(s) = TcpStream::connect_timeout(&addr, Duration::from_secs(1)) {
            drop(s);
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ReplicaListener {
    fn drop(&mut self) {
        self.halt();
    }
}

fn replica_session(
    mut stream: TcpStream,
    engine: &EngineHandle,
    health: &HealthState,
    stop: &Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(READ_POLL_INTERVAL))?;
    let h = health.snapshot();
    write_frame(&mut stream, FRAME_HELLO, h.applied_gen, h.applied_seq, &[])?;
    while let Some(f) = read_frame(&mut stream, stop)? {
        match f.kind {
            FRAME_BOOTSTRAP => {
                let state = if f.payload.is_empty() {
                    None
                } else {
                    Some(decode_snapshot(&f.payload)?.0)
                };
                engine.apply_replicated(ReplicaBatch::Bootstrap(state))?;
                health.update(|hh| {
                    hh.applied_gen = f.gen;
                    hh.applied_seq = 0;
                    hh.behind_since = None;
                });
                write_frame(&mut stream, FRAME_ACK, f.gen, 0, &[])?;
            }
            FRAME_RECORD => {
                // Lag injection: a paused replica keeps records pending, so
                // staleness (time behind the shipped end) grows until the
                // router's bounded-staleness rule refuses replica reads.
                while health.snapshot().apply_paused {
                    health.update(|hh| {
                        if hh.behind_since.is_none() {
                            hh.behind_since = Some(Instant::now());
                        }
                    });
                    if stop.load(Ordering::Relaxed) {
                        return Ok(());
                    }
                    thread::sleep(Duration::from_millis(5));
                }
                let op = decode_wal_record(&f.payload)?;
                engine.apply_replicated(ReplicaBatch::Ops(vec![op]))?;
                health.update(|hh| {
                    hh.applied_gen = f.gen;
                    hh.applied_seq = f.seq;
                    hh.behind_since = None;
                });
                write_frame(&mut stream, FRAME_ACK, f.gen, f.seq, &[])?;
            }
            FRAME_HEARTBEAT => {
                health.update(|hh| {
                    if (f.gen, f.seq) > (hh.applied_gen, hh.applied_seq) {
                        if hh.behind_since.is_none() {
                            hh.behind_since = Some(Instant::now());
                        }
                    } else {
                        hh.behind_since = None;
                    }
                });
                let hh = health.snapshot();
                write_frame(&mut stream, FRAME_ACK, hh.applied_gen, hh.applied_seq, &[])?;
            }
            other => bail!("unexpected frame kind {other} from shipper"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_over_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            write_frame(&mut s, FRAME_RECORD, 3, 17, b"payload").unwrap();
            write_frame(&mut s, FRAME_HEARTBEAT, 3, 17, &[]).unwrap();
        });
        let (mut conn, _) = listener.accept().unwrap();
        conn.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
        let stop = AtomicBool::new(false);
        let f = read_frame(&mut conn, &stop).unwrap().unwrap();
        assert_eq!((f.kind, f.gen, f.seq), (FRAME_RECORD, 3, 17));
        assert_eq!(f.payload, b"payload");
        let hb = read_frame(&mut conn, &stop).unwrap().unwrap();
        assert_eq!((hb.kind, hb.gen, hb.seq), (FRAME_HEARTBEAT, 3, 17));
        assert!(hb.payload.is_empty());
        writer.join().unwrap();
        // Writer hung up: next read is a clean end-of-stream.
        assert!(read_frame(&mut conn, &stop).unwrap().is_none());
    }

    #[test]
    fn stop_flag_ends_read() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _idle = TcpStream::connect(addr).unwrap();
        let (mut conn, _) = listener.accept().unwrap();
        conn.set_read_timeout(Some(Duration::from_millis(5))).unwrap();
        let stop = AtomicBool::new(true);
        assert!(read_frame(&mut conn, &stop).unwrap().is_none());
    }
}
