//! The TweakLLM router — Figure 1 of the paper.
//!
//! Pipeline per query: embed → vector-DB top-k → threshold routing:
//! * similarity ≥ τ → **hit pathway**: Small LLM tweaks the cached response
//!   using (new query, cached query, cached response);
//! * similarity < τ → **miss pathway**: Big LLM generates fresh; the new
//!   (query, embedding, response) triple is inserted into the cache;
//! * optional exact-match fast path (§6.1): identical normalized text
//!   returns the cached response verbatim at zero model cost.

pub mod batcher;
pub mod engine;
pub mod scheduler;

pub use batcher::Batcher;
pub use engine::{Engine, EngineHandle, EngineStats, SnapshotReport};
pub use scheduler::{Job, JobKind, Scheduler};

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::cache::persist::{RecoveryReport, SnapshotState};
use crate::cache::{SemanticCache, WalOp};
use crate::config::{Config, FaultsConfig};
use crate::cost::{CostLedger, ModelRole, TokenUsage};
use crate::faults::CircuitBreaker;
use crate::llm::{BatchDecodeStats, LanguageModel, LlmResponse, LlmSession, TweakPrompt};
use crate::metrics::{Counters, LatencyRecorder};
use crate::runtime::{Embedder, Runtime, SamplingParams, TextEmbedder};
use crate::trace::{Stage, TraceBuilder, TraceHub, TraceTag};
use crate::util::ThreadPool;

/// Where a request's response is delivered (front-ends block on the
/// receiving end). One definition shared by the engine and the scheduler.
pub type ReplyTx = std::sync::mpsc::Sender<Result<RoutedResponse>>;

/// One event on a streaming reply channel: zero or more `Delta`s followed
/// by exactly one terminal `Done` or `Error`.
#[derive(Debug)]
pub enum StreamEvent {
    /// Text appended to the response. May be empty — a liveness probe the
    /// engine sends between tokens so a vanished receiver is noticed even
    /// when a fairness round produced no new text.
    Delta(String),
    /// Terminal success: the finished response. The sink streams the
    /// not-yet-sent remainder before this event, so the concatenation of
    /// all deltas is bit-identical to `RoutedResponse::text`.
    Done(RoutedResponse),
    /// Terminal failure: structured error, the stream is over.
    Error(String),
}

/// Transport behind a [`ReplySink`].
enum SinkChan {
    /// Classic one-shot reply channel (TCP line protocol): deltas are
    /// discarded, the response arrives once at EOS.
    Blocking(ReplyTx),
    /// Delta-streaming channel. `live = false` (the `EngineHandle::request`
    /// drain wrapper) suppresses mid-decode deltas so the blocking shape
    /// pays no per-token sends.
    Stream { tx: std::sync::mpsc::Sender<StreamEvent>, live: bool },
}

/// Where a request's reply — streamed or one-shot — is delivered. Owns the
/// streaming protocol invariants:
/// * `done()` first streams the un-sent remainder of the final text, so
///   concatenated deltas are bit-identical to the blocking response on
///   EVERY pathway (cached-text pathways replay entirely through this
///   remainder);
/// * a failed send latches `closed` — the client went away, and the
///   scheduler uses that to cancel the in-flight session;
/// * `has_emitted()` reports whether any text actually left the process:
///   the degradation ladder and miss retries must never swap or restart
///   response text mid-stream.
pub struct ReplySink {
    chan: SinkChan,
    /// Bytes of response text already streamed as deltas.
    sent: usize,
    /// A non-empty delta has been offered (TTFT latch; tracked for every
    /// sink shape so `first_token` lands on blocking traces too).
    seen: bool,
    /// A send failed: the receiver is gone.
    closed: bool,
}

impl ReplySink {
    /// One-shot reply channel (TCP line protocol, `Msg::Request` today).
    pub fn blocking(tx: ReplyTx) -> ReplySink {
        ReplySink { chan: SinkChan::Blocking(tx), sent: 0, seen: false, closed: false }
    }

    /// Live delta-streaming channel (`EngineHandle::request_streaming`).
    pub fn stream(tx: std::sync::mpsc::Sender<StreamEvent>) -> ReplySink {
        ReplySink { chan: SinkChan::Stream { tx, live: true }, sent: 0, seen: false, closed: false }
    }

    /// Streaming transport with deltas suppressed — the drain-to-EOS
    /// wrapper behind the blocking `EngineHandle::request`.
    pub fn buffered(tx: std::sync::mpsc::Sender<StreamEvent>) -> ReplySink {
        ReplySink {
            chan: SinkChan::Stream { tx, live: false },
            sent: 0,
            seen: false,
            closed: false,
        }
    }

    /// Discard-everything sink for direct blocking `Router` calls.
    pub fn ignore() -> ReplySink {
        ReplySink::blocking(std::sync::mpsc::channel().0)
    }

    /// Offer a delta. Returns `true` iff this is the first non-empty text
    /// of the reply — the caller's cue to stamp the TTFT trace event.
    /// Blocking/buffered sinks record the latch but send nothing.
    pub fn delta(&mut self, text: &str) -> bool {
        if text.is_empty() {
            return false;
        }
        let first = !self.seen;
        self.seen = true;
        if !self.closed {
            if let SinkChan::Stream { tx, live: true } = &self.chan {
                if tx.send(StreamEvent::Delta(text.to_string())).is_err() {
                    self.closed = true;
                } else {
                    self.sent += text.len();
                }
            }
        }
        first
    }

    /// Empty-delta liveness probe: notices a receiver that went away in a
    /// round that produced no text. No-op on non-live sinks.
    pub fn probe(&mut self) {
        if self.closed {
            return;
        }
        if let SinkChan::Stream { tx, live: true } = &self.chan {
            if tx.send(StreamEvent::Delta(String::new())).is_err() {
                self.closed = true;
            }
        }
    }

    /// Whether any response text has actually been streamed out.
    pub fn has_emitted(&self) -> bool {
        self.sent > 0
    }

    /// Whether the receiving end is known gone (a send failed).
    pub fn is_closed(&self) -> bool {
        self.closed
    }

    /// Terminal success: stream the not-yet-sent remainder of the final
    /// text (this is also how cached-text pathways replay as chunks), then
    /// deliver the full response. Consumes the sink — one reply per request.
    pub fn done(mut self, resp: RoutedResponse) {
        match &self.chan {
            SinkChan::Blocking(tx) => {
                let _ = tx.send(Ok(resp));
            }
            SinkChan::Stream { tx, live } => {
                if *live
                    && !self.closed
                    && self.sent < resp.text.len()
                    && resp.text.is_char_boundary(self.sent)
                {
                    let tail = resp.text[self.sent..].to_string();
                    if tx.send(StreamEvent::Delta(tail)).is_err() {
                        self.closed = true;
                    }
                }
                let _ = tx.send(StreamEvent::Done(resp));
            }
        }
    }

    /// Terminal failure: a structured error event ends the stream.
    /// Consumes the sink.
    pub fn fail(self, msg: &str) {
        match &self.chan {
            SinkChan::Blocking(tx) => {
                let _ = tx.send(Err(anyhow!("{msg}")));
            }
            SinkChan::Stream { tx, .. } => {
                let _ = tx.send(StreamEvent::Error(msg.to_string()));
            }
        }
    }
}

/// Which pathway served a request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Pathway {
    /// Exact text match — cached response returned verbatim, no model run.
    ExactHit,
    /// Semantic hit — Small LLM tweaked the cached response.
    TweakHit,
    /// Miss — Big LLM generated fresh (and the cache was updated).
    Miss,
    /// Degradation ladder: the tweak step was unavailable (error, timeout,
    /// deadline, or open breaker) and the raw cached response was served
    /// verbatim — the paper's premise that a cached answer beats no answer.
    DegradedHit,
}

/// Outcome of the route stage alone — the threshold decision with every
/// snapshot the generation will need, but no generation work yet. Splitting
/// route-decision from generation is what lets the engine enqueue the
/// resulting sessions on the decode scheduler instead of running each to
/// completion in routing order.
pub enum RouteDecision {
    /// Resolved immediately by the exact-match fast path (re-checked at
    /// route time: an earlier request in the same drain may have inserted
    /// this very query).
    Exact(RoutedResponse),
    /// Hit pathway: Small LLM tweak over a snapshot of the cache entry.
    Tweak(TweakJob),
    /// Miss pathway: Big LLM generation, cache insert at completion.
    Miss(MissJob),
}

/// Everything a tweak generation needs, snapshotted at route time (the
/// cache entry may be evicted while the session is in flight).
pub struct TweakJob {
    pub prompt: TweakPrompt,
    pub hit_id: usize,
    pub score: f32,
}

/// Everything a miss generation needs to complete (the embedding is kept
/// for the cache insert at EOS).
pub struct MissJob {
    pub query: String,
    pub embedding: Vec<f32>,
    /// Top-1 similarity that fell below the threshold (None: empty cache).
    pub top_score: Option<f32>,
    /// Insert the response into the cache at EOS. `false` on the embed
    /// degradation rung: the query was routed straight to the miss path
    /// with no (trustworthy) embedding, so there is nothing to index.
    pub insert: bool,
}

#[derive(Clone, Debug)]
pub struct RoutedResponse {
    pub text: String,
    pub pathway: Pathway,
    /// Top-1 cosine similarity (None when the cache was empty).
    pub similarity: Option<f32>,
    /// The cached query used as tweak basis (TweakHit/ExactHit).
    pub cached_query: Option<String>,
    /// The id of the cache entry used (hits) or inserted (misses).
    pub cache_entry: Option<usize>,
    pub usage: TokenUsage,
    pub total_micros: u128,
    /// Id of the request's span trace (0 when tracing is disabled) —
    /// surfaced to clients so a streamed reply can be joined to its trace.
    pub trace_id: u64,
}

/// How a request may use the cache. `Default` is the normal owner path;
/// the cluster front end (`cluster::ClusterServer`) selects the other two
/// when routing around a dead shard owner.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReadMode {
    /// Full pathway: hits served, misses generated and inserted.
    #[default]
    Default,
    /// Serve cache hits but never mutate entry state (a replica serving
    /// reads during an owner outage: the entry id space belongs to the
    /// owner's WAL, and a local insert would diverge from the stream).
    ReplicaRead,
    /// Skip the cache entirely — the bounded-staleness rule rejected the
    /// replica, so the request degrades to a fresh generation.
    Bypass,
}

/// A unit of replicated cache state applied on the engine thread: the
/// replica side of WAL shipping (see `cluster::ship`).
pub enum ReplicaBatch {
    /// Rebuild the cache, optionally restoring a shipped snapshot
    /// (`None`: the owner is still at generation 0, start empty).
    Bootstrap(Option<SnapshotState>),
    /// Shipped WAL records, in log order.
    Ops(Vec<WalOp>),
}

/// Per-backend circuit breakers (embedder, Small/tweak LLM, Big LLM).
/// Consulted only when `[faults] enabled`; an open breaker moves requests
/// down the degradation ladder without paying the backend's failure mode.
pub struct Breakers {
    pub embed: CircuitBreaker,
    pub small: CircuitBreaker,
    pub big: CircuitBreaker,
}

impl Breakers {
    fn new(cfg: &FaultsConfig) -> Breakers {
        Breakers {
            embed: CircuitBreaker::from_config(cfg),
            small: CircuitBreaker::from_config(cfg),
            big: CircuitBreaker::from_config(cfg),
        }
    }
}

/// Has `ms` milliseconds elapsed since `anchor`? `ms == 0` never expires
/// (the config convention for "unbounded").
pub(crate) fn deadline_expired(
    anchor: std::time::Instant,
    ms: u64,
    now: std::time::Instant,
) -> bool {
    ms > 0 && now.duration_since(anchor) >= std::time::Duration::from_millis(ms)
}

/// How a driven session ended (blocking path).
enum DriveEnd {
    Done(LlmResponse),
    /// The request's end-to-end deadline expired mid-generation.
    Deadline,
    /// The per-generation budget (tweak/generation timeout) expired.
    Budget,
    /// The streaming client went away mid-generation (a delta send failed).
    Cancelled,
}

/// Drive a session to EOS, checking the request deadline and the generation
/// budget between advances (`0` budgets never fire). Hung sessions — ones
/// that report work forever — end at whichever budget expires first. Token
/// deltas stream out through `sink` as each advance decodes them; the first
/// one stamps the trace's TTFT event.
fn drive_session(
    mut session: Box<dyn LlmSession>,
    deadline: (std::time::Instant, u64),
    budget: (std::time::Instant, u64),
    sink: &mut ReplySink,
    trace: &mut TraceBuilder,
) -> Result<DriveEnd> {
    loop {
        let now = std::time::Instant::now();
        if deadline_expired(deadline.0, deadline.1, now) {
            return Ok(DriveEnd::Deadline);
        }
        if deadline_expired(budget.0, budget.1, now) {
            return Ok(DriveEnd::Budget);
        }
        if sink.is_closed() {
            return Ok(DriveEnd::Cancelled);
        }
        let more = session.advance()?;
        if sink.delta(&session.take_delta()) {
            trace.first_token();
        }
        if !more {
            break;
        }
    }
    Ok(DriveEnd::Done(session.finish()?))
}

/// The router: owns the cache and both models. Single-threaded by design —
/// the engine wraps it in a dedicated thread (PJRT CPU serializes compute).
pub struct Router {
    pub config: Config,
    embedder: Box<dyn TextEmbedder>,
    cache: SemanticCache,
    big: Box<dyn LanguageModel>,
    small: Box<dyn LanguageModel>,
    pub ledger: CostLedger,
    pub latency: LatencyRecorder,
    pub counters: Counters,
    /// Completed per-request span traces (ring + slow list + histograms).
    pub traces: TraceHub,
    /// Per-backend circuit breakers ([`FaultsConfig`] tuning).
    pub breakers: Breakers,
    /// What crash recovery found on startup (None: persistence disabled).
    pub recovery: Option<RecoveryReport>,
    /// Shared scan workers for the sharded vector search (`index.shards`
    /// > 1). Kept here so `enable_persistence` can re-attach it to the
    /// replacement cache.
    scan_pool: Option<Arc<ThreadPool>>,
}

impl Router {
    /// Build from compiled artifacts (the production path). Decode runs
    /// device-resident when `config.device_resident` and the artifact set
    /// carries the packed-state executables (literal fallback otherwise).
    pub fn from_runtime(rt: &Runtime, config: Config) -> Result<Router> {
        let embedder: Box<dyn TextEmbedder> = Box::new(Embedder::new(rt)?);
        // Batched decode slots are claimed by the scheduler's concurrent
        // sessions; with the scheduler off (run-to-completion) the pool is
        // not built — it would only ever hold one live slot while paying
        // the full batch-width compute. Span gating stays capability-based
        // either way (see `with_decode_batch_opts`), so responses are
        // identical across the scheduler A/B for a fixed config + artifact
        // set, and pre-batched artifact dirs keep their span fusion.
        let slots = config.scheduler.decode_batch;
        let build_pool = config.scheduler.enabled;
        let big = Box::new(
            crate::llm::SubstrateLlm::new_with(
                rt,
                "big",
                SamplingParams {
                    temperature: config.big_llm.temperature,
                    top_k: config.big_llm.top_k,
                    max_new_tokens: config.big_llm.max_new_tokens,
                },
                config.seed,
                config.device_resident,
            )?
            .with_decode_batch_opts(slots, build_pool),
        );
        // The prefix cache is wired to the SMALL model only: tweak prompts
        // share the static template + cached-entry head across requests,
        // while big-model miss prompts are raw user queries that almost
        // never share a 64-token prefix — snapshots there would be pure
        // overhead.
        let small = Box::new(
            crate::llm::SubstrateLlm::new_with(
                rt,
                "small",
                SamplingParams {
                    temperature: config.small_llm.temperature,
                    top_k: config.small_llm.top_k,
                    max_new_tokens: config.small_llm.max_new_tokens,
                },
                config.seed,
                config.device_resident,
            )?
            .with_decode_batch_opts(slots, build_pool)
            .with_prefix_cache(config.prefix_cache_bytes),
        );
        let mut router = Self::with_models(embedder, big, small, config);
        router.enable_persistence()?;
        Ok(router)
    }

    /// Build with injected models (tests / baselines / quality-model eval).
    pub fn with_models(
        embedder: Box<dyn TextEmbedder>,
        big: Box<dyn LanguageModel>,
        small: Box<dyn LanguageModel>,
        config: Config,
    ) -> Router {
        let mut cache = SemanticCache::with_opts(
            embedder.out_dim(),
            config.index_kind(),
            config.index_opts(),
        )
        .with_eviction(config.eviction.policy, config.eviction.capacity)
        .with_exact_match(config.exact_match_fast_path);
        // The engine/router side owns the scan workers; the cache only
        // borrows them for fan-out, so one pool serves every cache this
        // router ever builds (including a persistence-recovered one).
        let scan_pool = if config.index.shards > 1 {
            Some(Arc::new(ThreadPool::new(config.index.shards)))
        } else {
            None
        };
        if let Some(pool) = &scan_pool {
            cache.set_pool(Arc::clone(pool), config.index.shards);
        }
        let traces = TraceHub::new(config.trace.clone());
        let breakers = Breakers::new(&config.faults);
        Router {
            config,
            embedder,
            cache,
            big,
            small,
            ledger: CostLedger::default(),
            latency: LatencyRecorder::new(),
            counters: Counters::default(),
            traces,
            breakers,
            recovery: None,
            scan_pool,
        }
    }

    /// Swap the ephemeral cache for a durable one recovered from
    /// `config.persist.data_dir` (snapshot + WAL replay). No-op when the
    /// `[persist]` section is disabled. Must run before serving traffic —
    /// it replaces the cache wholesale.
    pub fn enable_persistence(&mut self) -> Result<Option<RecoveryReport>> {
        if !self.config.persist.enabled() {
            return Ok(None);
        }
        let (mut cache, report) = SemanticCache::open_persistent_with(
            self.embedder.out_dim(),
            self.config.index_kind(),
            self.config.index_opts(),
            self.config.eviction.policy,
            self.config.eviction.capacity,
            self.config.exact_match_fast_path,
            &self.config.persist,
        )?;
        if let Some(pool) = &self.scan_pool {
            cache.set_pool(Arc::clone(pool), self.config.index.shards);
        }
        self.cache = cache;
        self.recovery = Some(report.clone());
        Ok(Some(report))
    }

    /// Snapshot the cache now (graceful shutdown / the admin verb).
    /// Returns the new persistence generation; `None` when ephemeral.
    pub fn snapshot(&mut self) -> Result<Option<u64>> {
        self.cache.compact_now()
    }

    /// Replica side of WAL shipping: install a bootstrap snapshot or apply
    /// a batch of shipped records through the recovery path. A bootstrap
    /// rebuilds the cache wholesale (same construction as `with_models`),
    /// so a re-bootstrap after the shipper fell behind starts clean. The
    /// replica cache stays ephemeral — every applied record already lives
    /// in the owner's WAL, and journaling it again here would double-write
    /// the log on promotion.
    pub fn apply_replicated(&mut self, batch: ReplicaBatch) -> Result<()> {
        match batch {
            ReplicaBatch::Bootstrap(state) => {
                let mut cache = SemanticCache::with_opts(
                    self.embedder.out_dim(),
                    self.config.index_kind(),
                    self.config.index_opts(),
                )
                .with_eviction(self.config.eviction.policy, self.config.eviction.capacity)
                .with_exact_match(self.config.exact_match_fast_path);
                if let Some(pool) = &self.scan_pool {
                    cache.set_pool(Arc::clone(pool), self.config.index.shards);
                }
                if let Some(state) = state {
                    cache.restore_replicated(state)?;
                }
                self.cache = cache;
                Ok(())
            }
            ReplicaBatch::Ops(ops) => {
                for op in ops {
                    self.cache.apply_replicated_op(op)?;
                }
                Ok(())
            }
        }
    }

    pub fn cache(&self) -> &SemanticCache {
        &self.cache
    }

    pub fn embedder(&self) -> &dyn TextEmbedder {
        self.embedder.as_ref()
    }

    /// Combined batched-decode occupancy counters of both models' slot
    /// pools (`None` when neither model decodes batched).
    pub fn batch_stats(&self) -> Option<BatchDecodeStats> {
        BatchDecodeStats::merge(self.big.batch_stats(), self.small.batch_stats())
    }

    /// Combined KV-prefix-cache counters of both models (`None` when
    /// neither has prefix reuse enabled).
    pub fn prefix_stats(&self) -> Option<crate::runtime::PrefixCacheStats> {
        crate::runtime::PrefixCacheStats::merge(
            self.big.prefix_stats(),
            self.small.prefix_stats(),
        )
    }

    /// Pre-populate the cache (dataset warm-up in the eval protocols).
    pub fn warm(&mut self, pairs: &[(String, String)]) -> Result<()> {
        let queries: Vec<&str> = pairs.iter().map(|(q, _)| q.as_str()).collect();
        let embeddings = self.embedder.embed_batch(&queries)?;
        for ((q, r), e) in pairs.iter().zip(embeddings) {
            self.cache.insert(q, r, e);
        }
        Ok(())
    }

    /// Route one query through the Figure-1 pipeline (one-shot reply).
    pub fn handle(&mut self, query: &str) -> Result<RoutedResponse> {
        self.handle_streaming(query, &mut ReplySink::ignore())
    }

    /// [`Self::handle`] with a delta sink: generated text streams out as it
    /// decodes. The router only emits deltas — the terminal `done`/`fail`
    /// event stays with the caller, who owns the sink.
    pub fn handle_streaming(
        &mut self,
        query: &str,
        sink: &mut ReplySink,
    ) -> Result<RoutedResponse> {
        let t_start = std::time::Instant::now();
        let mut trace = self.traces.begin(query, t_start);

        // 0) exact-match fast path (§6.1)
        if let Some(resp) = self.try_exact(query, t_start, &mut trace) {
            return Ok(resp);
        }

        // 1) embed — embedder failure (or an open embed breaker) drops to
        // the ladder's bypass rung: straight to the miss path, no insert.
        let faults_on = self.config.faults.enabled;
        if !faults_on || self.breakers.embed.allow(std::time::Instant::now()) {
            let t = std::time::Instant::now();
            match self.embedder.embed(query) {
                Ok(embedding) => {
                    if faults_on {
                        self.breakers.embed.record_success(std::time::Instant::now());
                    }
                    self.latency.record_duration("embed", t.elapsed());
                    trace.span_from(Stage::Embed, t);
                    return self.handle_embedded_streaming(
                        query, embedding, t_start, sink, &mut trace,
                    );
                }
                Err(e) => {
                    if !faults_on {
                        return Err(e);
                    }
                    self.breakers.embed.record_failure(std::time::Instant::now());
                }
            }
        }
        let job = self.miss_bypass_job(query);
        self.run_miss_blocking(job, t_start, sink, &mut trace)
    }

    /// Exact-match fast path; `None` when disabled or no exact entry.
    /// On a hit the trace is finished here (tagged `exact_hit`).
    pub fn try_exact(
        &mut self,
        query: &str,
        t_start: std::time::Instant,
        trace: &mut TraceBuilder,
    ) -> Option<RoutedResponse> {
        if !self.config.exact_match_fast_path {
            return None;
        }
        let t = std::time::Instant::now();
        let (id, entry) = self.cache.lookup_exact(query)?;
        let text = entry.response_text.clone();
        let cached_query = entry.query_text.clone();
        self.cache.touch(id);
        trace.span_from_value(Stage::Route, t, 1.0);
        trace.set_similarity(1.0);
        self.ledger.record_free();
        self.counters.inc("requests");
        self.counters.inc("exact_hits");
        trace.span_since_last(Stage::Reply);
        // Sample elapsed once, after the reply span, so every span nests
        // within [0, total_us] and the recorded latency and the reported
        // total_micros are the same number.
        let total_micros = t_start.elapsed().as_micros();
        self.latency.record("total", total_micros as f64);
        let trace_id = trace.id();
        self.traces.finish(
            trace,
            TraceTag::ExactHit,
            total_micros as u64,
            self.config.similarity_threshold,
        );
        Some(RoutedResponse {
            text,
            pathway: Pathway::ExactHit,
            similarity: Some(1.0),
            cached_query: Some(cached_query),
            cache_entry: Some(id),
            usage: TokenUsage::default(),
            total_micros,
            trace_id,
        })
    }

    /// Route a query whose embedding was already computed (batched front).
    /// Blocking shape: route → begin session → drive to EOS → complete.
    /// Exactly the staged pipeline the scheduler runs, collapsed in place —
    /// so a request costs the same work whether the scheduler is on or off.
    pub fn handle_embedded(
        &mut self,
        query: &str,
        embedding: Vec<f32>,
        t_start: std::time::Instant,
        trace: &mut TraceBuilder,
    ) -> Result<RoutedResponse> {
        self.handle_embedded_streaming(query, embedding, t_start, &mut ReplySink::ignore(), trace)
    }

    /// [`Self::handle_embedded`] with a delta sink — the scheduler-off
    /// streaming path. Deltas flow out per advance; terminal events stay
    /// with the caller.
    pub fn handle_embedded_streaming(
        &mut self,
        query: &str,
        embedding: Vec<f32>,
        t_start: std::time::Instant,
        sink: &mut ReplySink,
        trace: &mut TraceBuilder,
    ) -> Result<RoutedResponse> {
        match self.route(query, embedding, t_start, trace) {
            RouteDecision::Exact(resp) => Ok(resp),
            RouteDecision::Tweak(job) => self.run_tweak_blocking(job, t_start, sink, trace),
            RouteDecision::Miss(job) => self.run_miss_blocking(job, t_start, sink, trace),
        }
    }

    /// Blocking hit pathway with the degradation ladder: a tweak that
    /// errors, overruns its budget, outlives the request deadline, or is
    /// rejected by an open breaker degrades to the raw cached response.
    /// With `[faults]` disabled this is exactly the old fail-through path.
    ///
    /// Mid-stream guard: once deltas have left the process the response
    /// text is committed — degrading would swap it under the client — so a
    /// post-emission deadline/budget/error fails the request with a
    /// structured error instead of degrading.
    fn run_tweak_blocking(
        &mut self,
        job: TweakJob,
        t_start: std::time::Instant,
        sink: &mut ReplySink,
        trace: &mut TraceBuilder,
    ) -> Result<RoutedResponse> {
        let f = self.config.faults;
        if f.enabled && !self.breakers.small.allow(std::time::Instant::now()) {
            return Ok(self.complete_degraded(&job, t_start, trace));
        }
        let (dl, bg) = if f.enabled { (f.request_deadline_ms, f.tweak_timeout_ms) } else { (0, 0) };
        let t = std::time::Instant::now();
        let outcome = match self.begin_tweak_session(&job) {
            Ok(session) => {
                let decode_started = std::time::Instant::now();
                match drive_session(session, (t_start, dl), (t, bg), sink, trace) {
                    Ok(DriveEnd::Done(resp)) => {
                        let recomputed =
                            resp.usage.input_tokens.saturating_sub(resp.restored_tokens);
                        trace.span_at(Stage::Prefill, t, decode_started, recomputed as f32);
                        trace.span_at(
                            Stage::Decode,
                            decode_started,
                            std::time::Instant::now(),
                            resp.decode_micros as f32,
                        );
                        trace.set_compute(resp.prefill_micros, resp.decode_micros);
                        trace.set_prefill_tokens(resp.usage.input_tokens, recomputed);
                        Ok(DriveEnd::Done(resp))
                    }
                    other => other,
                }
            }
            Err(e) => Err(e),
        };
        match outcome {
            Ok(DriveEnd::Done(resp)) => {
                if f.enabled {
                    self.breakers.small.record_success(std::time::Instant::now());
                }
                Ok(self.complete_tweak(&job, resp, t_start, t.elapsed().as_micros(), trace))
            }
            Ok(DriveEnd::Cancelled) => {
                self.finish_failed("cancelled", false, t_start, trace);
                Err(anyhow!("client disconnected mid-generation"))
            }
            // Deadline expiry is the request running out of time, not
            // (necessarily) backend sickness: degrade, no breaker record.
            Ok(DriveEnd::Deadline) => {
                if sink.has_emitted() {
                    self.finish_failed("shed", false, t_start, trace);
                    return Err(anyhow!("request deadline exceeded mid-stream"));
                }
                Ok(self.complete_degraded(&job, t_start, trace))
            }
            Ok(DriveEnd::Budget) => {
                self.breakers.small.record_failure(std::time::Instant::now());
                if sink.has_emitted() {
                    self.finish_failed("failed", false, t_start, trace);
                    return Err(anyhow!("tweak timeout ({bg} ms) mid-stream"));
                }
                Ok(self.complete_degraded(&job, t_start, trace))
            }
            Err(e) => {
                if !f.enabled {
                    return Err(e);
                }
                self.breakers.small.record_failure(std::time::Instant::now());
                if sink.has_emitted() {
                    self.finish_failed("failed", false, t_start, trace);
                    return Err(anyhow!("tweak failed mid-stream: {e:#}"));
                }
                Ok(self.complete_degraded(&job, t_start, trace))
            }
        }
    }

    /// Blocking miss pathway with bounded retry-and-backoff. Retries
    /// re-begin the session; per-request RNG substreams make a successful
    /// retry bit-identical to a first-try success. Exhausted retries (or an
    /// open Big-LLM breaker, or deadline expiry) return a structured error
    /// after accounting the failure (`finish_failed`).
    /// Mid-stream guard: a retry restarts the token stream from scratch,
    /// which would duplicate text already streamed to the client — so once
    /// deltas have been emitted, the first failure is terminal.
    fn run_miss_blocking(
        &mut self,
        job: MissJob,
        t_start: std::time::Instant,
        sink: &mut ReplySink,
        trace: &mut TraceBuilder,
    ) -> Result<RoutedResponse> {
        let f = self.config.faults;
        let attempts = if f.enabled { f.miss_retries + 1 } else { 1 };
        let (dl, bg) =
            if f.enabled { (f.request_deadline_ms, f.generation_timeout_ms) } else { (0, 0) };
        let mut last_err: Option<anyhow::Error> = None;
        let mut done: Option<(LlmResponse, u128)> = None;
        for attempt in 0..attempts {
            let now = std::time::Instant::now();
            if deadline_expired(t_start, dl, now) {
                self.finish_failed("shed", false, t_start, trace);
                return Err(anyhow!("request deadline exceeded ({dl} ms)"));
            }
            if f.enabled && !self.breakers.big.allow(now) {
                self.finish_failed("failed", false, t_start, trace);
                return Err(anyhow!("big LLM unavailable (circuit breaker open)"));
            }
            if attempt > 0 {
                std::thread::sleep(std::time::Duration::from_millis(
                    f.retry_backoff_ms.saturating_mul(attempt as u64),
                ));
            }
            let t = std::time::Instant::now();
            let drive = match self.begin_miss_session(&job) {
                Ok(session) => {
                    let decode_started = std::time::Instant::now();
                    match drive_session(session, (t_start, dl), (t, bg), sink, trace) {
                        Ok(DriveEnd::Done(resp)) => {
                            let recomputed =
                                resp.usage.input_tokens.saturating_sub(resp.restored_tokens);
                            trace.span_at(Stage::Prefill, t, decode_started, recomputed as f32);
                            trace.span_at(
                                Stage::Decode,
                                decode_started,
                                std::time::Instant::now(),
                                resp.decode_micros as f32,
                            );
                            trace.set_compute(resp.prefill_micros, resp.decode_micros);
                            trace.set_prefill_tokens(resp.usage.input_tokens, recomputed);
                            Ok(DriveEnd::Done(resp))
                        }
                        other => other,
                    }
                }
                Err(e) => Err(e),
            };
            match drive {
                Ok(DriveEnd::Done(resp)) => {
                    if f.enabled {
                        self.breakers.big.record_success(std::time::Instant::now());
                    }
                    done = Some((resp, t.elapsed().as_micros()));
                    break;
                }
                Ok(DriveEnd::Deadline) => {
                    self.finish_failed("shed", false, t_start, trace);
                    return Err(anyhow!("request deadline exceeded mid-generation"));
                }
                Ok(DriveEnd::Cancelled) => {
                    self.finish_failed("cancelled", false, t_start, trace);
                    return Err(anyhow!("client disconnected mid-generation"));
                }
                Ok(DriveEnd::Budget) => {
                    self.breakers.big.record_failure(std::time::Instant::now());
                    last_err = Some(anyhow!("generation timeout ({bg} ms)"));
                    if sink.has_emitted() {
                        break;
                    }
                }
                Err(e) => {
                    if !f.enabled {
                        return Err(e);
                    }
                    self.breakers.big.record_failure(std::time::Instant::now());
                    last_err = Some(e);
                    if sink.has_emitted() {
                        break;
                    }
                }
            }
        }
        match done {
            Some((resp, gen_micros)) => {
                Ok(self.complete_miss(job, resp, t_start, gen_micros, trace))
            }
            None => {
                self.finish_failed("failed", false, t_start, trace);
                let e = last_err.expect("no success implies a recorded error");
                Err(anyhow!(
                    "miss generation failed after {attempts} attempt{}: {e:#}",
                    if attempts == 1 { "" } else { "s" }
                ))
            }
        }
    }

    /// Stage 1: the threshold decision (Figure 1) with no generation work.
    /// Everything the generation needs later is snapshotted into the job.
    pub fn route(
        &mut self,
        query: &str,
        embedding: Vec<f32>,
        t_start: std::time::Instant,
        trace: &mut TraceBuilder,
    ) -> RouteDecision {
        // Exact-match re-check: the batched front runs `try_exact` before
        // embedding, but an identical query routed earlier in this same
        // drain may have inserted its response since.
        if let Some(resp) = self.try_exact(query, t_start, trace) {
            return RouteDecision::Exact(resp);
        }
        self.counters.inc("requests");
        let t = std::time::Instant::now();
        let hits = self.cache.search(&embedding, self.config.top_k);
        self.latency.record_duration("search", t.elapsed());
        trace.span_from(Stage::Search, t);
        let t_route = std::time::Instant::now();
        let top = hits.first().copied();
        let threshold = self.config.similarity_threshold;
        let decision = match top {
            Some(hit) if hit.score >= threshold => {
                let entry = self
                    .cache
                    .entry(hit.id)
                    .expect("search returned tombstoned id");
                RouteDecision::Tweak(TweakJob {
                    prompt: TweakPrompt {
                        new_query: query.to_string(),
                        cached_query: entry.query_text.clone(),
                        cached_response: entry.response_text.clone(),
                    },
                    hit_id: hit.id,
                    score: hit.score,
                })
            }
            top => RouteDecision::Miss(MissJob {
                query: query.to_string(),
                embedding,
                top_score: top.map(|h| h.score),
                insert: true,
            }),
        };
        let score = match &decision {
            RouteDecision::Tweak(j) => j.score,
            RouteDecision::Miss(j) => j.top_score.unwrap_or(f32::NAN),
            RouteDecision::Exact(_) => unreachable!("exact resolved above"),
        };
        trace.span_from_value(Stage::Route, t_route, score);
        if score.is_finite() {
            trace.set_similarity(score);
        }
        decision
    }

    /// Stage 2 (hit pathway): start the Small-LLM tweak session.
    pub fn begin_tweak_session(&mut self, job: &TweakJob) -> Result<Box<dyn LlmSession>> {
        self.small.begin_tweak(&job.prompt)
    }

    /// Stage 2 (miss pathway): start the Big-LLM generation session.
    pub fn begin_miss_session(&mut self, job: &MissJob) -> Result<Box<dyn LlmSession>> {
        self.big.begin_respond(&job.query)
    }

    /// Stage 3 (hit pathway): account a finished tweak and build the reply.
    /// `gen_micros` is the session's begin→EOS wall time — under the
    /// scheduler that is occupancy (interleaved sessions overlap), not
    /// exclusive compute.
    pub fn complete_tweak(
        &mut self,
        job: &TweakJob,
        resp: LlmResponse,
        t_start: std::time::Instant,
        gen_micros: u128,
        trace: &mut TraceBuilder,
    ) -> RoutedResponse {
        self.latency.record("tweak_generate", gen_micros as f64);
        self.cache.touch(job.hit_id);
        self.ledger.record(ModelRole::Small, resp.usage);
        self.counters.inc("tweak_hits");
        // Reply span before the total sample: spans nest in [0, total_us].
        trace.span_since_last(Stage::Reply);
        let total_micros = t_start.elapsed().as_micros();
        self.latency.record("total", total_micros as f64);
        let trace_id = trace.id();
        self.traces.finish(
            trace,
            TraceTag::TweakHit,
            total_micros as u64,
            self.config.similarity_threshold,
        );
        RoutedResponse {
            text: resp.text,
            pathway: Pathway::TweakHit,
            similarity: Some(job.score),
            cached_query: Some(job.prompt.cached_query.clone()),
            cache_entry: Some(job.hit_id),
            usage: resp.usage,
            total_micros,
            trace_id,
        }
    }

    /// Stage 3 (miss pathway): cache insert + accounting at session EOS.
    pub fn complete_miss(
        &mut self,
        job: MissJob,
        resp: LlmResponse,
        t_start: std::time::Instant,
        gen_micros: u128,
        trace: &mut TraceBuilder,
    ) -> RoutedResponse {
        self.latency.record("big_generate", gen_micros as f64);
        let id = if job.insert {
            let t = std::time::Instant::now();
            let id = self.cache.insert(&job.query, &resp.text, job.embedding);
            self.latency.record_duration("cache_insert", t.elapsed());
            trace.span_from(Stage::CacheInsert, t);
            Some(id)
        } else {
            // Embed-bypass rung: no embedding to index, nothing inserted.
            None
        };
        self.ledger.record(ModelRole::Big, resp.usage);
        self.counters.inc("misses");
        // Reply span before the total sample: spans nest in [0, total_us].
        trace.span_since_last(Stage::Reply);
        let total_micros = t_start.elapsed().as_micros();
        self.latency.record("total", total_micros as f64);
        let trace_id = trace.id();
        self.traces.finish(
            trace,
            TraceTag::Miss,
            total_micros as u64,
            self.config.similarity_threshold,
        );
        RoutedResponse {
            text: resp.text,
            pathway: Pathway::Miss,
            similarity: job.top_score,
            cached_query: None,
            cache_entry: id,
            usage: resp.usage,
            total_micros,
            trace_id,
        }
    }

    /// Degradation-ladder terminal for the hit pathway: serve the raw
    /// cached response verbatim (no model run) because the tweak step was
    /// unavailable. Accounted as its own `degraded_hit` pathway in
    /// counters, latency, and traces so dashboards see degradation happen.
    pub fn complete_degraded(
        &mut self,
        job: &TweakJob,
        t_start: std::time::Instant,
        trace: &mut TraceBuilder,
    ) -> RoutedResponse {
        self.cache.touch(job.hit_id);
        self.ledger.record_free();
        self.counters.inc("degraded_hits");
        // Reply span before the total sample: spans nest in [0, total_us].
        trace.span_since_last(Stage::Reply);
        let total_micros = t_start.elapsed().as_micros();
        self.latency.record("total", total_micros as f64);
        let trace_id = trace.id();
        self.traces.finish(
            trace,
            TraceTag::DegradedHit,
            total_micros as u64,
            self.config.similarity_threshold,
        );
        RoutedResponse {
            text: job.prompt.cached_response.clone(),
            pathway: Pathway::DegradedHit,
            similarity: Some(job.score),
            cached_query: Some(job.prompt.cached_query.clone()),
            cache_entry: Some(job.hit_id),
            usage: TokenUsage::default(),
            total_micros,
            trace_id,
        }
    }

    /// Account a request answered with a structured error — deadline shed
    /// (`kind = "shed"`) or exhausted generation attempts (`"failed"`). The
    /// single-recording invariant holds for failures too: one `total`
    /// latency sample and one finished trace (tag `failed`) per request.
    /// `count_request` covers requests shed before ever reaching `route()`
    /// (which is where "requests" is normally counted). The caller sends
    /// the error on the reply channel.
    pub fn finish_failed(
        &mut self,
        kind: &'static str,
        count_request: bool,
        enqueued: std::time::Instant,
        trace: &mut TraceBuilder,
    ) {
        if count_request {
            self.counters.inc("requests");
        }
        self.counters.inc(kind);
        trace.span_since_last(Stage::Reply);
        let total_micros = enqueued.elapsed().as_micros();
        self.latency.record("total", total_micros as f64);
        self.traces.finish(
            trace,
            TraceTag::Failed,
            total_micros as u64,
            self.config.similarity_threshold,
        );
    }

    /// Degradation ladder, embed rung: build a miss job with no embedding
    /// (embedder down or its breaker open). Counted as a request here — the
    /// query never reaches `route()` — and served without a cache insert.
    pub fn miss_bypass_job(&mut self, query: &str) -> MissJob {
        self.counters.inc("requests");
        self.counters.inc("embed_bypasses");
        MissJob {
            query: query.to_string(),
            embedding: Vec::new(),
            top_score: None,
            insert: false,
        }
    }

    /// Account a request served by attaching to an identical in-flight miss
    /// (duplicate coalescing): zero model cost, one shared generation. With
    /// the exact fast path on this is reported as an exact hit — it is
    /// exactly what re-checking after the leader's insert would yield; with
    /// it off (paper config) it stays a miss, served free.
    pub fn complete_follower(
        &mut self,
        leader_query: &str,
        leader: &RoutedResponse,
        enqueued: std::time::Instant,
        trace: &mut TraceBuilder,
    ) -> RoutedResponse {
        // NB: "requests" was already counted when this request was routed;
        // only the pathway partition is settled here. (Coalescing itself is
        // counted by the scheduler, at attach time.)
        self.ledger.record_free();
        // The follower *used* the freshly-inserted entry: feed LRU/LFU just
        // like the exact fast path would have.
        if let Some(id) = leader.cache_entry {
            self.cache.touch(id);
        }
        let pathway = if self.config.exact_match_fast_path {
            self.counters.inc("exact_hits");
            Pathway::ExactHit
        } else {
            self.counters.inc("misses");
            Pathway::Miss
        };
        // The follower's wait for the leader's generation is its queue-wait.
        trace.span_since_last(Stage::QueueWait);
        trace.span_since_last(Stage::Reply);
        let total_micros = enqueued.elapsed().as_micros();
        self.latency.record("total", total_micros as f64);
        let trace_id = trace.id();
        self.traces.finish(
            trace,
            TraceTag::Coalesced,
            total_micros as u64,
            self.config.similarity_threshold,
        );
        RoutedResponse {
            text: leader.text.clone(),
            pathway,
            similarity: Some(1.0),
            cached_query: Some(leader_query.to_string()),
            cache_entry: leader.cache_entry,
            usage: TokenUsage::default(),
            total_micros,
            trace_id,
        }
    }

    /// Hit rate over the lifetime of this router (tweak + exact hits).
    pub fn hit_rate(&self) -> f64 {
        let hits = self.counters.get("tweak_hits") + self.counters.get("exact_hits");
        let total = self.counters.get("requests");
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    // Router unit tests use mock models + a mock embedder; they live in
    // rust/tests/router.rs because Embedder requires compiled artifacts.
    // Here we test the pure pieces.
    use super::*;

    #[test]
    fn pathway_eq() {
        assert_ne!(Pathway::ExactHit, Pathway::Miss);
        assert_eq!(Pathway::TweakHit, Pathway::TweakHit);
    }

    fn resp(text: &str) -> RoutedResponse {
        RoutedResponse {
            text: text.to_string(),
            pathway: Pathway::Miss,
            similarity: None,
            cached_query: None,
            cache_entry: None,
            usage: TokenUsage::default(),
            total_micros: 0,
            trace_id: 0,
        }
    }

    /// Core identity invariant: concat(deltas) == Done.text, whether the
    /// deltas were streamed during decode or replayed by `done()`.
    #[test]
    fn sink_done_streams_the_unsent_remainder() {
        // Nothing streamed: the whole text arrives as one pre-Done delta.
        let (tx, rx) = std::sync::mpsc::channel();
        ReplySink::stream(tx).done(resp("hello world"));
        let mut got = String::new();
        let mut done_text = None;
        for ev in rx.iter() {
            match ev {
                StreamEvent::Delta(d) => got.push_str(&d),
                StreamEvent::Done(r) => done_text = Some(r.text),
                StreamEvent::Error(e) => panic!("unexpected error: {e}"),
            }
        }
        assert_eq!(got, "hello world");
        assert_eq!(done_text.as_deref(), Some("hello world"));

        // Partially streamed: only the tail is replayed.
        let (tx, rx) = std::sync::mpsc::channel();
        let mut sink = ReplySink::stream(tx);
        assert!(sink.delta("hello "), "first non-empty delta is the TTFT cue");
        assert!(!sink.delta("wor"), "later deltas are not");
        assert!(sink.has_emitted());
        sink.done(resp("hello world"));
        let mut got = String::new();
        for ev in rx.iter() {
            if let StreamEvent::Delta(d) = ev {
                got.push_str(&d);
            }
        }
        assert_eq!(got, "hello world");
    }

    #[test]
    fn sink_latches_closed_when_receiver_drops() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut sink = ReplySink::stream(tx);
        assert!(sink.delta("a"));
        drop(rx);
        sink.probe();
        assert!(sink.is_closed(), "probe must notice the dropped receiver");
        assert!(!sink.delta("b"), "deltas after close are swallowed");
    }

    #[test]
    fn blocking_and_buffered_sinks_never_stream() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut sink = ReplySink::blocking(tx);
        assert!(sink.delta("chunk"), "TTFT latch fires even when not streaming");
        assert!(!sink.has_emitted(), "nothing left the process");
        sink.done(resp("full text"));
        assert_eq!(rx.recv().unwrap().unwrap().text, "full text");

        let (tx, rx) = std::sync::mpsc::channel();
        let mut sink = ReplySink::buffered(tx);
        sink.delta("chunk");
        assert!(!sink.has_emitted());
        sink.done(resp("full text"));
        match rx.recv().unwrap() {
            StreamEvent::Done(r) => assert_eq!(r.text, "full text"),
            other => panic!("buffered sink must skip straight to Done, got {other:?}"),
        }
    }

    #[test]
    fn sink_fail_maps_to_the_transport() {
        let (tx, rx) = std::sync::mpsc::channel();
        ReplySink::stream(tx).fail("boom");
        match rx.recv().unwrap() {
            StreamEvent::Error(e) => assert_eq!(e, "boom"),
            other => panic!("expected Error, got {other:?}"),
        }
        let (tx, rx) = std::sync::mpsc::channel();
        ReplySink::blocking(tx).fail("boom");
        assert_eq!(format!("{:#}", rx.recv().unwrap().unwrap_err()), "boom");
    }
}
