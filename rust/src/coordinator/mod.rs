//! The TweakLLM router — Figure 1 of the paper.
//!
//! Pipeline per query: embed → vector-DB top-k → threshold routing:
//! * similarity ≥ τ → **hit pathway**: Small LLM tweaks the cached response
//!   using (new query, cached query, cached response);
//! * similarity < τ → **miss pathway**: Big LLM generates fresh; the new
//!   (query, embedding, response) triple is inserted into the cache;
//! * optional exact-match fast path (§6.1): identical normalized text
//!   returns the cached response verbatim at zero model cost.

pub mod batcher;
pub mod engine;
pub mod scheduler;

pub use batcher::Batcher;
pub use engine::{Engine, EngineHandle, EngineStats, SnapshotReport};
pub use scheduler::{Job, JobKind, Scheduler};

use std::sync::Arc;

use anyhow::Result;

use crate::cache::persist::RecoveryReport;
use crate::cache::SemanticCache;
use crate::config::Config;
use crate::cost::{CostLedger, ModelRole, TokenUsage};
use crate::llm::{BatchDecodeStats, LanguageModel, LlmResponse, LlmSession, TweakPrompt};
use crate::metrics::{Counters, LatencyRecorder};
use crate::runtime::{Embedder, Runtime, SamplingParams, TextEmbedder};
use crate::trace::{Stage, TraceBuilder, TraceHub, TraceTag};
use crate::util::ThreadPool;

/// Where a request's response is delivered (front-ends block on the
/// receiving end). One definition shared by the engine and the scheduler.
pub type ReplyTx = std::sync::mpsc::Sender<Result<RoutedResponse>>;

/// Which pathway served a request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Pathway {
    /// Exact text match — cached response returned verbatim, no model run.
    ExactHit,
    /// Semantic hit — Small LLM tweaked the cached response.
    TweakHit,
    /// Miss — Big LLM generated fresh (and the cache was updated).
    Miss,
}

/// Outcome of the route stage alone — the threshold decision with every
/// snapshot the generation will need, but no generation work yet. Splitting
/// route-decision from generation is what lets the engine enqueue the
/// resulting sessions on the decode scheduler instead of running each to
/// completion in routing order.
pub enum RouteDecision {
    /// Resolved immediately by the exact-match fast path (re-checked at
    /// route time: an earlier request in the same drain may have inserted
    /// this very query).
    Exact(RoutedResponse),
    /// Hit pathway: Small LLM tweak over a snapshot of the cache entry.
    Tweak(TweakJob),
    /// Miss pathway: Big LLM generation, cache insert at completion.
    Miss(MissJob),
}

/// Everything a tweak generation needs, snapshotted at route time (the
/// cache entry may be evicted while the session is in flight).
pub struct TweakJob {
    pub prompt: TweakPrompt,
    pub hit_id: usize,
    pub score: f32,
}

/// Everything a miss generation needs to complete (the embedding is kept
/// for the cache insert at EOS).
pub struct MissJob {
    pub query: String,
    pub embedding: Vec<f32>,
    /// Top-1 similarity that fell below the threshold (None: empty cache).
    pub top_score: Option<f32>,
}

#[derive(Clone, Debug)]
pub struct RoutedResponse {
    pub text: String,
    pub pathway: Pathway,
    /// Top-1 cosine similarity (None when the cache was empty).
    pub similarity: Option<f32>,
    /// The cached query used as tweak basis (TweakHit/ExactHit).
    pub cached_query: Option<String>,
    /// The id of the cache entry used (hits) or inserted (misses).
    pub cache_entry: Option<usize>,
    pub usage: TokenUsage,
    pub total_micros: u128,
}

/// The router: owns the cache and both models. Single-threaded by design —
/// the engine wraps it in a dedicated thread (PJRT CPU serializes compute).
pub struct Router {
    pub config: Config,
    embedder: Box<dyn TextEmbedder>,
    cache: SemanticCache,
    big: Box<dyn LanguageModel>,
    small: Box<dyn LanguageModel>,
    pub ledger: CostLedger,
    pub latency: LatencyRecorder,
    pub counters: Counters,
    /// Completed per-request span traces (ring + slow list + histograms).
    pub traces: TraceHub,
    /// What crash recovery found on startup (None: persistence disabled).
    pub recovery: Option<RecoveryReport>,
    /// Shared scan workers for the sharded vector search (`index.shards`
    /// > 1). Kept here so `enable_persistence` can re-attach it to the
    /// replacement cache.
    scan_pool: Option<Arc<ThreadPool>>,
}

impl Router {
    /// Build from compiled artifacts (the production path). Decode runs
    /// device-resident when `config.device_resident` and the artifact set
    /// carries the packed-state executables (literal fallback otherwise).
    pub fn from_runtime(rt: &Runtime, config: Config) -> Result<Router> {
        let embedder: Box<dyn TextEmbedder> = Box::new(Embedder::new(rt)?);
        // Batched decode slots are claimed by the scheduler's concurrent
        // sessions; with the scheduler off (run-to-completion) the pool is
        // not built — it would only ever hold one live slot while paying
        // the full batch-width compute. Span gating stays capability-based
        // either way (see `with_decode_batch_opts`), so responses are
        // identical across the scheduler A/B for a fixed config + artifact
        // set, and pre-batched artifact dirs keep their span fusion.
        let slots = config.scheduler.decode_batch;
        let build_pool = config.scheduler.enabled;
        let big = Box::new(
            crate::llm::SubstrateLlm::new_with(
                rt,
                "big",
                SamplingParams {
                    temperature: config.big_llm.temperature,
                    top_k: config.big_llm.top_k,
                    max_new_tokens: config.big_llm.max_new_tokens,
                },
                config.seed,
                config.device_resident,
            )?
            .with_decode_batch_opts(slots, build_pool),
        );
        let small = Box::new(
            crate::llm::SubstrateLlm::new_with(
                rt,
                "small",
                SamplingParams {
                    temperature: config.small_llm.temperature,
                    top_k: config.small_llm.top_k,
                    max_new_tokens: config.small_llm.max_new_tokens,
                },
                config.seed,
                config.device_resident,
            )?
            .with_decode_batch_opts(slots, build_pool),
        );
        let mut router = Self::with_models(embedder, big, small, config);
        router.enable_persistence()?;
        Ok(router)
    }

    /// Build with injected models (tests / baselines / quality-model eval).
    pub fn with_models(
        embedder: Box<dyn TextEmbedder>,
        big: Box<dyn LanguageModel>,
        small: Box<dyn LanguageModel>,
        config: Config,
    ) -> Router {
        let mut cache = SemanticCache::with_opts(
            embedder.out_dim(),
            config.index_kind(),
            config.index_opts(),
        )
        .with_eviction(config.eviction.policy, config.eviction.capacity)
        .with_exact_match(config.exact_match_fast_path);
        // The engine/router side owns the scan workers; the cache only
        // borrows them for fan-out, so one pool serves every cache this
        // router ever builds (including a persistence-recovered one).
        let scan_pool = if config.index.shards > 1 {
            Some(Arc::new(ThreadPool::new(config.index.shards)))
        } else {
            None
        };
        if let Some(pool) = &scan_pool {
            cache.set_pool(Arc::clone(pool), config.index.shards);
        }
        let traces = TraceHub::new(config.trace.clone());
        Router {
            config,
            embedder,
            cache,
            big,
            small,
            ledger: CostLedger::default(),
            latency: LatencyRecorder::new(),
            counters: Counters::default(),
            traces,
            recovery: None,
            scan_pool,
        }
    }

    /// Swap the ephemeral cache for a durable one recovered from
    /// `config.persist.data_dir` (snapshot + WAL replay). No-op when the
    /// `[persist]` section is disabled. Must run before serving traffic —
    /// it replaces the cache wholesale.
    pub fn enable_persistence(&mut self) -> Result<Option<RecoveryReport>> {
        if !self.config.persist.enabled() {
            return Ok(None);
        }
        let (mut cache, report) = SemanticCache::open_persistent_with(
            self.embedder.out_dim(),
            self.config.index_kind(),
            self.config.index_opts(),
            self.config.eviction.policy,
            self.config.eviction.capacity,
            self.config.exact_match_fast_path,
            &self.config.persist,
        )?;
        if let Some(pool) = &self.scan_pool {
            cache.set_pool(Arc::clone(pool), self.config.index.shards);
        }
        self.cache = cache;
        self.recovery = Some(report.clone());
        Ok(Some(report))
    }

    /// Snapshot the cache now (graceful shutdown / the admin verb).
    /// Returns the new persistence generation; `None` when ephemeral.
    pub fn snapshot(&mut self) -> Result<Option<u64>> {
        self.cache.compact_now()
    }

    pub fn cache(&self) -> &SemanticCache {
        &self.cache
    }

    pub fn embedder(&self) -> &dyn TextEmbedder {
        self.embedder.as_ref()
    }

    /// Combined batched-decode occupancy counters of both models' slot
    /// pools (`None` when neither model decodes batched).
    pub fn batch_stats(&self) -> Option<BatchDecodeStats> {
        BatchDecodeStats::merge(self.big.batch_stats(), self.small.batch_stats())
    }

    /// Pre-populate the cache (dataset warm-up in the eval protocols).
    pub fn warm(&mut self, pairs: &[(String, String)]) -> Result<()> {
        let queries: Vec<&str> = pairs.iter().map(|(q, _)| q.as_str()).collect();
        let embeddings = self.embedder.embed_batch(&queries)?;
        for ((q, r), e) in pairs.iter().zip(embeddings) {
            self.cache.insert(q, r, e);
        }
        Ok(())
    }

    /// Route one query through the Figure-1 pipeline.
    pub fn handle(&mut self, query: &str) -> Result<RoutedResponse> {
        let t_start = std::time::Instant::now();
        let mut trace = self.traces.begin(query, t_start);

        // 0) exact-match fast path (§6.1)
        if let Some(resp) = self.try_exact(query, t_start, &mut trace) {
            return Ok(resp);
        }

        // 1) embed
        let t = std::time::Instant::now();
        let embedding = self.embedder.embed(query)?;
        self.latency.record_duration("embed", t.elapsed());
        trace.span_from(Stage::Embed, t);

        self.handle_embedded(query, embedding, t_start, &mut trace)
    }

    /// Exact-match fast path; `None` when disabled or no exact entry.
    /// On a hit the trace is finished here (tagged `exact_hit`).
    pub fn try_exact(
        &mut self,
        query: &str,
        t_start: std::time::Instant,
        trace: &mut TraceBuilder,
    ) -> Option<RoutedResponse> {
        if !self.config.exact_match_fast_path {
            return None;
        }
        let t = std::time::Instant::now();
        let (id, entry) = self.cache.lookup_exact(query)?;
        let text = entry.response_text.clone();
        let cached_query = entry.query_text.clone();
        self.cache.touch(id);
        trace.span_from_value(Stage::Route, t, 1.0);
        trace.set_similarity(1.0);
        self.ledger.record_free();
        self.counters.inc("requests");
        self.counters.inc("exact_hits");
        trace.span_since_last(Stage::Reply);
        // Sample elapsed once, after the reply span, so every span nests
        // within [0, total_us] and the recorded latency and the reported
        // total_micros are the same number.
        let total_micros = t_start.elapsed().as_micros();
        self.latency.record("total", total_micros as f64);
        self.traces.finish(
            trace,
            TraceTag::ExactHit,
            total_micros as u64,
            self.config.similarity_threshold,
        );
        Some(RoutedResponse {
            text,
            pathway: Pathway::ExactHit,
            similarity: Some(1.0),
            cached_query: Some(cached_query),
            cache_entry: Some(id),
            usage: TokenUsage::default(),
            total_micros,
        })
    }

    /// Route a query whose embedding was already computed (batched front).
    /// Blocking shape: route → begin session → drive to EOS → complete.
    /// Exactly the staged pipeline the scheduler runs, collapsed in place —
    /// so a request costs the same work whether the scheduler is on or off.
    pub fn handle_embedded(
        &mut self,
        query: &str,
        embedding: Vec<f32>,
        t_start: std::time::Instant,
        trace: &mut TraceBuilder,
    ) -> Result<RoutedResponse> {
        match self.route(query, embedding, t_start, trace) {
            RouteDecision::Exact(resp) => Ok(resp),
            RouteDecision::Tweak(job) => {
                let t = std::time::Instant::now();
                let mut session = self.begin_tweak_session(&job)?;
                let decode_started = std::time::Instant::now();
                trace.span_at(Stage::Prefill, t, decode_started, f32::NAN);
                while session.advance()? {}
                let resp = session.finish()?;
                trace.span_at(
                    Stage::Decode,
                    decode_started,
                    std::time::Instant::now(),
                    resp.decode_micros as f32,
                );
                trace.set_compute(resp.prefill_micros, resp.decode_micros);
                Ok(self.complete_tweak(&job, resp, t_start, t.elapsed().as_micros(), trace))
            }
            RouteDecision::Miss(job) => {
                let t = std::time::Instant::now();
                let mut session = self.begin_miss_session(&job)?;
                let decode_started = std::time::Instant::now();
                trace.span_at(Stage::Prefill, t, decode_started, f32::NAN);
                while session.advance()? {}
                let resp = session.finish()?;
                trace.span_at(
                    Stage::Decode,
                    decode_started,
                    std::time::Instant::now(),
                    resp.decode_micros as f32,
                );
                trace.set_compute(resp.prefill_micros, resp.decode_micros);
                Ok(self.complete_miss(job, resp, t_start, t.elapsed().as_micros(), trace))
            }
        }
    }

    /// Stage 1: the threshold decision (Figure 1) with no generation work.
    /// Everything the generation needs later is snapshotted into the job.
    pub fn route(
        &mut self,
        query: &str,
        embedding: Vec<f32>,
        t_start: std::time::Instant,
        trace: &mut TraceBuilder,
    ) -> RouteDecision {
        // Exact-match re-check: the batched front runs `try_exact` before
        // embedding, but an identical query routed earlier in this same
        // drain may have inserted its response since.
        if let Some(resp) = self.try_exact(query, t_start, trace) {
            return RouteDecision::Exact(resp);
        }
        self.counters.inc("requests");
        let t = std::time::Instant::now();
        let hits = self.cache.search(&embedding, self.config.top_k);
        self.latency.record_duration("search", t.elapsed());
        trace.span_from(Stage::Search, t);
        let t_route = std::time::Instant::now();
        let top = hits.first().copied();
        let threshold = self.config.similarity_threshold;
        let decision = match top {
            Some(hit) if hit.score >= threshold => {
                let entry = self
                    .cache
                    .entry(hit.id)
                    .expect("search returned tombstoned id");
                RouteDecision::Tweak(TweakJob {
                    prompt: TweakPrompt {
                        new_query: query.to_string(),
                        cached_query: entry.query_text.clone(),
                        cached_response: entry.response_text.clone(),
                    },
                    hit_id: hit.id,
                    score: hit.score,
                })
            }
            top => RouteDecision::Miss(MissJob {
                query: query.to_string(),
                embedding,
                top_score: top.map(|h| h.score),
            }),
        };
        let score = match &decision {
            RouteDecision::Tweak(j) => j.score,
            RouteDecision::Miss(j) => j.top_score.unwrap_or(f32::NAN),
            RouteDecision::Exact(_) => unreachable!("exact resolved above"),
        };
        trace.span_from_value(Stage::Route, t_route, score);
        if score.is_finite() {
            trace.set_similarity(score);
        }
        decision
    }

    /// Stage 2 (hit pathway): start the Small-LLM tweak session.
    pub fn begin_tweak_session(&mut self, job: &TweakJob) -> Result<Box<dyn LlmSession>> {
        self.small.begin_tweak(&job.prompt)
    }

    /// Stage 2 (miss pathway): start the Big-LLM generation session.
    pub fn begin_miss_session(&mut self, job: &MissJob) -> Result<Box<dyn LlmSession>> {
        self.big.begin_respond(&job.query)
    }

    /// Stage 3 (hit pathway): account a finished tweak and build the reply.
    /// `gen_micros` is the session's begin→EOS wall time — under the
    /// scheduler that is occupancy (interleaved sessions overlap), not
    /// exclusive compute.
    pub fn complete_tweak(
        &mut self,
        job: &TweakJob,
        resp: LlmResponse,
        t_start: std::time::Instant,
        gen_micros: u128,
        trace: &mut TraceBuilder,
    ) -> RoutedResponse {
        self.latency.record("tweak_generate", gen_micros as f64);
        self.cache.touch(job.hit_id);
        self.ledger.record(ModelRole::Small, resp.usage);
        self.counters.inc("tweak_hits");
        // Reply span before the total sample: spans nest in [0, total_us].
        trace.span_since_last(Stage::Reply);
        let total_micros = t_start.elapsed().as_micros();
        self.latency.record("total", total_micros as f64);
        self.traces.finish(
            trace,
            TraceTag::TweakHit,
            total_micros as u64,
            self.config.similarity_threshold,
        );
        RoutedResponse {
            text: resp.text,
            pathway: Pathway::TweakHit,
            similarity: Some(job.score),
            cached_query: Some(job.prompt.cached_query.clone()),
            cache_entry: Some(job.hit_id),
            usage: resp.usage,
            total_micros,
        }
    }

    /// Stage 3 (miss pathway): cache insert + accounting at session EOS.
    pub fn complete_miss(
        &mut self,
        job: MissJob,
        resp: LlmResponse,
        t_start: std::time::Instant,
        gen_micros: u128,
        trace: &mut TraceBuilder,
    ) -> RoutedResponse {
        self.latency.record("big_generate", gen_micros as f64);
        let t = std::time::Instant::now();
        let id = self.cache.insert(&job.query, &resp.text, job.embedding);
        self.latency.record_duration("cache_insert", t.elapsed());
        trace.span_from(Stage::CacheInsert, t);
        self.ledger.record(ModelRole::Big, resp.usage);
        self.counters.inc("misses");
        // Reply span before the total sample: spans nest in [0, total_us].
        trace.span_since_last(Stage::Reply);
        let total_micros = t_start.elapsed().as_micros();
        self.latency.record("total", total_micros as f64);
        self.traces.finish(
            trace,
            TraceTag::Miss,
            total_micros as u64,
            self.config.similarity_threshold,
        );
        RoutedResponse {
            text: resp.text,
            pathway: Pathway::Miss,
            similarity: job.top_score,
            cached_query: None,
            cache_entry: Some(id),
            usage: resp.usage,
            total_micros,
        }
    }

    /// Account a request served by attaching to an identical in-flight miss
    /// (duplicate coalescing): zero model cost, one shared generation. With
    /// the exact fast path on this is reported as an exact hit — it is
    /// exactly what re-checking after the leader's insert would yield; with
    /// it off (paper config) it stays a miss, served free.
    pub fn complete_follower(
        &mut self,
        leader_query: &str,
        leader: &RoutedResponse,
        enqueued: std::time::Instant,
        trace: &mut TraceBuilder,
    ) -> RoutedResponse {
        // NB: "requests" was already counted when this request was routed;
        // only the pathway partition is settled here. (Coalescing itself is
        // counted by the scheduler, at attach time.)
        self.ledger.record_free();
        // The follower *used* the freshly-inserted entry: feed LRU/LFU just
        // like the exact fast path would have.
        if let Some(id) = leader.cache_entry {
            self.cache.touch(id);
        }
        let pathway = if self.config.exact_match_fast_path {
            self.counters.inc("exact_hits");
            Pathway::ExactHit
        } else {
            self.counters.inc("misses");
            Pathway::Miss
        };
        // The follower's wait for the leader's generation is its queue-wait.
        trace.span_since_last(Stage::QueueWait);
        trace.span_since_last(Stage::Reply);
        let total_micros = enqueued.elapsed().as_micros();
        self.latency.record("total", total_micros as f64);
        self.traces.finish(
            trace,
            TraceTag::Coalesced,
            total_micros as u64,
            self.config.similarity_threshold,
        );
        RoutedResponse {
            text: leader.text.clone(),
            pathway,
            similarity: Some(1.0),
            cached_query: Some(leader_query.to_string()),
            cache_entry: leader.cache_entry,
            usage: TokenUsage::default(),
            total_micros,
        }
    }

    /// Hit rate over the lifetime of this router (tweak + exact hits).
    pub fn hit_rate(&self) -> f64 {
        let hits = self.counters.get("tweak_hits") + self.counters.get("exact_hits");
        let total = self.counters.get("requests");
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    // Router unit tests use mock models + a mock embedder; they live in
    // rust/tests/router.rs because Embedder requires compiled artifacts.
    // Here we test the pure pieces.
    use super::*;

    #[test]
    fn pathway_eq() {
        assert_ne!(Pathway::ExactHit, Pathway::Miss);
        assert_eq!(Pathway::TweakHit, Pathway::TweakHit);
    }
}
