//! Continuous-batching decode scheduler: the engine's answer to
//! head-of-line blocking.
//!
//! Before this module, `Engine::flush` ran every drained request to
//! completion in routing order, so one Big-LLM miss stalled every tweak-hit
//! queued behind it and the paper's hit-latency advantage evaporated under
//! concurrent load. The scheduler instead holds each routed request as a
//! live [`LlmSession`] — Big-LLM miss generations and Small-LLM tweak
//! generations side by side — and round-robins `advance()` across all of
//! them, replying to each front-end the moment its session reaches EOS.
//! Tweak-hits (a handful of decode units) overtake in-flight misses
//! (dozens), newly-drained requests are admitted mid-flight, and per-session
//! RNG keeps every token stream bit-identical to a sequential run.
//!
//! Duplicate coalescing rides on the same structure: a miss whose
//! normalized query matches an in-flight (or queued) miss attaches to that
//! leader as a *follower* instead of starting a second generation, and the
//! leader's response is fanned out to every follower at completion. This
//! closes the duplicate-in-batch bug where two identical queries in one
//! micro-batch both paid a Big-LLM generation and inserted duplicate cache
//! rows.
//!
//! **Batched decode (PR 5).** With `[scheduler] decode_batch > 0` and
//! batched artifacts compiled, the sessions this ring advances share a
//! slot-batched decode pool per model (`runtime::BatchedDecode` via
//! `llm::SubstrateLlm`, or `MockLlm::with_batch` in tests). The fairness
//! round below then *is* "one batched step for everyone": the first
//! session's `advance()` triggers a single masked device dispatch that
//! moves every live slot one token, and each peer's `advance()` consumes
//! the round credit its slot banked — O(1) dispatches per round instead of
//! O(S), with mid-flight admission claiming freed slots at `start` time.
//! The scheduler itself needs no batching-specific path; occupancy is
//! surfaced through `Router::batch_stats` (`batched_steps` /
//! `mean_active_slots` in engine stats and the TCP `stats` verb).

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::{deadline_expired, MissJob, ReplySink, ReplyTx, Router, TweakJob};
use crate::config::SchedulerConfig;
use crate::llm::LlmSession;
use crate::trace::{Stage, TraceBuilder};

/// Which generation a routed request needs.
pub enum JobKind {
    Tweak(TweakJob),
    /// `key` is the normalized query key (`cache::query_key`) used for
    /// in-flight duplicate coalescing.
    Miss { job: MissJob, key: u64 },
}

/// A routed request: the decision snapshot plus everything needed to reply.
pub struct Job {
    pub kind: JobKind,
    pub reply: ReplySink,
    /// When the request entered the submission pipeline (drives reported
    /// latency, exactly as in the sequential path).
    pub enqueued: Instant,
    /// The request's span-trace arena (disabled outside the engine path).
    pub trace: TraceBuilder,
    /// Generation attempts already failed (miss retry accounting). A failed
    /// miss re-enters the waiting queue up to `[faults] miss_retries`
    /// times; per-request RNG substreams make a successful retry
    /// bit-identical to a first-try success.
    pub attempts: usize,
}

impl Job {
    pub fn new(kind: JobKind, reply: ReplyTx, enqueued: Instant) -> Job {
        Job {
            kind,
            reply: ReplySink::blocking(reply),
            enqueued,
            trace: TraceBuilder::disabled(),
            attempts: 0,
        }
    }

    pub fn traced(kind: JobKind, reply: ReplyTx, enqueued: Instant, trace: TraceBuilder) -> Job {
        Job { kind, reply: ReplySink::blocking(reply), enqueued, trace, attempts: 0 }
    }

    /// Engine path: reply through an explicit delta sink — streaming or
    /// blocking decided by the front end.
    pub fn with_sink(
        kind: JobKind,
        reply: ReplySink,
        enqueued: Instant,
        trace: TraceBuilder,
    ) -> Job {
        Job { kind, reply, enqueued, trace, attempts: 0 }
    }
}

/// A job whose session is live (prefill done, decode in progress).
struct Active {
    job: Job,
    session: Box<dyn LlmSession>,
    /// Session begin time — completion reports begin→EOS occupancy.
    started: Instant,
    /// Prefill end (first decode step eligible) — starts the decode span.
    decode_started: Instant,
}

/// Followers attached to one in-flight miss leader.
#[derive(Default)]
struct FollowerSet {
    /// Reply sinks of the attached duplicates (with their enqueue time and
    /// trace, exactly as a leader job carries them).
    sinks: Vec<(ReplySink, Instant, TraceBuilder)>,
    /// Leader text streamed so far — replayed to a follower at attach time
    /// so every follower's delta concatenation is complete regardless of
    /// when it joined the generation.
    streamed: String,
}

pub struct Scheduler {
    cfg: SchedulerConfig,
    /// Round-robin ring of live sessions.
    active: VecDeque<Active>,
    /// Admitted jobs waiting for a session slot (FIFO).
    waiting: VecDeque<Job>,
    /// Followers per in-flight (active or waiting) miss, by normalized
    /// query key: O(1) duplicate coalescing regardless of backlog size.
    /// An entry exists exactly while its leader is in flight.
    followers: HashMap<u64, FollowerSet>,
    /// Requests served by attaching to an in-flight duplicate (lifetime).
    coalesced: u64,
    /// Sessions completed (lifetime).
    completed: u64,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            cfg,
            active: VecDeque::new(),
            waiting: VecDeque::new(),
            followers: HashMap::new(),
            coalesced: 0,
            completed: 0,
        }
    }

    /// No sessions live and none waiting.
    pub fn is_idle(&self) -> bool {
        self.active.is_empty() && self.waiting.is_empty()
    }

    pub fn active_sessions(&self) -> usize {
        self.active.len()
    }

    pub fn waiting_jobs(&self) -> usize {
        self.waiting.len()
    }

    pub fn coalesced(&self) -> u64 {
        self.coalesced
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Admit a routed request: coalesce onto an identical in-flight miss,
    /// start its session if a slot is free, or queue it.
    pub fn submit(&mut self, mut job: Job, router: &mut Router) {
        if let JobKind::Miss { key, .. } = &job.kind {
            if let Some(flw) = self.followers.get_mut(key) {
                // Catch the follower up on what the leader has already
                // streamed, then subscribe it to the rest of the stream.
                let mut sink = job.reply;
                if sink.delta(&flw.streamed) {
                    job.trace.first_token();
                }
                flw.sinks.push((sink, job.enqueued, job.trace));
                self.coalesced += 1;
                return;
            }
            // This job is now the in-flight leader for its key.
            self.followers.insert(*key, FollowerSet::default());
        }
        if self.active.len() < self.cfg.max_concurrent_sessions.max(1) {
            self.start(job, router);
        } else {
            self.waiting.push_back(job);
        }
    }

    /// One fairness round: every live session gets up to
    /// `fairness_steps` decode units, completed sessions reply (leader +
    /// followers) and free their slot for waiting jobs. Returns how many
    /// sessions completed this round.
    pub fn step(&mut self, router: &mut Router) -> usize {
        let mut finished = 0;
        let live = self.active.len();
        let f = router.config.faults;
        for _ in 0..live {
            let mut act = match self.active.pop_front() {
                Some(a) => a,
                None => break,
            };
            if f.enabled {
                let now = Instant::now();
                // Budget checks at the round boundary: an expired session
                // resolves NOW (degrade / shed / retry) and frees its slot
                // — dropping the session releases any batch-pool slot —
                // instead of decoding on borrowed time.
                if deadline_expired(act.job.enqueued, f.request_deadline_ms, now) {
                    let Active { job, .. } = act;
                    match &job.kind {
                        JobKind::Tweak(_) => self.degrade(job, router),
                        JobKind::Miss { .. } => self.shed(job, router),
                    }
                    finished += 1;
                    continue;
                }
                let overrun = match &act.job.kind {
                    JobKind::Tweak(_) => {
                        deadline_expired(act.started, f.tweak_timeout_ms, now)
                    }
                    JobKind::Miss { .. } => {
                        deadline_expired(act.started, f.generation_timeout_ms, now)
                    }
                };
                if overrun {
                    let Active { job, .. } = act;
                    match &job.kind {
                        JobKind::Tweak(_) => {
                            router.breakers.small.record_failure(now);
                            self.degrade(job, router);
                            finished += 1;
                        }
                        JobKind::Miss { .. } => {
                            router.breakers.big.record_failure(now);
                            let e = anyhow!(
                                "generation timeout ({} ms)",
                                f.generation_timeout_ms
                            );
                            if self.retry_or_fail(job, e, router) {
                                finished += 1;
                            }
                        }
                    }
                    continue;
                }
            }
            let t_turn = Instant::now();
            let outcome = Self::advance_some(&mut act, self.cfg.fairness_steps.max(1));
            // Child span of the decode span: this session's turn in the
            // round, tagged with the round's batch-slot occupancy.
            act.job.trace.decode_round(t_turn, live as f32);
            // Stream the round's decoded text to the leader and every
            // follower; empty rounds send a liveness probe instead so a
            // vanished client is noticed. Skipped on an advance error: the
            // session is about to degrade/retry and text from the doomed
            // attempt must not leak into the stream.
            if outcome.is_ok() {
                let delta = act.session.take_delta();
                self.pump_delta(&mut act.job, &delta, router);
            }
            match outcome {
                Ok(false) => {
                    if act.job.reply.is_closed() && !self.has_live_followers(&act.job.kind) {
                        // Dropping the session frees its batch-pool slot.
                        let Active { job, .. } = act;
                        self.cancel(job, router);
                        finished += 1;
                    } else {
                        self.active.push_back(act);
                    }
                }
                Ok(true) => {
                    self.complete(act, router);
                    finished += 1;
                }
                Err(e) => {
                    let Active { job, .. } = act;
                    match &job.kind {
                        JobKind::Tweak(_) if f.enabled => {
                            // Ladder rung 1: a failed tweak degrades to the
                            // raw cached response instead of failing.
                            router.breakers.small.record_failure(Instant::now());
                            self.degrade(job, router);
                            finished += 1;
                        }
                        JobKind::Miss { .. } if f.enabled => {
                            router.breakers.big.record_failure(Instant::now());
                            if self.retry_or_fail(job, e, router) {
                                finished += 1;
                            }
                        }
                        _ => {
                            self.fail(job, &e, router);
                            finished += 1;
                        }
                    }
                }
            }
        }
        self.admit(router);
        finished
    }

    /// Drive everything to completion (graceful shutdown).
    pub fn drain(&mut self, router: &mut Router) {
        while !self.is_idle() {
            self.step(router);
        }
    }

    /// Up to `steps` decode units on one session; Ok(true) when it is done.
    fn advance_some(act: &mut Active, steps: usize) -> Result<bool> {
        for _ in 0..steps {
            if act.session.is_done() {
                return Ok(true);
            }
            if !act.session.advance()? {
                return Ok(true);
            }
        }
        Ok(act.session.is_done())
    }

    /// Forward one round's decoded text to the leader sink and every
    /// follower sink (an empty delta probes instead). First non-empty text
    /// stamps each trace's TTFT event; followers whose client vanished are
    /// pruned here, accounted as cancelled.
    fn pump_delta(&mut self, job: &mut Job, delta: &str, router: &mut Router) {
        if delta.is_empty() {
            job.reply.probe();
        } else if job.reply.delta(delta) {
            job.trace.first_token();
        }
        if let JobKind::Miss { key, .. } = &job.kind {
            if let Some(flw) = self.followers.get_mut(key) {
                flw.streamed.push_str(delta);
                for (sink, _, f_trace) in flw.sinks.iter_mut() {
                    if delta.is_empty() {
                        sink.probe();
                    } else if sink.delta(delta) {
                        f_trace.first_token();
                    }
                }
                flw.sinks.retain_mut(|(sink, f_enqueued, f_trace)| {
                    if sink.is_closed() {
                        router.finish_failed("cancelled", false, *f_enqueued, f_trace);
                        false
                    } else {
                        true
                    }
                });
            }
        }
    }

    /// Does this job's generation still have listening followers? (Only a
    /// miss leader can: followers attach by query key.)
    fn has_live_followers(&self, kind: &JobKind) -> bool {
        match kind {
            JobKind::Miss { key, .. } => {
                self.followers.get(key).is_some_and(|flw| !flw.sinks.is_empty())
            }
            JobKind::Tweak(_) => false,
        }
    }

    /// The streaming client went away and nobody else is waiting on this
    /// generation: drop it, account the request as `cancelled` (one trace,
    /// one total sample — the invariant holds for abandoned requests too),
    /// and drain the follower entry so a later duplicate starts fresh.
    fn cancel(&mut self, job: Job, router: &mut Router) {
        let Job { kind, enqueued, mut trace, .. } = job;
        if let JobKind::Miss { key, .. } = &kind {
            self.followers.remove(key);
        }
        router.finish_failed("cancelled", false, enqueued, &mut trace);
    }

    /// Fill free session slots from the waiting queue (FIFO).
    fn admit(&mut self, router: &mut Router) {
        while self.active.len() < self.cfg.max_concurrent_sessions.max(1) {
            let job = match self.waiting.pop_front() {
                Some(j) => j,
                None => break,
            };
            self.start(job, router);
        }
    }

    /// Start a job's session (runs the prefill); failures walk the
    /// degradation ladder (degrade / retry / structured error) instead of
    /// poisoning the ring.
    fn start(&mut self, mut job: Job, router: &mut Router) {
        // A queued client may have vanished while waiting for a slot:
        // probe before paying the prefill. A leader with live followers
        // starts regardless — the generation is shared.
        job.reply.probe();
        if job.reply.is_closed() && !self.has_live_followers(&job.kind) {
            self.cancel(job, router);
            return;
        }
        let f = router.config.faults;
        if f.enabled {
            let now = Instant::now();
            // Shed before prefill: a request that has already outlived its
            // deadline must not occupy a slot.
            if deadline_expired(job.enqueued, f.request_deadline_ms, now) {
                match &job.kind {
                    JobKind::Tweak(_) => self.degrade(job, router),
                    JobKind::Miss { .. } => self.shed(job, router),
                }
                return;
            }
            // Open breakers divert proactively — no timeout paid.
            match &job.kind {
                JobKind::Tweak(_) if !router.breakers.small.allow(now) => {
                    self.degrade(job, router);
                    return;
                }
                JobKind::Miss { .. } if !router.breakers.big.allow(now) => {
                    self.fail(
                        job,
                        &anyhow!("big LLM unavailable (circuit breaker open)"),
                        router,
                    );
                    return;
                }
                _ => {}
            }
        }
        // Queue wait: routing decision end → session start (≈0 when a slot
        // was free at submit time).
        job.trace.span_since_last(Stage::QueueWait);
        let started = Instant::now();
        let session = match &job.kind {
            JobKind::Tweak(t) => router.begin_tweak_session(t),
            JobKind::Miss { job: m, .. } => router.begin_miss_session(m),
        };
        match session {
            Ok(session) => {
                let decode_started = Instant::now();
                job.trace.span_at(Stage::Prefill, started, decode_started, f32::NAN);
                self.active.push_back(Active { job, session, started, decode_started });
            }
            Err(e) => match &job.kind {
                JobKind::Tweak(_) if f.enabled => {
                    router.breakers.small.record_failure(Instant::now());
                    self.degrade(job, router);
                }
                JobKind::Miss { .. } if f.enabled => {
                    router.breakers.big.record_failure(Instant::now());
                    self.retry_or_fail(job, e, router);
                }
                _ => self.fail(job, &e, router),
            },
        }
    }

    /// Session reached EOS: account it on the router, reply to the leader
    /// and fan the response out to coalesced followers.
    fn complete(&mut self, act: Active, router: &mut Router) {
        let gen_micros = act.started.elapsed().as_micros();
        let Active { job, session, decode_started, .. } = act;
        let f = router.config.faults;
        let resp = match session.finish() {
            Ok(r) => r,
            Err(e) => {
                match &job.kind {
                    JobKind::Tweak(_) if f.enabled => {
                        router.breakers.small.record_failure(Instant::now());
                        self.degrade(job, router);
                    }
                    JobKind::Miss { .. } if f.enabled => {
                        router.breakers.big.record_failure(Instant::now());
                        self.retry_or_fail(job, e, router);
                    }
                    _ => self.fail(job, &e, router),
                }
                return;
            }
        };
        if f.enabled {
            match &job.kind {
                JobKind::Tweak(_) => router.breakers.small.record_success(Instant::now()),
                JobKind::Miss { .. } => router.breakers.big.record_success(Instant::now()),
            }
        }
        self.completed += 1;
        let Job { kind, reply, enqueued, mut trace, .. } = job;
        // Parent span over every fairness-round turn; value = the
        // generator-reported decode compute inside that occupancy window.
        trace.span_at(Stage::Decode, decode_started, Instant::now(), resp.decode_micros as f32);
        trace.set_compute(resp.prefill_micros, resp.decode_micros);
        // Prefill span value = tokens recomputed (prompt minus the prefix
        // restored from the KV cache); known only once the response is in.
        let recomputed = resp.usage.input_tokens.saturating_sub(resp.restored_tokens);
        trace.set_span_value(Stage::Prefill, recomputed as f32);
        trace.set_prefill_tokens(resp.usage.input_tokens, recomputed);
        let (routed, leader_query, followers) = match kind {
            JobKind::Tweak(t) => {
                let routed = router.complete_tweak(&t, resp, enqueued, gen_micros, &mut trace);
                (routed, t.prompt.new_query, FollowerSet::default())
            }
            JobKind::Miss { job: m, key } => {
                let query = m.query.clone();
                let routed = router.complete_miss(m, resp, enqueued, gen_micros, &mut trace);
                let flw = self.followers.remove(&key).unwrap_or_default();
                (routed, query, flw)
            }
        };
        for (sink, f_enqueued, mut f_trace) in followers.sinks {
            let fan = router.complete_follower(&leader_query, &routed, f_enqueued, &mut f_trace);
            sink.done(fan);
        }
        reply.done(routed);
    }

    /// Degradation-ladder rung 1: resolve a tweak job with the raw cached
    /// response (the tweak step errored, timed out, outlived the deadline,
    /// or its breaker is open). The cached text is in the job snapshot, so
    /// this costs no model work.
    fn degrade(&mut self, job: Job, router: &mut Router) {
        if job.reply.has_emitted() {
            // Mid-stream guard: partial tweak text already left the
            // process; serving the raw cached response now would corrupt
            // the stream. A structured error ends it instead.
            self.resolve_failed(job, &anyhow!("tweak unavailable mid-stream"), "failed", router);
            return;
        }
        let Job { kind, reply, enqueued, mut trace, .. } = job;
        let t = match kind {
            JobKind::Tweak(t) => t,
            JobKind::Miss { .. } => unreachable!("only tweak jobs degrade"),
        };
        let routed = router.complete_degraded(&t, enqueued, &mut trace);
        reply.done(routed);
        self.completed += 1;
    }

    /// Shed a miss that outlived its request deadline: a structured error
    /// to the leader and every coalesced follower.
    fn shed(&mut self, job: Job, router: &mut Router) {
        let dl = router.config.faults.request_deadline_ms;
        self.resolve_failed(job, &anyhow!("request deadline exceeded ({dl} ms)"), "shed", router);
    }

    /// Failed miss: re-queue for another attempt when the retry budget,
    /// breaker, and deadline allow — the back of the waiting queue is the
    /// backoff (other work runs first; the engine thread never sleeps) —
    /// else answer with a structured error. Returns `true` when terminal.
    /// The followers entry survives a re-queue: the leader is still in
    /// flight, and duplicates keep attaching to it.
    fn retry_or_fail(&mut self, mut job: Job, e: anyhow::Error, router: &mut Router) -> bool {
        let f = router.config.faults;
        let now = Instant::now();
        // A retry restarts the token stream from the beginning. That is
        // invisible when nothing has been streamed (per-request RNG makes
        // the retry bit-identical), but once the leader OR any follower has
        // received text, a restart would duplicate it — the failure is
        // terminal instead.
        let streamed_any = job.reply.has_emitted()
            || match &job.kind {
                JobKind::Miss { key, .. } => self
                    .followers
                    .get(key)
                    .is_some_and(|flw| flw.sinks.iter().any(|(s, _, _)| s.has_emitted())),
                JobKind::Tweak(_) => false,
            };
        if f.enabled
            && !streamed_any
            && job.attempts < f.miss_retries
            && router.breakers.big.allow(now)
            && !deadline_expired(job.enqueued, f.request_deadline_ms, now)
        {
            job.attempts += 1;
            router.counters.inc("miss_retries");
            // The retry replays the identical token stream from scratch;
            // reset the follower catch-up buffer to match (no sink has
            // received any of it — checked above).
            if let JobKind::Miss { key, .. } = &job.kind {
                if let Some(flw) = self.followers.get_mut(key) {
                    flw.streamed.clear();
                }
            }
            self.waiting.push_back(job);
            return false;
        }
        self.fail(job, &e, router);
        true
    }

    /// Terminal failure: structured error to the leader and every follower.
    fn fail(&mut self, job: Job, e: &anyhow::Error, router: &mut Router) {
        self.resolve_failed(job, e, "failed", router);
    }

    /// Propagate a failure to the leader and every coalesced follower (the
    /// followers entry must be drained, or later duplicates would attach to
    /// a leader that no longer exists and never hear back). Every request —
    /// followers included — still finishes one trace (tag `failed`) and
    /// records one total sample: the one-reply-one-trace invariant holds on
    /// the failure path too.
    fn resolve_failed(
        &mut self,
        job: Job,
        e: &anyhow::Error,
        kind: &'static str,
        router: &mut Router,
    ) {
        let Job { kind: jkind, reply, enqueued, mut trace, .. } = job;
        let msg = if kind == "shed" {
            format!("{e:#}")
        } else {
            format!("generation failed: {e:#}")
        };
        if let JobKind::Miss { key, .. } = &jkind {
            let flw = self.followers.remove(key).unwrap_or_default();
            for (sink, f_enqueued, mut f_trace) in flw.sinks {
                router.finish_failed(kind, false, f_enqueued, &mut f_trace);
                sink.fail(&msg);
            }
        }
        router.finish_failed(kind, false, enqueued, &mut trace);
        reply.fail(&msg);
    }
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc;

    use super::*;
    use crate::baselines::MockLlm;
    use crate::cache::query_key;
    use crate::config::{Config, IndexKindConfig, SchedulerConfig};
    use crate::coordinator::{Pathway, RouteDecision, RoutedResponse};
    use crate::runtime::{NativeBowEmbedder, TextEmbedder};

    fn test_router_with(sched: SchedulerConfig, big: MockLlm) -> Router {
        let mut cfg = Config::paper();
        cfg.index.kind = IndexKindConfig::Flat;
        cfg.exact_match_fast_path = true;
        cfg.scheduler = sched;
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        Router::with_models(embedder, Box::new(big), Box::new(MockLlm::new("small")), cfg)
    }

    fn test_router(sched: SchedulerConfig) -> Router {
        test_router_with(
            sched,
            MockLlm::new("big").with_pace(4, std::time::Duration::ZERO),
        )
    }

    fn sched_cfg(max: usize, fairness: usize) -> SchedulerConfig {
        SchedulerConfig {
            enabled: true,
            max_concurrent_sessions: max,
            fairness_steps: fairness,
            decode_batch: 0,
        }
    }

    /// Route a query through the router and submit the outcome; returns the
    /// reply receiver (panics on an exact hit — tests route fresh queries).
    fn submit_query(
        sched: &mut Scheduler,
        router: &mut Router,
        query: &str,
    ) -> mpsc::Receiver<Result<RoutedResponse>> {
        let (tx, rx) = mpsc::channel();
        let emb = router.embedder().embed(query).unwrap();
        let mut trace = TraceBuilder::disabled();
        let kind = match router.route(query, emb, Instant::now(), &mut trace) {
            RouteDecision::Exact(resp) => {
                tx.send(Ok(resp)).unwrap();
                return rx;
            }
            RouteDecision::Tweak(t) => JobKind::Tweak(t),
            RouteDecision::Miss(m) => {
                let key = query_key(&m.query);
                JobKind::Miss { job: m, key }
            }
        };
        sched.submit(Job::new(kind, tx, Instant::now()), router);
        rx
    }

    #[test]
    fn tweak_session_overtakes_slow_miss() {
        let mut router = test_router(sched_cfg(4, 1));
        let mut sched = Scheduler::new(router.config.scheduler);
        // Prime an entry so a paraphrase routes to the (1-step) tweak path.
        let prime = submit_query(&mut sched, &mut router, "why is coffee good for health?");
        sched.drain(&mut router);
        assert_eq!(prime.recv().unwrap().unwrap().pathway, Pathway::Miss);
        // A slow 4-step miss, then a 1-step tweak behind it.
        let miss = submit_query(&mut sched, &mut router, "write a poem about glaciers");
        let tweak = submit_query(&mut sched, &mut router, "why is coffee great for health?");
        assert_eq!(sched.active_sessions(), 2);
        // Round 1 completes the tweak (1 unit) while the miss still runs.
        sched.step(&mut router);
        let t = tweak.recv().unwrap().unwrap();
        assert_eq!(t.pathway, Pathway::TweakHit);
        assert!(
            miss.try_recv().is_err(),
            "miss must still be in flight after round 1"
        );
        sched.drain(&mut router);
        assert_eq!(miss.recv().unwrap().unwrap().pathway, Pathway::Miss);
    }

    #[test]
    fn duplicate_misses_coalesce_onto_one_generation() {
        let mut router = test_router(sched_cfg(4, 1));
        let mut sched = Scheduler::new(router.config.scheduler);
        let a = submit_query(&mut sched, &mut router, "what is a b-tree exactly");
        let b = submit_query(&mut sched, &mut router, "what is a  B-TREE exactly");
        assert_eq!(sched.active_sessions(), 1, "dup must not start a session");
        assert_eq!(sched.coalesced(), 1);
        sched.drain(&mut router);
        let ra = a.recv().unwrap().unwrap();
        let rb = b.recv().unwrap().unwrap();
        assert_eq!(ra.pathway, Pathway::Miss);
        assert_eq!(rb.pathway, Pathway::ExactHit); // fast path on
        assert_eq!(ra.text, rb.text);
        assert_eq!(ra.cache_entry, rb.cache_entry);
        assert_eq!(router.counters.get("misses"), 1);
        assert_eq!(router.cache().len(), 1, "one insert, no stale duplicate row");
    }

    #[test]
    fn batched_sessions_cost_one_dispatch_per_round() {
        // The tentpole economics at the scheduler level: S active batched
        // sessions advance through O(1) pool dispatches per fairness round
        // — asserted via the dispatch-counting mock pool.
        let mut router = test_router_with(
            sched_cfg(8, 1),
            MockLlm::new("big")
                .with_pace(6, std::time::Duration::ZERO)
                .with_batch(4),
        );
        let mut sched = Scheduler::new(router.config.scheduler);
        let mut rxs = Vec::new();
        for i in 0..4 {
            let q = format!("batchtopic{i}a batchtopic{i}b batchtopic{i}c");
            rxs.push(submit_query(&mut sched, &mut router, &q));
        }
        assert_eq!(sched.active_sessions(), 4);
        sched.drain(&mut router);
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().unwrap().pathway, Pathway::Miss);
        }
        let stats = router.batch_stats().expect("batched pool live");
        assert_eq!(
            stats.dispatches, 6,
            "6-step sessions must cost 6 rounds, not 4 sessions × 6 steps"
        );
        assert_eq!(stats.active_slot_sum, 24, "all four slots rode every round");
        assert_eq!(stats.slots, 4);
    }

    #[test]
    fn batched_pool_overflow_queues_into_free_slots() {
        // 5 concurrent misses over a 2-slot pool: three overflow onto
        // per-session mocks, everyone completes, and the pool sees
        // multi-slot occupancy throughout.
        let mut router = test_router_with(
            sched_cfg(8, 1),
            MockLlm::new("big")
                .with_pace(3, std::time::Duration::ZERO)
                .with_batch(2),
        );
        let mut sched = Scheduler::new(router.config.scheduler);
        let mut rxs = Vec::new();
        for i in 0..5 {
            let q = format!("ovf{i}a ovf{i}b ovf{i}c ovf{i}d");
            rxs.push(submit_query(&mut sched, &mut router, &q));
        }
        sched.drain(&mut router);
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().unwrap().pathway, Pathway::Miss);
        }
        let stats = router.batch_stats().expect("batched pool live");
        assert!(stats.dispatches > 0);
        assert!(
            stats.active_slot_sum > stats.dispatches,
            "both slots must have been occupied at once: {stats:?}"
        );
    }

    #[test]
    fn admission_cap_queues_and_backfills() {
        let mut router = test_router(sched_cfg(2, 1));
        let mut sched = Scheduler::new(router.config.scheduler);
        let mut rxs = Vec::new();
        for i in 0..5 {
            let q = format!("topic {i} alpha beta gamma");
            rxs.push(submit_query(&mut sched, &mut router, &q));
        }
        assert_eq!(sched.active_sessions(), 2);
        assert_eq!(sched.waiting_jobs(), 3);
        sched.drain(&mut router);
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().unwrap().pathway, Pathway::Miss);
        }
        assert_eq!(sched.completed(), 5);
        assert!(sched.is_idle());
    }

    #[test]
    fn dropped_stream_receiver_cancels_in_flight_session() {
        let mut router = test_router(sched_cfg(2, 1));
        let mut sched = Scheduler::new(router.config.scheduler);
        let query = "cancel me topic alpha beta";
        let emb = router.embedder().embed(query).unwrap();
        let mut trace = TraceBuilder::disabled();
        let kind = match router.route(query, emb, Instant::now(), &mut trace) {
            RouteDecision::Miss(m) => {
                let key = query_key(&m.query);
                JobKind::Miss { job: m, key }
            }
            _ => unreachable!("fresh query must route to the miss path"),
        };
        let (tx, rx) = mpsc::channel();
        let job = Job::with_sink(kind, ReplySink::stream(tx), Instant::now(), trace);
        sched.submit(job, &mut router);
        assert_eq!(sched.active_sessions(), 1);
        // One round streams the first chunk; then the client goes away.
        sched.step(&mut router);
        drop(rx);
        let mut rounds = 0;
        while sched.active_sessions() > 0 {
            sched.step(&mut router);
            rounds += 1;
            assert!(rounds < 10, "cancelled session must free its slot promptly");
        }
        assert!(sched.is_idle(), "no waiting job may be stranded");
        assert_eq!(router.counters.get("cancelled"), 1);
        assert_eq!(
            router.counters.get("misses"),
            0,
            "a cancelled generation must not be accounted as a completed miss"
        );
    }

    #[test]
    fn late_follower_catches_up_on_streamed_text() {
        let mut router = test_router(sched_cfg(4, 1));
        let mut sched = Scheduler::new(router.config.scheduler);
        let query = "what is a skip list exactly";
        // Leader: a plain blocking submission (4-step miss).
        let leader_rx = submit_query(&mut sched, &mut router, query);
        // Two rounds of decode happen before the duplicate arrives.
        sched.step(&mut router);
        sched.step(&mut router);
        // Follower: a streaming duplicate of the same query.
        let emb = router.embedder().embed(query).unwrap();
        let mut trace = TraceBuilder::disabled();
        let kind = match router.route(query, emb, Instant::now(), &mut trace) {
            RouteDecision::Miss(m) => {
                let key = query_key(&m.query);
                JobKind::Miss { job: m, key }
            }
            _ => unreachable!("exact fast path must miss pre-insert"),
        };
        let (tx, rx) = mpsc::channel();
        let follower = Job::with_sink(kind, ReplySink::stream(tx), Instant::now(), trace);
        sched.submit(follower, &mut router);
        assert_eq!(sched.coalesced(), 1, "duplicate must attach, not start a session");
        sched.drain(&mut router);
        let leader = leader_rx.recv().unwrap().unwrap();
        let mut streamed = String::new();
        let mut done_text = None;
        for ev in rx.iter() {
            match ev {
                crate::coordinator::StreamEvent::Delta(d) => streamed.push_str(&d),
                crate::coordinator::StreamEvent::Done(r) => done_text = Some(r.text),
                crate::coordinator::StreamEvent::Error(e) => panic!("follower failed: {e}"),
            }
        }
        assert_eq!(
            streamed, leader.text,
            "catch-up + live deltas must reassemble the leader's exact text"
        );
        assert_eq!(done_text.as_deref(), Some(leader.text.as_str()));
    }
}
