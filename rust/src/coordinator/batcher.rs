//! Dynamic micro-batcher for the embed stage.
//!
//! vLLM-router-style policy: collect requests until either `max_batch` is
//! reached or the oldest request has waited `max_wait`. The compiled
//! embedder has batch variants {1, 8, 32}; batching amortizes the per-call
//! PJRT dispatch overhead across concurrent requests.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::config::BatcherConfig;

/// A queued item: opaque payload + its two timestamps. `enqueued` is when
/// the request entered the submission pipeline (drives latency reporting);
/// `arrived` is when the batcher picked it up (drives the batch-deadline
/// policy). Keeping them separate matters: stamping the deadline from
/// `enqueued` would make any backlog that built up behind a slow
/// generation instantly past-deadline, collapsing those requests into
/// singleton batches exactly when batching matters most.
#[derive(Debug)]
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
    pub arrived: Instant,
}

#[derive(Debug)]
pub struct Batcher<T> {
    queue: VecDeque<Pending<T>>,
    pub max_batch: usize,
    pub max_wait: Duration,
    batches_emitted: u64,
    items_emitted: u64,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher {
            queue: VecDeque::new(),
            max_batch: cfg.max_batch.max(1),
            max_wait: Duration::from_micros(cfg.max_wait_micros),
            batches_emitted: 0,
            items_emitted: 0,
        }
    }

    pub fn push(&mut self, payload: T) {
        self.push_at(payload, Instant::now());
    }

    /// Queue with an explicit enqueue stamp. The engine passes the instant
    /// a request entered the submission channel, so the latency reported
    /// for that request covers the full queueing delay (channel wait while
    /// the engine is busy generating + batcher wait). The batch deadline
    /// still counts from pickup (`arrived` = now), so a drained backlog
    /// gets its `max_wait` window to coalesce into one batch.
    pub fn push_at(&mut self, payload: T, enqueued: Instant) {
        self.queue.push_back(Pending { payload, enqueued, arrived: Instant::now() });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should the current queue be flushed now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        if self.queue.len() >= self.max_batch {
            return true;
        }
        now.duration_since(self.queue.front().unwrap().arrived) >= self.max_wait
    }

    /// How long until the oldest item times out (None if empty).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|p| {
            self.max_wait
                .saturating_sub(now.duration_since(p.arrived))
        })
    }

    /// Drain up to `max_batch` items with their arrival stamps (the engine
    /// computes per-request total latency from these).
    pub fn drain_pending(&mut self) -> Vec<Pending<T>> {
        let n = self.queue.len().min(self.max_batch);
        let batch: Vec<Pending<T>> = self.queue.drain(..n).collect();
        if !batch.is_empty() {
            self.batches_emitted += 1;
            self.items_emitted += batch.len() as u64;
        }
        batch
    }

    /// Drain up to `max_batch` payloads.
    pub fn drain(&mut self) -> Vec<T> {
        self.drain_pending().into_iter().map(|p| p.payload).collect()
    }

    /// Mean batch size so far (batching effectiveness metric).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches_emitted == 0 {
            0.0
        } else {
            self.items_emitted as f64 / self.batches_emitted as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, wait_us: u64) -> BatcherConfig {
        BatcherConfig { max_batch, max_wait_micros: wait_us }
    }

    #[test]
    fn flushes_at_max_batch() {
        let mut b = Batcher::new(cfg(4, 1_000_000));
        for i in 0..4 {
            b.push(i);
        }
        assert!(b.ready(Instant::now()));
        assert_eq!(b.drain(), vec![0, 1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn not_ready_below_batch_before_deadline() {
        let mut b = Batcher::new(cfg(8, 1_000_000));
        b.push(1);
        assert!(!b.ready(Instant::now()));
    }

    #[test]
    fn ready_after_deadline() {
        let mut b = Batcher::new(cfg(8, 0));
        b.push(1);
        assert!(b.ready(Instant::now()));
    }

    #[test]
    fn drain_respects_max_batch() {
        let mut b = Batcher::new(cfg(3, 0));
        for i in 0..7 {
            b.push(i);
        }
        assert_eq!(b.drain().len(), 3);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn mean_batch_size_tracks() {
        let mut b = Batcher::new(cfg(4, 0));
        for i in 0..4 {
            b.push(i);
        }
        b.drain();
        for i in 0..2 {
            b.push(i);
        }
        b.drain();
        assert!((b.mean_batch_size() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_never_ready() {
        let b: Batcher<u32> = Batcher::new(cfg(1, 0));
        assert!(!b.ready(Instant::now()));
        assert!(b.time_to_deadline(Instant::now()).is_none());
    }

    #[test]
    fn push_at_preserves_enqueue_stamp_without_expiring_deadline() {
        let mut b = Batcher::new(cfg(4, 100_000));
        let early = Instant::now() - Duration::from_millis(250);
        b.push_at(7u32, early);
        // The batch deadline counts from pickup, NOT from the (old) enqueue
        // stamp — a drained backlog must still get its coalescing window.
        assert!(!b.ready(Instant::now()));
        let pending = b.drain_pending();
        assert_eq!(pending.len(), 1);
        assert_eq!(pending[0].payload, 7);
        // ...while the enqueue stamp survives for latency reporting.
        assert_eq!(pending[0].enqueued, early);
        assert!(pending[0].arrived > early);
    }
}
