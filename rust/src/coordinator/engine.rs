//! The serving engine: a dedicated thread that owns the `Router` (and with
//! it the PJRT client) and consumes requests from a channel, batching the
//! embed stage and interleaving decode via the [`Scheduler`].
//!
//! Leader/worker shape: the engine thread is the single worker for model
//! compute (the CPU PJRT client serializes execution anyway); front-ends
//! (TCP server, in-process clients, bench harnesses) are leaders that
//! submit `Request` messages and block on a rendezvous channel.
//!
//! The serve loop alternates three duties, never blocking while any
//! session is in flight:
//! 1. **ingest** — drain the submission channel into the batcher (blocking
//!    only when there is truly nothing to do);
//! 2. **flush** — when the batcher is ready, embed the micro-batch, route
//!    each request, and hand the resulting decode jobs to the scheduler
//!    (or run them to completion in place when the scheduler is disabled);
//! 3. **advance** — give every live session one fairness round, replying
//!    to front-ends as sessions reach EOS.
//!
//! Flushing loops while the batcher remains ready — and, on shutdown,
//! until it is empty — so a burst larger than `max_batch` can never strand
//! leftovers (the old loop flushed once and went back to a blocking
//! `recv`, parking any remainder forever on a then-idle connection).

use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::scheduler::{Job, JobKind, Scheduler};
use super::{
    deadline_expired, Batcher, ReadMode, ReplicaBatch, ReplySink, RouteDecision, RoutedResponse,
    Router, StreamEvent,
};
use crate::cache::query_key;
use crate::trace::{Stage, StageSummary, TraceBuilder, TraceReport};

/// What rides through the batcher per request: the query, the reply sink
/// (streaming or one-shot), the request's span-trace arena, and how the
/// request may use the cache (cluster failover modes).
type BatchItem = (String, ReplySink, TraceBuilder, ReadMode);

enum Msg {
    Request {
        query: String,
        reply: ReplySink,
        /// Stamped by `EngineHandle::request` before the channel send, so
        /// reported latency includes time spent queued behind whatever the
        /// engine was doing (e.g. a slow Big-LLM generation).
        enqueued: Instant,
        mode: ReadMode,
    },
    Stats {
        reply: mpsc::Sender<EngineStats>,
    },
    Trace {
        n: usize,
        reply: mpsc::Sender<TraceReport>,
    },
    Snapshot {
        reply: mpsc::Sender<Result<SnapshotReport>>,
    },
    /// Apply replicated state (WAL shipping) on the engine thread, between
    /// request batches — the replica equivalent of recovery replay.
    Replicate {
        batch: ReplicaBatch,
        reply: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub requests: u64,
    pub tweak_hits: u64,
    pub exact_hits: u64,
    pub misses: u64,
    pub cache_size: usize,
    pub mean_batch_size: f64,
    pub latency_table: String,
    pub cost_dollars: f64,
    pub baseline_dollars: f64,
    // ---- decode scheduler ----
    /// Sessions decoding right now (0 when the scheduler is disabled).
    pub active_sessions: usize,
    /// Routed jobs waiting for a session slot.
    pub waiting_sessions: usize,
    /// Requests served by coalescing onto an identical in-flight miss.
    pub coalesced: u64,
    /// Batched decode dispatches issued across both models' slot pools
    /// (each advances every active slot in one device call); 0 when
    /// batched decode is off or unavailable.
    pub batched_steps: u64,
    /// Mean active slots per batched dispatch (batch occupancy); 0.0 when
    /// no batched dispatch has run.
    pub mean_active_slots: f64,
    // ---- KV prefix cache (all zero when prefix reuse is off) ----
    /// Prefill lookups served from a cached prefix state.
    pub prefix_hits: u64,
    /// Prefill lookups that ran cold.
    pub prefix_misses: u64,
    /// Cached prefix states evicted by the LRU byte budget.
    pub prefix_evictions: u64,
    /// Prompt tokens restored from cache instead of recomputed.
    pub prefix_saved_tokens: u64,
    // ---- persistence (all zero when the [persist] section is disabled) ----
    pub persist_enabled: bool,
    pub persist_generation: u64,
    pub wal_bytes: u64,
    pub wal_records: u64,
    pub compactions: u64,
    pub last_compaction_unix: u64,
    /// Live entries recovered from snapshot + WAL at startup.
    pub recovered_entries: u64,
    // ---- tracing ----
    /// Per-stage × per-pathway latency quantiles from the trace histograms
    /// (empty when tracing is disabled).
    pub stage_latency: Vec<StageSummary>,
    /// Traces completed since startup (ring + evicted).
    pub traces_finished: u64,
    // ---- fault tolerance (all zero / "closed" when [faults] is disabled) ----
    /// Tweak hits degraded to the raw cached response (tweak LLM sick).
    pub degraded_hits: u64,
    /// Requests shed at a stage boundary after their deadline expired.
    pub shed: u64,
    /// Requests answered with a terminal structured error.
    pub failed: u64,
    /// In-flight requests abandoned because the streaming client
    /// disconnected (session dropped, slot freed, no reply sent).
    pub cancelled: u64,
    /// Requests routed straight to the miss path because the embedder was
    /// unavailable (no cache lookup, no insert).
    pub embed_bypasses: u64,
    /// Miss-generation retry attempts (requeues + blocking-path retries).
    pub miss_retries: u64,
    /// Lifetime closed→open transitions across all three breakers.
    pub breaker_trips: u64,
    /// Breaker states: "closed", "open", or "half_open".
    pub breaker_embed: String,
    pub breaker_small: String,
    pub breaker_big: String,
}

/// Result of an explicit `{"admin": "snapshot"}` request.
#[derive(Clone, Debug, Default)]
pub struct SnapshotReport {
    pub persist_enabled: bool,
    /// Generation of the snapshot just written (0 when disabled).
    pub generation: u64,
    /// Live entries captured.
    pub entries: usize,
}

/// Handle used by front-ends to talk to the engine. Cheap to clone.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
}

impl EngineHandle {
    /// Route one query (blocks until the engine responds). A thin
    /// drain-to-EOS wrapper over the streaming transport: deltas are
    /// suppressed at the source (`ReplySink::buffered`), so this costs one
    /// terminal event exactly like the pre-streaming rendezvous channel.
    pub fn request(&self, query: &str) -> Result<RoutedResponse> {
        self.request_mode(query, ReadMode::Default)
    }

    /// [`Self::request`] with an explicit cache [`ReadMode`] — the cluster
    /// front end's failover lever (replica reads, staleness bypass).
    pub fn request_mode(&self, query: &str, mode: ReadMode) -> Result<RoutedResponse> {
        let rx = self.submit(query, false, mode)?;
        for ev in rx.iter() {
            match ev {
                StreamEvent::Delta(_) => {}
                StreamEvent::Done(resp) => return Ok(resp),
                StreamEvent::Error(msg) => return Err(anyhow!("{msg}")),
            }
        }
        Err(anyhow!("engine dropped the request"))
    }

    /// Route one query, streaming token deltas as the engine decodes them.
    /// The receiver yields `Delta` events (empty ones are liveness probes)
    /// and ends with exactly one `Done` or `Error`; concatenated deltas are
    /// bit-identical to the blocking response's text on every pathway.
    /// Dropping the receiver mid-stream cancels the in-flight generation.
    pub fn request_streaming(&self, query: &str) -> Result<mpsc::Receiver<StreamEvent>> {
        self.submit(query, true, ReadMode::Default)
    }

    fn submit(
        &self,
        query: &str,
        live: bool,
        mode: ReadMode,
    ) -> Result<mpsc::Receiver<StreamEvent>> {
        let (tx, rx) = mpsc::channel();
        let reply = if live { ReplySink::stream(tx) } else { ReplySink::buffered(tx) };
        self.tx
            .send(Msg::Request {
                query: query.to_string(),
                reply,
                enqueued: Instant::now(),
                mode,
            })
            .map_err(|_| anyhow!("engine is down"))?;
        Ok(rx)
    }

    /// Apply replicated cache state (a bootstrap snapshot or shipped WAL
    /// records) on the engine thread. Blocks until applied, so the caller
    /// can ack the shipped position truthfully.
    pub fn apply_replicated(&self, batch: ReplicaBatch) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Replicate { batch, reply })
            .map_err(|_| anyhow!("engine is down"))?;
        rx.recv()
            .map_err(|_| anyhow!("engine dropped the replicate request"))?
    }

    pub fn stats(&self) -> Result<EngineStats> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Stats { reply })
            .map_err(|_| anyhow!("engine is down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the stats request"))
    }

    /// Fetch the last `n` completed traces + the slow-request list.
    pub fn traces(&self, n: usize) -> Result<TraceReport> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Trace { n, reply })
            .map_err(|_| anyhow!("engine is down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the trace request"))
    }

    /// Force a cache snapshot + WAL rotation (the admin protocol verb).
    pub fn snapshot(&self) -> Result<SnapshotReport> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Snapshot { reply })
            .map_err(|_| anyhow!("engine is down"))?;
        rx.recv()
            .map_err(|_| anyhow!("engine dropped the snapshot request"))?
    }
}

pub struct Engine {
    tx: mpsc::Sender<Msg>,
    thread: Option<thread::JoinHandle<()>>,
}

impl Engine {
    /// Start the engine thread. The router is *constructed on the engine
    /// thread* by `factory` because the PJRT handles inside it are not
    /// `Send`; construction errors are surfaced here synchronously.
    pub fn start<F>(factory: F) -> Result<(Engine, EngineHandle)>
    where
        F: FnOnce() -> Result<Router> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread = thread::Builder::new()
            .name("tweakllm-engine".into())
            .spawn(move || {
                let mut router = match factory() {
                    Ok(r) => {
                        let _ = ready_tx.send(Ok(()));
                        r
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                Self::serve(&mut router, rx);
                // Graceful shutdown: fold the WAL into a final snapshot so
                // the next start replays nothing. Crash recovery does not
                // depend on this — it is an optimization, not a correctness
                // requirement.
                if let Err(e) = router.snapshot() {
                    eprintln!("[engine] final snapshot failed: {e:#}");
                }
            })
            .expect("spawn engine thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok((Engine { tx: tx.clone(), thread: Some(thread) }, EngineHandle { tx }))
    }

    /// The engine thread's serve loop (see the module docs for the shape).
    fn serve(router: &mut Router, rx: mpsc::Receiver<Msg>) {
        let mut batcher: Batcher<BatchItem> = Batcher::new(router.config.batcher);
        let mut sched = Scheduler::new(router.config.scheduler);
        let sched_on = router.config.scheduler.enabled;
        let mut shutdown = false;
        loop {
            // ---- 1) ingest ----
            // Block for work only when fully idle; a live session must
            // keep advancing, so otherwise the channel is polled.
            if !shutdown && sched.is_idle() && batcher.is_empty() {
                match rx.recv() {
                    Ok(m) => shutdown = Self::on_msg(m, router, &mut batcher, &sched),
                    Err(_) => shutdown = true,
                }
            }
            if !shutdown {
                loop {
                    match rx.try_recv() {
                        Ok(m) => {
                            if Self::on_msg(m, router, &mut batcher, &sched) {
                                shutdown = true;
                                break;
                            }
                        }
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            shutdown = true;
                            break;
                        }
                    }
                }
            }
            // A sub-batch waiting out its coalescing window with no session
            // in flight: sleep until the deadline instead of spinning.
            if !shutdown && sched.is_idle() && !batcher.is_empty() {
                let now = Instant::now();
                if !batcher.ready(now) {
                    let timeout = batcher.time_to_deadline(now).unwrap_or_default();
                    match rx.recv_timeout(timeout) {
                        Ok(m) => shutdown = Self::on_msg(m, router, &mut batcher, &sched),
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => shutdown = true,
                    }
                }
            }
            // ---- 2) flush (keep going: no stranded leftovers) ----
            loop {
                let now = Instant::now();
                if !(batcher.ready(now) || (shutdown && !batcher.is_empty())) {
                    break;
                }
                Self::flush(router, &mut batcher, sched_on.then_some(&mut sched));
            }
            // ---- 3) advance live sessions one fairness round ----
            sched.step(router);
            // Exit only once every accepted request has been answered.
            if shutdown && sched.is_idle() && batcher.is_empty() {
                break;
            }
        }
    }

    /// Process one control message; returns `true` on a shutdown request.
    fn on_msg(
        msg: Msg,
        router: &mut Router,
        batcher: &mut Batcher<BatchItem>,
        sched: &Scheduler,
    ) -> bool {
        match msg {
            Msg::Request { query, reply, enqueued, mode } => {
                let mut trace = router.traces.begin(&query, enqueued);
                // Channel transit: enqueue stamp → engine-thread pickup.
                trace.span_from(Stage::Ingest, enqueued);
                batcher.push_at((query, reply, trace, mode), enqueued);
                false
            }
            Msg::Stats { reply } => {
                let _ = reply.send(Self::collect_stats(router, batcher, sched));
                false
            }
            Msg::Trace { n, reply } => {
                let _ = reply.send(router.traces.report(n));
                false
            }
            Msg::Snapshot { reply } => {
                let _ = reply.send(Self::do_snapshot(router));
                false
            }
            Msg::Replicate { batch, reply } => {
                let _ = reply.send(router.apply_replicated(batch));
                false
            }
            Msg::Shutdown => true,
        }
    }

    /// Embed the whole micro-batch in one artifact call, route each
    /// request, and dispatch the decode work. With the scheduler the jobs
    /// join the live interleave; without it each runs to completion here in
    /// routing order (the pre-scheduler behavior). Each request's latency
    /// is measured from its own enqueue instant — NOT from the drain — so
    /// queue wait behind a slow generation shows up in `total_micros`.
    fn flush(
        router: &mut Router,
        batcher: &mut Batcher<BatchItem>,
        mut sched: Option<&mut Scheduler>,
    ) {
        let batch = batcher.drain_pending();
        if batch.is_empty() {
            return;
        }
        let drained = Instant::now();
        // Exact-match fast path first: those don't need embeddings.
        let mut to_embed: Vec<(String, ReplySink, Instant, TraceBuilder, ReadMode)> =
            Vec::with_capacity(batch.len());
        let faults = router.config.faults;
        for pending in batch {
            let enqueued = pending.enqueued;
            let arrived = pending.arrived;
            let (query, reply, mut trace, mode) = pending.payload;
            trace.span_at(Stage::BatcherWait, arrived, drained, f32::NAN);
            // Deadline shedding at the first stage boundary: a request that
            // aged out in the batcher never pays for embed/route/decode.
            if faults.enabled && deadline_expired(enqueued, faults.request_deadline_ms, drained) {
                router.finish_failed("shed", true, enqueued, &mut trace);
                reply.fail(&format!(
                    "request deadline exceeded ({} ms)",
                    faults.request_deadline_ms
                ));
                continue;
            }
            // Bounded-staleness bypass (the cluster router rejected the
            // replica's lag): no cache access at all, straight to the miss
            // path — the same rung the embed-down ladder uses.
            if mode == ReadMode::Bypass {
                let job = router.miss_bypass_job(&query);
                match &mut sched {
                    Some(s) => {
                        let key = query_key(&job.query);
                        let kind = JobKind::Miss { job, key };
                        s.submit(Job::with_sink(kind, reply, enqueued, trace), router);
                    }
                    None => {
                        let mut reply = reply;
                        match router.run_miss_blocking(job, enqueued, &mut reply, &mut trace) {
                            Ok(resp) => reply.done(resp),
                            Err(e) => reply.fail(&format!("{e:#}")),
                        }
                    }
                }
                continue;
            }
            if let Some(resp) = router.try_exact(&query, enqueued, &mut trace) {
                reply.done(resp);
            } else {
                to_embed.push((query, reply, enqueued, trace, mode));
            }
        }
        if to_embed.is_empty() {
            return;
        }
        // Borrowed views only — embedding a batch must not copy every query.
        let queries: Vec<&str> = to_embed.iter().map(|(q, _, _, _, _)| q.as_str()).collect();
        // Embed rung of the degradation ladder: an open breaker skips the
        // backend call entirely; a failed call records breaker evidence.
        // Either way every batch-mate falls through to the miss path below
        // (no similarity search, no cache insert) instead of erroring out.
        let embedded_ok = if faults.enabled && !router.breakers.embed.allow(Instant::now()) {
            None
        } else {
            let t_embed = Instant::now();
            match router.embedder().embed_batch(&queries) {
                Ok(embeddings) => {
                    let embedded = Instant::now();
                    if faults.enabled {
                        router.breakers.embed.record_success(embedded);
                    }
                    router.latency.record("embed", (embedded - t_embed).as_micros() as f64);
                    Some((embeddings, t_embed, embedded))
                }
                Err(e) => {
                    if faults.enabled {
                        router.breakers.embed.record_failure(Instant::now());
                        None
                    } else {
                        let msg = format!("batched embed failed: {e}");
                        for (_, reply, _, _, _) in to_embed {
                            reply.fail(&msg);
                        }
                        return;
                    }
                }
            }
        };
        match embedded_ok {
            Some((embeddings, t_embed, embedded)) => {
                // One embed interval shared by the whole micro-batch: stamp
                // it on every trace before any request starts routing, so a
                // batch-mate's route time never bleeds into an embed span.
                for (_, _, _, trace, _) in to_embed.iter_mut() {
                    trace.span_at(Stage::Embed, t_embed, embedded, f32::NAN);
                }
                for ((query, mut reply, enqueued, mut trace, mode), emb) in
                    to_embed.into_iter().zip(embeddings)
                {
                    match &mut sched {
                        Some(s) => match router.route(&query, emb, enqueued, &mut trace) {
                            RouteDecision::Exact(resp) => {
                                reply.done(resp);
                            }
                            RouteDecision::Tweak(t) => {
                                let kind = JobKind::Tweak(t);
                                s.submit(Job::with_sink(kind, reply, enqueued, trace), router);
                            }
                            RouteDecision::Miss(mut m) => {
                                // A replica serving during an owner outage
                                // generates the miss but never inserts: the
                                // entry space belongs to the owner's WAL.
                                if mode == ReadMode::ReplicaRead {
                                    m.insert = false;
                                }
                                let key = query_key(&m.query);
                                let kind = JobKind::Miss { job: m, key };
                                s.submit(Job::with_sink(kind, reply, enqueued, trace), router);
                            }
                        },
                        None => {
                            let result = match router.route(&query, emb, enqueued, &mut trace) {
                                RouteDecision::Exact(resp) => Ok(resp),
                                RouteDecision::Tweak(t) => {
                                    router.run_tweak_blocking(t, enqueued, &mut reply, &mut trace)
                                }
                                RouteDecision::Miss(mut m) => {
                                    if mode == ReadMode::ReplicaRead {
                                        m.insert = false;
                                    }
                                    router.run_miss_blocking(m, enqueued, &mut reply, &mut trace)
                                }
                            };
                            match result {
                                Ok(resp) => reply.done(resp),
                                Err(e) => reply.fail(&format!("{e:#}")),
                            }
                        }
                    }
                }
            }
            None => {
                // Embedder unavailable: bypass the cache for every
                // batch-mate rather than failing them.
                for (query, mut reply, enqueued, mut trace, _) in to_embed {
                    let job = router.miss_bypass_job(&query);
                    match &mut sched {
                        Some(s) => {
                            let key = query_key(&job.query);
                            let kind = JobKind::Miss { job, key };
                            s.submit(Job::with_sink(kind, reply, enqueued, trace), router);
                        }
                        None => {
                            match router.run_miss_blocking(job, enqueued, &mut reply, &mut trace) {
                                Ok(resp) => reply.done(resp),
                                Err(e) => reply.fail(&format!("{e:#}")),
                            }
                        }
                    }
                }
            }
        }
    }

    fn do_snapshot(router: &mut Router) -> Result<SnapshotReport> {
        let entries = router.cache().len();
        match router.snapshot()? {
            Some(generation) => Ok(SnapshotReport {
                persist_enabled: true,
                generation,
                entries,
            }),
            None => Ok(SnapshotReport {
                persist_enabled: false,
                generation: 0,
                entries,
            }),
        }
    }

    fn collect_stats(
        router: &Router,
        batcher: &Batcher<BatchItem>,
        sched: &Scheduler,
    ) -> EngineStats {
        let persist = router.cache().persist_status();
        let batch = router.batch_stats();
        let prefix = router.prefix_stats();
        EngineStats {
            requests: router.counters.get("requests"),
            tweak_hits: router.counters.get("tweak_hits"),
            exact_hits: router.counters.get("exact_hits"),
            misses: router.counters.get("misses"),
            cache_size: router.cache().len(),
            mean_batch_size: batcher.mean_batch_size(),
            latency_table: router.latency.table(),
            cost_dollars: router.ledger.dollars(&router.config.cost),
            baseline_dollars: router.ledger.baseline_dollars(&router.config.cost),
            active_sessions: sched.active_sessions(),
            waiting_sessions: sched.waiting_jobs(),
            coalesced: sched.coalesced(),
            batched_steps: batch.map_or(0, |b| b.dispatches),
            mean_active_slots: batch.map_or(0.0, |b| {
                if b.dispatches == 0 {
                    0.0
                } else {
                    b.active_slot_sum as f64 / b.dispatches as f64
                }
            }),
            prefix_hits: prefix.map_or(0, |p| p.hits),
            prefix_misses: prefix.map_or(0, |p| p.misses),
            prefix_evictions: prefix.map_or(0, |p| p.evictions),
            prefix_saved_tokens: prefix.map_or(0, |p| p.saved_tokens),
            persist_enabled: persist.is_some(),
            persist_generation: persist.map_or(0, |p| p.generation),
            wal_bytes: persist.map_or(0, |p| p.wal_bytes),
            wal_records: persist.map_or(0, |p| p.wal_records),
            compactions: persist.map_or(0, |p| p.compactions),
            last_compaction_unix: persist.map_or(0, |p| p.last_compaction_unix),
            recovered_entries: router
                .recovery
                .as_ref()
                .map_or(0, |r| r.recovered_entries),
            stage_latency: router.traces.stage_summaries(),
            traces_finished: router.traces.finished(),
            degraded_hits: router.counters.get("degraded_hits"),
            shed: router.counters.get("shed"),
            failed: router.counters.get("failed"),
            cancelled: router.counters.get("cancelled"),
            embed_bypasses: router.counters.get("embed_bypasses"),
            miss_retries: router.counters.get("miss_retries"),
            breaker_trips: router.breakers.embed.trips()
                + router.breakers.small.trips()
                + router.breakers.big.trips(),
            breaker_embed: router.breakers.embed.state().name().to_string(),
            breaker_small: router.breakers.small.state().name().to_string(),
            breaker_big: router.breakers.big.state().name().to_string(),
        }
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}
