//! The serving engine: a dedicated thread that owns the `Router` (and with
//! it the PJRT client) and consumes requests from a channel, batching the
//! embed stage.
//!
//! Leader/worker shape: the engine thread is the single worker for model
//! compute (the CPU PJRT client serializes execution anyway); front-ends
//! (TCP server, in-process clients, bench harnesses) are leaders that
//! submit `Request` messages and block on a rendezvous channel.

use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::{Batcher, RoutedResponse, Router};

enum Msg {
    Request {
        query: String,
        reply: mpsc::Sender<Result<RoutedResponse>>,
        /// Stamped by `EngineHandle::request` before the channel send, so
        /// reported latency includes time spent queued behind whatever the
        /// engine was doing (e.g. a slow Big-LLM generation).
        enqueued: Instant,
    },
    Stats {
        reply: mpsc::Sender<EngineStats>,
    },
    Snapshot {
        reply: mpsc::Sender<Result<SnapshotReport>>,
    },
    Shutdown,
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub requests: u64,
    pub tweak_hits: u64,
    pub exact_hits: u64,
    pub misses: u64,
    pub cache_size: usize,
    pub mean_batch_size: f64,
    pub latency_table: String,
    pub cost_dollars: f64,
    pub baseline_dollars: f64,
    // ---- persistence (all zero when the [persist] section is disabled) ----
    pub persist_enabled: bool,
    pub persist_generation: u64,
    pub wal_bytes: u64,
    pub wal_records: u64,
    pub compactions: u64,
    pub last_compaction_unix: u64,
    /// Live entries recovered from snapshot + WAL at startup.
    pub recovered_entries: u64,
}

/// Result of an explicit `{"admin": "snapshot"}` request.
#[derive(Clone, Debug, Default)]
pub struct SnapshotReport {
    pub persist_enabled: bool,
    /// Generation of the snapshot just written (0 when disabled).
    pub generation: u64,
    /// Live entries captured.
    pub entries: usize,
}

/// Handle used by front-ends to talk to the engine. Cheap to clone.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
}

impl EngineHandle {
    /// Route one query (blocks until the engine responds).
    pub fn request(&self, query: &str) -> Result<RoutedResponse> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Request {
                query: query.to_string(),
                reply,
                enqueued: Instant::now(),
            })
            .map_err(|_| anyhow!("engine is down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the request"))?
    }

    pub fn stats(&self) -> Result<EngineStats> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Stats { reply })
            .map_err(|_| anyhow!("engine is down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the stats request"))
    }

    /// Force a cache snapshot + WAL rotation (the admin protocol verb).
    pub fn snapshot(&self) -> Result<SnapshotReport> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Snapshot { reply })
            .map_err(|_| anyhow!("engine is down"))?;
        rx.recv()
            .map_err(|_| anyhow!("engine dropped the snapshot request"))?
    }
}

pub struct Engine {
    tx: mpsc::Sender<Msg>,
    thread: Option<thread::JoinHandle<()>>,
}

impl Engine {
    /// Start the engine thread. The router is *constructed on the engine
    /// thread* by `factory` because the PJRT handles inside it are not
    /// `Send`; construction errors are surfaced here synchronously.
    pub fn start<F>(factory: F) -> Result<(Engine, EngineHandle)>
    where
        F: FnOnce() -> Result<Router> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread = thread::Builder::new()
            .name("tweakllm-engine".into())
            .spawn(move || {
                let mut router = match factory() {
                    Ok(r) => {
                        let _ = ready_tx.send(Ok(()));
                        r
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut batcher: Batcher<(String, mpsc::Sender<Result<RoutedResponse>>)> =
                    Batcher::new(router.config.batcher);
                'serve: loop {
                    // Block for the first message, then drain greedily up to
                    // the batch deadline.
                    let first = match rx.recv() {
                        Ok(m) => m,
                        Err(_) => break 'serve,
                    };
                    match first {
                        Msg::Shutdown => break 'serve,
                        Msg::Stats { reply } => {
                            let _ = reply.send(Self::collect_stats(&router, &batcher));
                            continue;
                        }
                        Msg::Snapshot { reply } => {
                            let _ = reply.send(Self::do_snapshot(&mut router));
                            continue;
                        }
                        Msg::Request { query, reply, enqueued } => {
                            batcher.push_at((query, reply), enqueued)
                        }
                    }
                    // Greedy drain: accept more requests until ready.
                    loop {
                        let now = Instant::now();
                        if batcher.ready(now) {
                            break;
                        }
                        let timeout = batcher
                            .time_to_deadline(now)
                            .unwrap_or_default();
                        match rx.recv_timeout(timeout) {
                            Ok(Msg::Request { query, reply, enqueued }) => {
                                batcher.push_at((query, reply), enqueued)
                            }
                            Ok(Msg::Stats { reply }) => {
                                let _ = reply
                                    .send(Self::collect_stats(&router, &batcher));
                            }
                            Ok(Msg::Snapshot { reply }) => {
                                let _ = reply.send(Self::do_snapshot(&mut router));
                            }
                            Ok(Msg::Shutdown) => {
                                Self::flush(&mut router, &mut batcher);
                                break 'serve;
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                Self::flush(&mut router, &mut batcher);
                                break 'serve;
                            }
                        }
                    }
                    Self::flush(&mut router, &mut batcher);
                }
                // Graceful shutdown: fold the WAL into a final snapshot so
                // the next start replays nothing. Crash recovery does not
                // depend on this — it is an optimization, not a correctness
                // requirement.
                if let Err(e) = router.snapshot() {
                    eprintln!("[engine] final snapshot failed: {e:#}");
                }
            })
            .expect("spawn engine thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok((Engine { tx: tx.clone(), thread: Some(thread) }, EngineHandle { tx }))
    }

    /// Embed the whole micro-batch in one artifact call, then route each
    /// request sequentially (generation is inherently sequential on the
    /// single PJRT CPU device). Each request's latency is measured from its
    /// own enqueue instant — NOT from the drain — so queue wait behind a
    /// slow generation shows up in `total_micros`.
    fn flush(
        router: &mut Router,
        batcher: &mut Batcher<(String, mpsc::Sender<Result<RoutedResponse>>)>,
    ) {
        let batch = batcher.drain_pending();
        if batch.is_empty() {
            return;
        }
        // Exact-match fast path first: those don't need embeddings.
        let mut to_embed: Vec<(String, mpsc::Sender<Result<RoutedResponse>>, Instant)> =
            Vec::with_capacity(batch.len());
        for pending in batch {
            let enqueued = pending.enqueued;
            let (query, reply) = pending.payload;
            if let Some(resp) = router.try_exact(&query, enqueued) {
                let _ = reply.send(Ok(resp));
            } else {
                to_embed.push((query, reply, enqueued));
            }
        }
        if to_embed.is_empty() {
            return;
        }
        // Borrowed views only — embedding a batch must not copy every query.
        let queries: Vec<&str> = to_embed.iter().map(|(q, _, _)| q.as_str()).collect();
        match router.embedder().embed_batch(&queries) {
            Ok(embeddings) => {
                for ((query, reply, enqueued), emb) in to_embed.into_iter().zip(embeddings) {
                    let resp = router.handle_embedded(&query, emb, enqueued);
                    let _ = reply.send(resp);
                }
            }
            Err(e) => {
                let msg = format!("batched embed failed: {e}");
                for (_, reply, _) in to_embed {
                    let _ = reply.send(Err(anyhow!("{msg}")));
                }
            }
        }
    }

    fn do_snapshot(router: &mut Router) -> Result<SnapshotReport> {
        let entries = router.cache().len();
        match router.snapshot()? {
            Some(generation) => Ok(SnapshotReport {
                persist_enabled: true,
                generation,
                entries,
            }),
            None => Ok(SnapshotReport {
                persist_enabled: false,
                generation: 0,
                entries,
            }),
        }
    }

    fn collect_stats(
        router: &Router,
        batcher: &Batcher<(String, mpsc::Sender<Result<RoutedResponse>>)>,
    ) -> EngineStats {
        let persist = router.cache().persist_status();
        EngineStats {
            requests: router.counters.get("requests"),
            tweak_hits: router.counters.get("tweak_hits"),
            exact_hits: router.counters.get("exact_hits"),
            misses: router.counters.get("misses"),
            cache_size: router.cache().len(),
            mean_batch_size: batcher.mean_batch_size(),
            latency_table: router.latency.table(),
            cost_dollars: router.ledger.dollars(&router.config.cost),
            baseline_dollars: router.ledger.baseline_dollars(&router.config.cost),
            persist_enabled: persist.is_some(),
            persist_generation: persist.map_or(0, |p| p.generation),
            wal_bytes: persist.map_or(0, |p| p.wal_bytes),
            wal_records: persist.map_or(0, |p| p.wal_records),
            compactions: persist.map_or(0, |p| p.compactions),
            last_compaction_unix: persist.map_or(0, |p| p.last_compaction_unix),
            recovered_entries: router
                .recovery
                .as_ref()
                .map_or(0, |r| r.recovered_entries),
        }
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}
