//! Cost model: per-token API pricing, the basis of the paper's §5.2.3 cost
//! analysis ("25x API cost difference between our LLM pair").

use crate::config::CostConfig;

/// Which model served (part of) a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelRole {
    Big,
    Small,
}

/// Token accounting for one request.
#[derive(Clone, Copy, Debug, Default)]
pub struct TokenUsage {
    pub input_tokens: usize,
    pub output_tokens: usize,
}

#[derive(Clone, Debug, Default)]
pub struct CostLedger {
    pub big: TokenUsage,
    pub small: TokenUsage,
    pub requests_big: u64,
    pub requests_small: u64,
    pub requests_free: u64, // exact-match fast path: no model invoked
}

impl CostLedger {
    pub fn record(&mut self, role: ModelRole, usage: TokenUsage) {
        match role {
            ModelRole::Big => {
                self.big.input_tokens += usage.input_tokens;
                self.big.output_tokens += usage.output_tokens;
                self.requests_big += 1;
            }
            ModelRole::Small => {
                self.small.input_tokens += usage.input_tokens;
                self.small.output_tokens += usage.output_tokens;
                self.requests_small += 1;
            }
        }
    }

    pub fn record_free(&mut self) {
        self.requests_free += 1;
    }

    /// Dollar cost under the given pricing.
    pub fn dollars(&self, c: &CostConfig) -> f64 {
        let per_tok_big = c.big_per_mtok / 1e6;
        let per_tok_small = c.small_per_mtok / 1e6;
        self.big.output_tokens as f64 * per_tok_big
            + self.big.input_tokens as f64 * per_tok_big * c.input_frac
            + self.small.output_tokens as f64 * per_tok_small
            + self.small.input_tokens as f64 * per_tok_small * c.input_frac
    }

    /// Cost of serving *everything* with the Big LLM (the no-cache
    /// baseline the paper normalizes against).
    pub fn baseline_dollars(&self, c: &CostConfig) -> f64 {
        let per_tok_big = c.big_per_mtok / 1e6;
        let out = self.big.output_tokens + self.small.output_tokens;
        // Baseline input = just the raw queries; approximate with the big
        // pathway's observed per-request input and the small pathway's
        // query-only share (the tweak prompt inflates small inputs by the
        // cached Q/R, which the baseline would not send).
        let inp = self.big.input_tokens + self.small.input_tokens / 3;
        out as f64 * per_tok_big + inp as f64 * per_tok_big * c.input_frac
    }

    /// Fraction of the no-cache cost actually spent (paper: LMSYS 35%,
    /// WildChat 61%).
    pub fn cost_ratio(&self, c: &CostConfig) -> f64 {
        let base = self.baseline_dollars(c);
        if base <= 0.0 {
            return 1.0;
        }
        self.dollars(c) / base
    }

    pub fn total_requests(&self) -> u64 {
        self.requests_big + self.requests_small + self.requests_free
    }
}

/// Closed-form cost ratio given a hit rate (used by the analytical part of
/// the §5.2.3 bench): hits cost `1/ratio`, misses cost 1.
pub fn analytic_cost_ratio(hit_rate: f64, price_ratio: f64) -> f64 {
    (1.0 - hit_rate) + hit_rate / price_ratio
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CostConfig {
        CostConfig { big_per_mtok: 10.0, small_per_mtok: 0.4, input_frac: 0.25 }
    }

    #[test]
    fn ledger_accumulates() {
        let mut l = CostLedger::default();
        l.record(ModelRole::Big, TokenUsage { input_tokens: 100, output_tokens: 50 });
        l.record(ModelRole::Small, TokenUsage { input_tokens: 300, output_tokens: 50 });
        l.record_free();
        assert_eq!(l.total_requests(), 3);
        assert_eq!(l.big.output_tokens, 50);
        assert_eq!(l.small.input_tokens, 300);
    }

    #[test]
    fn small_pathway_is_cheaper() {
        let c = cfg();
        let mut all_big = CostLedger::default();
        all_big.record(ModelRole::Big, TokenUsage { input_tokens: 100, output_tokens: 100 });
        let mut all_small = CostLedger::default();
        all_small.record(ModelRole::Small, TokenUsage { input_tokens: 100, output_tokens: 100 });
        assert!(all_small.dollars(&c) < all_big.dollars(&c) / 20.0);
    }

    #[test]
    fn analytic_matches_paper_shape() {
        // paper: LMSYS 68% hits above 0.8 → ~35% of original cost
        let r = analytic_cost_ratio(0.68, 25.0);
        assert!((r - 0.347).abs() < 0.01, "r={r}");
        // WildChat 40% hits → ~61%
        let r = analytic_cost_ratio(0.40, 25.0);
        assert!((r - 0.616).abs() < 0.01, "r={r}");
    }

    #[test]
    fn cost_ratio_below_one_with_hits() {
        let c = cfg();
        let mut l = CostLedger::default();
        l.record(ModelRole::Big, TokenUsage { input_tokens: 50, output_tokens: 100 });
        l.record(ModelRole::Small, TokenUsage { input_tokens: 150, output_tokens: 100 });
        assert!(l.cost_ratio(&c) < 1.0);
    }
}
