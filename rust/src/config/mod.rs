//! Typed configuration for the whole stack, with a TOML-subset file loader
//! and CLI overrides. The `paper` preset matches Table 1 of the paper.
//!
//! The file format supports the subset of TOML we need: `[section]` headers,
//! `key = value` with string / number / boolean values, and `#` comments.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::cache::{EvictionPolicy, IndexKind, IndexOpts, PersistConfig, Quantization};

/// Routing + cache + model configuration (Fig 1 + Table 1).
#[derive(Clone, Debug)]
pub struct Config {
    /// Cosine similarity threshold for the hit pathway (Table 1: 0.7).
    pub similarity_threshold: f32,
    /// Top-k candidates retrieved from the vector DB.
    pub top_k: usize,
    /// Exact-match fast path (§6.1): return cached response verbatim when
    /// the normalized query text is identical.
    pub exact_match_fast_path: bool,
    /// Vector index family (Table 1: IVF_FLAT).
    pub index: IndexConfig,
    /// Eviction (paper: append-only, i.e. None).
    pub eviction: EvictionConfig,
    /// Dynamic batcher.
    pub batcher: BatcherConfig,
    /// Interleaved decode scheduler (continuous batching on the engine
    /// thread): live generations advance round-robin so tweak-hits complete
    /// while Big-LLM misses are still decoding.
    pub scheduler: SchedulerConfig,
    /// Generation settings per model role.
    pub big_llm: GenConfig,
    pub small_llm: GenConfig,
    /// Cost model: API price ratio (Table 1: ~25x per output token).
    pub cost: CostConfig,
    /// Durable cache persistence (snapshots + WAL). Disabled by default
    /// (the paper's deployment is ephemeral); set `persist.data_dir` to
    /// enable warm restarts.
    pub persist: PersistConfig,
    /// Per-request span tracing (ring buffer, slow-request list, per-stage
    /// histograms; surfaced via the `trace`/`stats` server verbs).
    pub trace: TraceConfig,
    /// Fault tolerance: per-request deadlines, the degradation ladder, and
    /// circuit breakers around each backend (DESIGN.md "Failure domains").
    pub faults: FaultsConfig,
    /// Front-end listeners beyond the TCP line protocol (`[server]`).
    pub server: ServerConfig,
    /// Artifact directory.
    pub artifact_dir: String,
    /// Keep decode state (KV caches) on device between steps, fetching only
    /// logits / span tokens per step (DESIGN.md §Perf L2). Automatically
    /// falls back to the literal transport when the artifact set predates
    /// the packed-state convention; `false` pins the literal path.
    pub device_resident: bool,
    /// Byte budget of the small model's cross-request KV prefix cache
    /// (DESIGN.md "KV prefix cache"): post-prefill snapshots of the static
    /// tweak-prompt head are stored in a radix tree and resumed on later
    /// tweaks sharing the prefix. LRU-evicted over this budget; 0 disables.
    /// Automatically off when the artifact set has no `prefill_resume`
    /// chunks.
    pub prefix_cache_bytes: usize,
    /// Master seed for all deterministic randomness.
    pub seed: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct IndexConfig {
    pub kind: IndexKindConfig,
    pub nlist: usize,
    pub nprobe: usize,
    /// Parallel scan shards (worker threads); 1 = single-threaded scan.
    pub shards: usize,
    /// Row storage mode: exact f32 or SQ8 (u8 codes + exact re-rank).
    pub quantization: Quantization,
    /// Rewrite a segment once this fraction of its rows is tombstoned
    /// (reclaims evicted rows' memory); `<= 0` disables compaction.
    pub compact_tombstone_frac: f32,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexKindConfig {
    Flat,
    IvfFlat,
}

#[derive(Clone, Copy, Debug)]
pub struct EvictionConfig {
    pub policy: EvictionPolicy,
    pub capacity: usize,
    pub ttl_ticks: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum embed micro-batch (must be <= largest compiled variant).
    pub max_batch: usize,
    /// Maximum time a request waits for batch-mates.
    pub max_wait_micros: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// `false` restores run-to-completion routing: each drained request
    /// finishes its whole generation before the next starts (head-of-line
    /// blocking; the pre-scheduler behavior, kept for A/B benchmarking).
    pub enabled: bool,
    /// Sessions decoding concurrently on the engine thread; admissions
    /// beyond this queue (FIFO) until a slot frees. Bounds resident decode
    /// state held at once.
    pub max_concurrent_sessions: usize,
    /// Decode units (`LlmSession::advance` calls) each live session gets
    /// per round-robin turn. 1 = fully fair interleave; larger values trade
    /// tweak-hit latency for fewer cross-session switches.
    pub fairness_steps: usize,
    /// Slot budget for batched resident decode (per model): sessions claim
    /// slots in a shared device buffer and ONE masked dispatch per fairness
    /// round advances all of them. The runtime picks the largest compiled
    /// `{m}_decode_batch{B}_res` bucket with `B <= decode_batch`; 0 — or an
    /// artifact set predating batched decode — falls back to per-session
    /// dispatch. When the artifact set CAN batch at this budget, span
    /// fusion is pinned off (the batched sampling path is single-step;
    /// responses must not depend on slot placement); pre-batched artifact
    /// dirs keep span fusion and today's outputs.
    pub decode_batch: usize,
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Collect per-request span traces. Off = zero per-request tracing work
    /// (disabled builders are no-ops and nothing is retained).
    pub enabled: bool,
    /// Completed traces kept in the in-memory ring buffer.
    pub ring_capacity: usize,
    /// Requests with total latency at or above this land in the slow-request
    /// retention list (survives ring eviction); `<= 0` disables the list.
    pub slow_threshold_ms: f64,
    /// When non-empty, completed traces are appended as JSONL to
    /// `<export_dir>/traces.jsonl` (`serve --trace-dir`).
    pub export_dir: String,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            enabled: true,
            ring_capacity: 256,
            slow_threshold_ms: 250.0,
            export_dir: String::new(),
        }
    }
}

/// `[faults]` section: degradation ladder + breaker tuning. All timeouts
/// use 0 as "unbounded" so the layer can be tightened knob by knob.
#[derive(Clone, Copy, Debug)]
pub struct FaultsConfig {
    /// Master switch. Off = no deadline checks, no breakers, no retries:
    /// the exact pre-fault-layer behavior (kept for A/B overhead runs).
    pub enabled: bool,
    /// Per-request end-to-end deadline, stamped at `EngineHandle::request`
    /// submission time and checked at stage boundaries (flush, session
    /// start, each decode round). Expired requests are shed with a
    /// structured error — or degraded to the raw cached response when one
    /// is in hand. 0 = no deadline.
    pub request_deadline_ms: u64,
    /// Budget for a single tweak generation (session start → EOS). A tweak
    /// that overruns is degraded to the raw cached response mid-decode and
    /// its slot freed. Catches hangs the deadline alone would let occupy a
    /// slot. 0 = unbounded.
    pub tweak_timeout_ms: u64,
    /// Budget for a single miss (Big-LLM) generation. Overruns fail the
    /// request (subject to retry). 0 = unbounded.
    pub generation_timeout_ms: u64,
    /// Extra attempts for a failed Big-LLM miss generation. Retries re-begin
    /// the session, and per-request RNG substreams make a successful retry
    /// bit-identical to a first-try success.
    pub miss_retries: usize,
    /// Base backoff before a miss retry; attempt `n` waits `n * backoff`.
    pub retry_backoff_ms: u64,
    /// Rolling outcome window per breaker (last N calls).
    pub breaker_window: usize,
    /// Failure fraction within the window that trips the breaker open.
    pub breaker_failure_ratio: f32,
    /// Outcomes required in the window before the ratio is meaningful; the
    /// breaker never opens on fewer samples.
    pub breaker_min_samples: usize,
    /// How long an open breaker rejects before allowing half-open probes.
    pub breaker_open_ms: u64,
    /// Consecutive probe successes needed to close from half-open.
    pub breaker_half_open_probes: usize,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            enabled: true,
            request_deadline_ms: 0,
            tweak_timeout_ms: 0,
            generation_timeout_ms: 0,
            miss_retries: 2,
            retry_backoff_ms: 5,
            breaker_window: 32,
            breaker_failure_ratio: 0.5,
            breaker_min_samples: 8,
            breaker_open_ms: 250,
            breaker_half_open_probes: 2,
        }
    }
}

/// `[server]` section: the optional HTTP/SSE front end riding beside the
/// TCP line protocol.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerConfig {
    /// Port for the OpenAI-compatible HTTP endpoint
    /// (`POST /v1/chat/completions`, SSE streaming when `"stream": true`).
    /// 0 disables the listener (the default: TCP line protocol only).
    pub http_port: u16,
}

#[derive(Clone, Copy, Debug)]
pub struct GenConfig {
    pub temperature: f32,
    pub top_k: usize,
    pub max_new_tokens: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct CostConfig {
    /// $ per 1M output tokens for the Big LLM (GPT-4o ballpark).
    pub big_per_mtok: f64,
    /// $ per 1M output tokens for the Small LLM (Llama 3.1 8B ballpark;
    /// 25x cheaper per Table 1).
    pub small_per_mtok: f64,
    /// Input tokens priced at this fraction of output tokens.
    pub input_frac: f64,
}

impl Default for Config {
    fn default() -> Self {
        Config::paper()
    }
}

impl Config {
    /// Table 1 of the paper.
    pub fn paper() -> Config {
        Config {
            similarity_threshold: 0.7,
            top_k: 1,
            exact_match_fast_path: false, // paper's implementation: tweak all hits
            index: IndexConfig {
                kind: IndexKindConfig::IvfFlat,
                nlist: 64,
                nprobe: 8,
                shards: 1,
                quantization: Quantization::None,
                compact_tombstone_frac: 0.3,
            },
            eviction: EvictionConfig {
                policy: EvictionPolicy::None,
                capacity: usize::MAX,
                ttl_ticks: u64::MAX,
            },
            batcher: BatcherConfig { max_batch: 32, max_wait_micros: 2_000 },
            scheduler: SchedulerConfig {
                enabled: true,
                max_concurrent_sessions: 8,
                fairness_steps: 1,
                decode_batch: 8,
            },
            big_llm: GenConfig { temperature: 1.0, top_k: 40, max_new_tokens: 48 },
            small_llm: GenConfig { temperature: 1.0, top_k: 40, max_new_tokens: 48 },
            cost: CostConfig {
                // GPT-4o: $10/M output; Llama 3.1 8B: $0.40/M output ≈ 25x.
                big_per_mtok: 10.0,
                small_per_mtok: 0.40,
                input_frac: 0.25,
            },
            persist: PersistConfig::default(),
            trace: TraceConfig::default(),
            faults: FaultsConfig::default(),
            server: ServerConfig::default(),
            artifact_dir: "artifacts".to_string(),
            device_resident: true,
            prefix_cache_bytes: 64 << 20,
            seed: 20250923,
        }
    }

    /// Fast preset for tests: FLAT index, tiny generations.
    pub fn test() -> Config {
        let mut c = Config::paper();
        c.index.kind = IndexKindConfig::Flat;
        c.big_llm.max_new_tokens = 8;
        c.small_llm.max_new_tokens = 8;
        c
    }

    pub fn index_kind(&self) -> IndexKind {
        match self.index.kind {
            IndexKindConfig::Flat => IndexKind::Flat,
            IndexKindConfig::IvfFlat => IndexKind::IvfFlat {
                nlist: self.index.nlist,
                nprobe: self.index.nprobe,
            },
        }
    }

    /// Index storage tuning derived from the `[index]` section.
    pub fn index_opts(&self) -> IndexOpts {
        IndexOpts {
            quantization: self.index.quantization,
            compact_tombstone_frac: self.index.compact_tombstone_frac,
            ..IndexOpts::default()
        }
    }

    /// Load from a TOML-subset file and apply on top of the paper preset.
    pub fn from_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let kv = parse_toml_subset(&text)?;
        let mut c = Config::paper();
        c.apply(&kv)?;
        Ok(c)
    }

    /// Apply `section.key -> value` overrides.
    pub fn apply(&mut self, kv: &BTreeMap<String, String>) -> Result<()> {
        for (key, val) in kv {
            self.set(key, val)
                .with_context(|| format!("config key {key:?} = {val:?}"))?;
        }
        Ok(())
    }

    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        let f = || -> Result<f64> { val.parse().map_err(|_| anyhow!("not a number")) };
        let u = || -> Result<usize> { val.parse().map_err(|_| anyhow!("not an integer")) };
        let b = || -> Result<bool> { val.parse().map_err(|_| anyhow!("not a bool")) };
        match key {
            "router.similarity_threshold" => self.similarity_threshold = f()? as f32,
            "router.top_k" => self.top_k = u()?,
            "router.exact_match_fast_path" => self.exact_match_fast_path = b()?,
            "index.kind" => {
                self.index.kind = match val {
                    "flat" => IndexKindConfig::Flat,
                    "ivf_flat" => IndexKindConfig::IvfFlat,
                    _ => bail!("unknown index kind (flat|ivf_flat)"),
                }
            }
            "index.nlist" => self.index.nlist = u()?,
            "index.nprobe" => self.index.nprobe = u()?,
            "index.shards" => {
                let n = u()?;
                if n == 0 {
                    bail!("index.shards must be >= 1");
                }
                self.index.shards = n;
            }
            "index.quantization" => {
                self.index.quantization = Quantization::parse(val)
                    .ok_or_else(|| anyhow!("unknown quantization (none|sq8)"))?
            }
            "index.compact_tombstone_frac" => {
                let frac = f()? as f32;
                if frac > 1.0 {
                    bail!("compact_tombstone_frac must be <= 1.0");
                }
                self.index.compact_tombstone_frac = frac;
            }
            "eviction.policy" => {
                self.eviction.policy = EvictionPolicy::parse(val)
                    .ok_or_else(|| anyhow!("unknown eviction policy"))?
            }
            "eviction.capacity" => self.eviction.capacity = u()?,
            "eviction.ttl_ticks" => self.eviction.ttl_ticks = u()? as u64,
            "batcher.max_batch" => self.batcher.max_batch = u()?,
            "batcher.max_wait_micros" => self.batcher.max_wait_micros = u()? as u64,
            "scheduler.enabled" => self.scheduler.enabled = b()?,
            "scheduler.max_concurrent_sessions" => {
                let n = u()?;
                if n == 0 {
                    bail!("scheduler.max_concurrent_sessions must be >= 1");
                }
                self.scheduler.max_concurrent_sessions = n;
            }
            "scheduler.fairness_steps" => {
                let n = u()?;
                if n == 0 {
                    bail!("scheduler.fairness_steps must be >= 1");
                }
                self.scheduler.fairness_steps = n;
            }
            // 0 = per-session dispatch (batched decode off)
            "scheduler.decode_batch" => self.scheduler.decode_batch = u()?,
            "big_llm.temperature" => self.big_llm.temperature = f()? as f32,
            "big_llm.top_k" => self.big_llm.top_k = u()?,
            "big_llm.max_new_tokens" => self.big_llm.max_new_tokens = u()?,
            "small_llm.temperature" => self.small_llm.temperature = f()? as f32,
            "small_llm.top_k" => self.small_llm.top_k = u()?,
            "small_llm.max_new_tokens" => self.small_llm.max_new_tokens = u()?,
            "cost.big_per_mtok" => self.cost.big_per_mtok = f()?,
            "cost.small_per_mtok" => self.cost.small_per_mtok = f()?,
            "cost.input_frac" => self.cost.input_frac = f()?,
            "trace.enabled" => self.trace.enabled = b()?,
            "trace.ring_capacity" => {
                let n = u()?;
                if n == 0 {
                    bail!("trace.ring_capacity must be >= 1");
                }
                self.trace.ring_capacity = n;
            }
            "trace.slow_threshold_ms" => self.trace.slow_threshold_ms = f()?,
            "trace.export_dir" => self.trace.export_dir = val.to_string(),
            "faults.enabled" => self.faults.enabled = b()?,
            "faults.request_deadline_ms" => {
                self.faults.request_deadline_ms = u()? as u64
            }
            "faults.tweak_timeout_ms" => self.faults.tweak_timeout_ms = u()? as u64,
            "faults.generation_timeout_ms" => {
                self.faults.generation_timeout_ms = u()? as u64
            }
            "faults.miss_retries" => self.faults.miss_retries = u()?,
            "faults.retry_backoff_ms" => self.faults.retry_backoff_ms = u()? as u64,
            "faults.breaker_window" => {
                let n = u()?;
                if n == 0 {
                    bail!("faults.breaker_window must be >= 1");
                }
                self.faults.breaker_window = n;
            }
            "faults.breaker_failure_ratio" => {
                let r = f()? as f32;
                if !(0.0..=1.0).contains(&r) {
                    bail!("faults.breaker_failure_ratio must be in [0, 1]");
                }
                self.faults.breaker_failure_ratio = r;
            }
            "faults.breaker_min_samples" => self.faults.breaker_min_samples = u()?,
            "faults.breaker_open_ms" => self.faults.breaker_open_ms = u()? as u64,
            "faults.breaker_half_open_probes" => {
                let n = u()?;
                if n == 0 {
                    bail!("faults.breaker_half_open_probes must be >= 1");
                }
                self.faults.breaker_half_open_probes = n;
            }
            // 0 = HTTP front end off (TCP line protocol only)
            "server.http_port" => self.server.http_port = u()? as u16,
            "persist.data_dir" => self.persist.data_dir = val.to_string(),
            "persist.wal_fsync" => self.persist.wal_fsync = b()?,
            "persist.compact_bytes" => self.persist.compact_bytes = u()? as u64,
            // 0 = fsync per append; >0 = group-commit window (ms)
            "persist.fsync_batch_ms" => self.persist.fsync_batch_ms = u()? as u64,
            "runtime.artifact_dir" => self.artifact_dir = val.to_string(),
            "runtime.device_resident" => self.device_resident = b()?,
            // 0 = prefix reuse off (every prefill runs cold)
            "runtime.prefix_cache_bytes" => self.prefix_cache_bytes = u()?,
            "runtime.seed" => self.seed = val.parse()?,
            _ => bail!("unknown config key"),
        }
        Ok(())
    }

    /// Render as Table 1-style rows (for `tweakllm config`).
    pub fn table(&self) -> Vec<(String, String)> {
        vec![
            ("Big LLM".into(), format!("substrate decoder 'big' (temp {}, top-k {}, max {} tok)", self.big_llm.temperature, self.big_llm.top_k, self.big_llm.max_new_tokens)),
            ("Small LLM".into(), format!("substrate decoder 'small' (temp {}, top-k {}, max {} tok; {:.0}x cheaper/ tok)", self.small_llm.temperature, self.small_llm.top_k, self.small_llm.max_new_tokens, self.cost.big_per_mtok / self.cost.small_per_mtok)),
            ("Embedding Model".into(), "substrate encoder, 384-dim, L2-normalized".into()),
            ("Vector Database".into(), {
                let base = match self.index.kind {
                    IndexKindConfig::Flat => "in-process FLAT (exact scan)".to_string(),
                    IndexKindConfig::IvfFlat => format!("in-process IVF_FLAT (nlist {}, nprobe {})", self.index.nlist, self.index.nprobe),
                };
                let quant = match self.index.quantization {
                    Quantization::None => "f32",
                    Quantization::Sq8 => "SQ8 + exact re-rank",
                };
                format!("{base}, {quant}, {} scan shard{}", self.index.shards, if self.index.shards == 1 { "" } else { "s" })
            }),
            ("Similarity Threshold".into(), self.similarity_threshold.to_string()),
            ("Eviction".into(), format!("{:?} (capacity {})", self.eviction.policy, if self.eviction.capacity == usize::MAX { "unbounded".into() } else { self.eviction.capacity.to_string() })),
            ("Persistence".into(), if self.persist.enabled() {
                let fsync = if self.persist.wal_fsync && self.persist.fsync_batch_ms > 0 {
                    format!("batched {} ms", self.persist.fsync_batch_ms)
                } else {
                    self.persist.wal_fsync.to_string()
                };
                format!("WAL+snapshots in {} (fsync {fsync}, compact at {} MiB)", self.persist.data_dir, self.persist.compact_bytes / (1024 * 1024))
            } else {
                "disabled (ephemeral, as in the paper)".into()
            }),
            ("Decode scheduler".into(), if self.scheduler.enabled {
                let batch = if self.scheduler.decode_batch > 0 {
                    format!(", batched decode ≤ {} slots", self.scheduler.decode_batch)
                } else {
                    ", per-session dispatch".into()
                };
                format!("interleaved ({} concurrent sessions, {} step{}/turn{batch})", self.scheduler.max_concurrent_sessions, self.scheduler.fairness_steps, if self.scheduler.fairness_steps == 1 { "" } else { "s" })
            } else {
                "run-to-completion (head-of-line blocking)".into()
            }),
            ("Tracing".into(), if self.trace.enabled {
                let export = if self.trace.export_dir.is_empty() {
                    String::new()
                } else {
                    format!(", JSONL export to {}", self.trace.export_dir)
                };
                format!("per-request spans, ring {} (slow ≥ {} ms{export})", self.trace.ring_capacity, self.trace.slow_threshold_ms)
            } else {
                "disabled".into()
            }),
            ("Fault tolerance".into(), if self.faults.enabled {
                let deadline = if self.faults.request_deadline_ms > 0 {
                    format!("{} ms deadline", self.faults.request_deadline_ms)
                } else {
                    "no deadline".into()
                };
                format!(
                    "{deadline}, {} miss retr{}, breakers {}/{} @ {:.0}%",
                    self.faults.miss_retries,
                    if self.faults.miss_retries == 1 { "y" } else { "ies" },
                    self.faults.breaker_min_samples,
                    self.faults.breaker_window,
                    self.faults.breaker_failure_ratio * 100.0
                )
            } else {
                "disabled (fail-through, no degradation)".into()
            }),
            ("KV prefix cache".into(), if self.prefix_cache_bytes > 0 {
                format!(
                    "cross-request tweak prefill reuse, {} MiB LRU",
                    self.prefix_cache_bytes >> 20
                )
            } else {
                "disabled (cold prefill every session)".into()
            }),
            ("Decode transport".into(), if self.device_resident {
                "device-resident KV (literal fallback for old artifact sets)".into()
            } else {
                "host literals (KV round-trips every step)".into()
            }),
            ("HTTP front end".into(), if self.server.http_port > 0 {
                format!(
                    "OpenAI-compatible /v1/chat/completions with SSE streaming on port {}",
                    self.server.http_port
                )
            } else {
                "disabled (TCP line protocol only)".into()
            }),
        ]
    }
}

/// Parse the TOML subset: sections, scalar keys, comments.
pub fn parse_toml_subset(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(sec) = line.strip_prefix('[') {
            let sec = sec
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: bad section header", lineno + 1))?;
            section = sec.trim().to_string();
        } else if let Some((k, v)) = line.split_once('=') {
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let v = v.trim().trim_matches('"').to_string();
            out.insert(key, v);
        } else {
            bail!("line {}: expected key = value", lineno + 1);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_matches_table1() {
        let c = Config::paper();
        assert_eq!(c.similarity_threshold, 0.7);
        assert!((c.cost.big_per_mtok / c.cost.small_per_mtok - 25.0).abs() < 1e-9);
        assert_eq!(c.index.kind, IndexKindConfig::IvfFlat);
    }

    #[test]
    fn toml_subset_parses() {
        let kv = parse_toml_subset(
            "# comment\n[router]\nsimilarity_threshold = 0.8\ntop_k = 3\n\n[index]\nkind = \"flat\"\n",
        )
        .unwrap();
        assert_eq!(kv["router.similarity_threshold"], "0.8");
        assert_eq!(kv["index.kind"], "flat");
    }

    #[test]
    fn apply_overrides() {
        let mut c = Config::paper();
        let mut kv = BTreeMap::new();
        kv.insert("router.similarity_threshold".to_string(), "0.85".to_string());
        kv.insert("index.kind".to_string(), "flat".to_string());
        c.apply(&kv).unwrap();
        assert_eq!(c.similarity_threshold, 0.85);
        assert_eq!(c.index.kind, IndexKindConfig::Flat);
    }

    #[test]
    fn persist_section_applies() {
        let mut c = Config::paper();
        assert!(!c.persist.enabled());
        let mut kv = BTreeMap::new();
        kv.insert("persist.data_dir".to_string(), "/tmp/cache".to_string());
        kv.insert("persist.wal_fsync".to_string(), "true".to_string());
        kv.insert("persist.compact_bytes".to_string(), "1048576".to_string());
        kv.insert("persist.fsync_batch_ms".to_string(), "25".to_string());
        c.apply(&kv).unwrap();
        assert!(c.persist.enabled());
        assert_eq!(c.persist.data_dir, "/tmp/cache");
        assert!(c.persist.wal_fsync);
        assert_eq!(c.persist.compact_bytes, 1_048_576);
        assert_eq!(c.persist.fsync_batch_ms, 25);
        let rows = c.table();
        assert!(rows.iter().any(|(k, v)| k == "Persistence" && v.contains("/tmp/cache")));
        assert!(rows.iter().any(|(k, v)| k == "Persistence" && v.contains("batched 25 ms")));
    }

    #[test]
    fn index_section_applies() {
        let mut c = Config::paper();
        let mut kv = BTreeMap::new();
        kv.insert("index.shards".to_string(), "8".to_string());
        kv.insert("index.quantization".to_string(), "sq8".to_string());
        kv.insert("index.compact_tombstone_frac".to_string(), "0.25".to_string());
        c.apply(&kv).unwrap();
        assert_eq!(c.index.shards, 8);
        assert_eq!(c.index.quantization, Quantization::Sq8);
        assert!((c.index.compact_tombstone_frac - 0.25).abs() < 1e-6);
        let opts = c.index_opts();
        assert_eq!(opts.quantization, Quantization::Sq8);
        assert!(c.set("index.shards", "0").is_err());
        assert!(c.set("index.quantization", "pq").is_err());
        assert!(c.set("index.compact_tombstone_frac", "1.5").is_err());
        let rows = c.table();
        assert!(rows.iter().any(|(k, v)| k == "Vector Database" && v.contains("SQ8")));
    }

    #[test]
    fn scheduler_section_applies() {
        let mut c = Config::paper();
        assert!(c.scheduler.enabled);
        assert_eq!(c.scheduler.max_concurrent_sessions, 8);
        assert_eq!(c.scheduler.fairness_steps, 1);
        assert_eq!(c.scheduler.decode_batch, 8);
        let mut kv = BTreeMap::new();
        kv.insert("scheduler.enabled".to_string(), "false".to_string());
        kv.insert("scheduler.max_concurrent_sessions".to_string(), "4".to_string());
        kv.insert("scheduler.fairness_steps".to_string(), "2".to_string());
        kv.insert("scheduler.decode_batch".to_string(), "0".to_string());
        c.apply(&kv).unwrap();
        assert!(!c.scheduler.enabled);
        assert_eq!(c.scheduler.max_concurrent_sessions, 4);
        assert_eq!(c.scheduler.fairness_steps, 2);
        assert_eq!(c.scheduler.decode_batch, 0, "0 must be accepted (disable)");
        assert!(c.set("scheduler.max_concurrent_sessions", "0").is_err());
        assert!(c.set("scheduler.fairness_steps", "0").is_err());
        let row = |c: &Config| -> String {
            for (k, v) in c.table() {
                if k == "Decode scheduler" {
                    return v;
                }
            }
            panic!("missing Decode scheduler row");
        };
        assert!(row(&c).contains("run-to-completion"));
        c.set("scheduler.enabled", "true").unwrap();
        assert!(row(&c).contains("4 concurrent"));
        assert!(row(&c).contains("per-session dispatch"));
        c.set("scheduler.decode_batch", "4").unwrap();
        assert!(row(&c).contains("batched decode ≤ 4 slots"));
    }

    #[test]
    fn runtime_device_resident_applies() {
        let mut c = Config::paper();
        assert!(c.device_resident);
        c.set("runtime.device_resident", "false").unwrap();
        assert!(!c.device_resident);
        assert!(c.set("runtime.device_resident", "maybe").is_err());
        let rows = c.table();
        assert!(rows.iter().any(|(k, v)| k == "Decode transport" && v.contains("literal")));
    }

    #[test]
    fn runtime_prefix_cache_bytes_applies() {
        let mut c = Config::paper();
        assert_eq!(c.prefix_cache_bytes, 64 << 20);
        let row = |c: &Config| -> String {
            c.table()
                .into_iter()
                .find(|(k, _)| k == "KV prefix cache")
                .map(|(_, v)| v)
                .unwrap()
        };
        assert!(row(&c).contains("64 MiB"));
        c.set("runtime.prefix_cache_bytes", "0").unwrap();
        assert_eq!(c.prefix_cache_bytes, 0, "0 must be accepted (disable)");
        assert!(row(&c).contains("disabled"));
        c.set("runtime.prefix_cache_bytes", "1048576").unwrap();
        assert_eq!(c.prefix_cache_bytes, 1 << 20);
        assert!(c.set("runtime.prefix_cache_bytes", "lots").is_err());
    }

    #[test]
    fn trace_section_applies() {
        let mut c = Config::paper();
        assert!(c.trace.enabled);
        assert_eq!(c.trace.ring_capacity, 256);
        assert!(c.trace.export_dir.is_empty());
        let mut kv = BTreeMap::new();
        kv.insert("trace.enabled".to_string(), "false".to_string());
        kv.insert("trace.ring_capacity".to_string(), "64".to_string());
        kv.insert("trace.slow_threshold_ms".to_string(), "50".to_string());
        kv.insert("trace.export_dir".to_string(), "/tmp/traces".to_string());
        c.apply(&kv).unwrap();
        assert!(!c.trace.enabled);
        assert_eq!(c.trace.ring_capacity, 64);
        assert!((c.trace.slow_threshold_ms - 50.0).abs() < 1e-9);
        assert_eq!(c.trace.export_dir, "/tmp/traces");
        assert!(c.set("trace.ring_capacity", "0").is_err());
        let rows = c.table();
        assert!(rows.iter().any(|(k, v)| k == "Tracing" && v.contains("disabled")));
        c.set("trace.enabled", "true").unwrap();
        let rows = c.table();
        assert!(rows.iter().any(|(k, v)| k == "Tracing" && v.contains("/tmp/traces")));
    }

    #[test]
    fn faults_section_applies() {
        let mut c = Config::paper();
        assert!(c.faults.enabled);
        assert_eq!(c.faults.request_deadline_ms, 0);
        assert_eq!(c.faults.miss_retries, 2);
        let mut kv = BTreeMap::new();
        kv.insert("faults.request_deadline_ms".to_string(), "750".to_string());
        kv.insert("faults.tweak_timeout_ms".to_string(), "100".to_string());
        kv.insert("faults.generation_timeout_ms".to_string(), "400".to_string());
        kv.insert("faults.miss_retries".to_string(), "3".to_string());
        kv.insert("faults.retry_backoff_ms".to_string(), "10".to_string());
        kv.insert("faults.breaker_window".to_string(), "16".to_string());
        kv.insert("faults.breaker_failure_ratio".to_string(), "0.75".to_string());
        kv.insert("faults.breaker_min_samples".to_string(), "4".to_string());
        kv.insert("faults.breaker_open_ms".to_string(), "100".to_string());
        kv.insert("faults.breaker_half_open_probes".to_string(), "1".to_string());
        c.apply(&kv).unwrap();
        assert_eq!(c.faults.request_deadline_ms, 750);
        assert_eq!(c.faults.tweak_timeout_ms, 100);
        assert_eq!(c.faults.generation_timeout_ms, 400);
        assert_eq!(c.faults.miss_retries, 3);
        assert_eq!(c.faults.retry_backoff_ms, 10);
        assert_eq!(c.faults.breaker_window, 16);
        assert!((c.faults.breaker_failure_ratio - 0.75).abs() < 1e-6);
        assert_eq!(c.faults.breaker_min_samples, 4);
        assert_eq!(c.faults.breaker_open_ms, 100);
        assert_eq!(c.faults.breaker_half_open_probes, 1);
        assert!(c.set("faults.breaker_window", "0").is_err());
        assert!(c.set("faults.breaker_failure_ratio", "1.5").is_err());
        assert!(c.set("faults.breaker_half_open_probes", "0").is_err());
        let rows = c.table();
        assert!(rows.iter().any(|(k, v)| k == "Fault tolerance" && v.contains("750 ms")));
        c.set("faults.enabled", "false").unwrap();
        let rows = c.table();
        assert!(rows.iter().any(|(k, v)| k == "Fault tolerance" && v.contains("disabled")));
    }

    #[test]
    fn server_section_applies() {
        let mut c = Config::paper();
        assert_eq!(c.server.http_port, 0, "HTTP front end must default off");
        let row = |c: &Config| -> String {
            c.table()
                .into_iter()
                .find(|(k, _)| k == "HTTP front end")
                .map(|(_, v)| v)
                .unwrap()
        };
        assert!(row(&c).contains("disabled"));
        let mut kv = BTreeMap::new();
        kv.insert("server.http_port".to_string(), "8080".to_string());
        c.apply(&kv).unwrap();
        assert_eq!(c.server.http_port, 8080);
        assert!(row(&c).contains("8080"));
        assert!(c.set("server.http_port", "not-a-port").is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = Config::paper();
        assert!(c.set("nope.nope", "1").is_err());
    }

    #[test]
    fn bad_section_rejected() {
        assert!(parse_toml_subset("[oops\nk=v").is_err());
        assert!(parse_toml_subset("just a line").is_err());
    }

    #[test]
    fn table_has_paper_components() {
        let rows = Config::paper().table();
        let keys: Vec<_> = rows.iter().map(|(k, _)| k.as_str()).collect();
        assert!(keys.contains(&"Big LLM"));
        assert!(keys.contains(&"Vector Database"));
        assert!(keys.contains(&"Similarity Threshold"));
    }
}
