//! Line-delimited-JSON TCP front-end + client.
//!
//! Protocol: one JSON object per line.
//!   → {"query": "why is coffee good for health?"}
//!   ← {"text": "...", "pathway": "tweak_hit", "similarity": 0.83,
//!      "latency_us": 1234}
//!   → {"stats": true}   ← {"requests": 10, "latency_table": "...",
//!      "stages": [{"stage": "decode", "pathway": "miss", ...}], ...}
//!   → {"admin": "snapshot"}
//!   ← {"snapshot": true, "generation": 3, "entries": 120}
//!   → {"admin": "trace", "n": 4}
//!   ← {"traces": [{"id": 7, "pathway": "tweak_hit", "spans": [...]}, ...],
//!      "slow": [...], "finished": 42, "dropped": 0}
//!
//! The server accepts any number of concurrent connections; each connection
//! thread forwards to the shared `EngineHandle` (the engine thread owns the
//! PJRT client and does the batching). Connection reads carry a short
//! timeout so idle connections observe the stop flag instead of pinning
//! their thread in a blocking read forever.
//!
//! The accept loop itself runs BLOCKING: the pre-PR-5 loop used nonblocking
//! `accept` + a 5 ms sleep poll, which quantized every cold connect by up
//! to 5 ms of added latency. Connections are now accepted the instant they
//! arrive; shutdown wakes the blocked `accept` with a self-connect
//! ([`Shutdown::signal`]).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{EngineHandle, Pathway};
use crate::trace::StageSummary;
use crate::util::Json;

pub fn pathway_str(p: Pathway) -> &'static str {
    match p {
        Pathway::ExactHit => "exact_hit",
        Pathway::TweakHit => "tweak_hit",
        Pathway::DegradedHit => "degraded_hit",
        Pathway::Miss => "miss",
    }
}

pub struct Server {
    listener: TcpListener,
    handle: EngineHandle,
    stop: Arc<AtomicBool>,
}

/// Stop handle for a serving [`Server`]: raises the stop flag AND wakes the
/// blocked `accept` with a self-connect, so shutdown is immediate without
/// the accept loop ever polling.
#[derive(Clone)]
pub struct Shutdown {
    stop: Arc<AtomicBool>,
    addr: std::net::SocketAddr,
}

impl Shutdown {
    /// Ask the server to stop serving. Idempotent; returns once the wake
    /// connection has been issued (the serve loop exits on observing it).
    pub fn signal(&self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept with a self-connect. A wildcard bind
        // address (0.0.0.0 / ::) is not portably connectable — rewrite it
        // to the matching loopback. A failure (listener already closed)
        // means the loop is past accepting — nothing to wake.
        let mut addr = self.addr;
        if addr.ip().is_unspecified() {
            match addr {
                std::net::SocketAddr::V4(_) => {
                    addr.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST))
                }
                std::net::SocketAddr::V6(_) => {
                    addr.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST))
                }
            }
        }
        if let Ok(s) = TcpStream::connect_timeout(&addr, Duration::from_secs(1)) {
            drop(s);
        }
    }
}

impl Server {
    pub fn bind(addr: &str, handle: EngineHandle) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Server { listener, handle, stop: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Handle that stops a running `serve` loop (flag + accept wake).
    pub fn shutdown_handle(&self) -> Result<Shutdown> {
        Ok(Shutdown { stop: Arc::clone(&self.stop), addr: self.listener.local_addr()? })
    }

    /// Serve until [`Shutdown::signal`]. Blocks the calling thread; every
    /// connect is accepted the moment it arrives (blocking accept — no
    /// poll-interval quantization on cold-connect latency).
    pub fn serve(&self) -> Result<()> {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    // Check AFTER accept too: the shutdown wake arrives as a
                    // connection; it (and any connect racing it) is dropped.
                    if self.stop.load(Ordering::Relaxed) {
                        return Ok(());
                    }
                    let handle = self.handle.clone();
                    let stop = Arc::clone(&self.stop);
                    thread::spawn(move || {
                        let _ = handle_connection(stream, handle, stop);
                    });
                }
                Err(e) => {
                    if self.stop.load(Ordering::Relaxed) {
                        return Ok(());
                    }
                    return Err(e.into());
                }
            }
        }
    }
}

/// How often an idle connection wakes up to poll the stop flag.
const READ_POLL_INTERVAL: std::time::Duration = std::time::Duration::from_millis(100);

/// Hard cap on one request line. Anything larger gets a structured error
/// reply (and the connection closed) instead of growing the line buffer
/// without bound.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Bound on each reply write: a stalled client (full socket buffer, dead
/// peer) errors out of the connection thread instead of pinning it forever.
const WRITE_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(5);

fn send_reply(writer: &mut TcpStream, reply: &Json) -> Result<()> {
    writer.write_all(reply.to_string().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

fn error_reply(msg: String) -> Json {
    Json::obj_from(vec![("error", Json::s(msg))])
}

fn handle_connection(
    stream: TcpStream,
    handle: EngineHandle,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // A blocking `read_line` on an idle connection would never observe the
    // stop flag (the old shutdown hang): bound every read so the loop polls.
    stream.set_read_timeout(Some(READ_POLL_INTERVAL))?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // NB: on timeout, bytes already consumed stay appended to `line`;
        // the next read_line call continues the same partial line, so slow
        // writers lose nothing.
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF: client closed
            Ok(_) => {
                if line.len() > MAX_LINE_BYTES {
                    send_reply(
                        &mut writer,
                        &error_reply(format!("request line exceeds {MAX_LINE_BYTES} bytes")),
                    )?;
                    break;
                }
                if !line.trim().is_empty() {
                    let reply = process_line(&line, &handle);
                    send_reply(&mut writer, &reply)?;
                }
                line.clear();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Bound the buffer for a line still in flight too: a client
                // streaming an endless unterminated line gets refused here,
                // not an OOM.
                if line.len() > MAX_LINE_BYTES {
                    send_reply(
                        &mut writer,
                        &error_reply(format!("request line exceeds {MAX_LINE_BYTES} bytes")),
                    )?;
                    break;
                }
                continue; // stop-flag poll point
            }
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                // read_line consumed through the newline before failing
                // UTF-8 validation, so the stream is still line-synced:
                // reply structurally and keep serving.
                send_reply(&mut writer, &error_reply("request is not valid UTF-8".into()))?;
                line.clear();
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

fn process_line(line: &str, handle: &EngineHandle) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            return Json::obj_from(vec![("error", Json::s(format!("bad json: {e}")))])
        }
    };
    if req.opt("stats").is_some() {
        return match handle.stats() {
            Ok(s) => Json::obj_from(vec![
                ("requests", Json::num(s.requests as f64)),
                ("tweak_hits", Json::num(s.tweak_hits as f64)),
                ("exact_hits", Json::num(s.exact_hits as f64)),
                ("misses", Json::num(s.misses as f64)),
                ("cache_size", Json::num(s.cache_size as f64)),
                ("mean_batch_size", Json::num(s.mean_batch_size)),
                ("active_sessions", Json::num(s.active_sessions as f64)),
                ("waiting_sessions", Json::num(s.waiting_sessions as f64)),
                ("coalesced", Json::num(s.coalesced as f64)),
                ("batched_steps", Json::num(s.batched_steps as f64)),
                ("mean_active_slots", Json::num(s.mean_active_slots)),
                ("prefix_hits", Json::num(s.prefix_hits as f64)),
                ("prefix_misses", Json::num(s.prefix_misses as f64)),
                ("prefix_evictions", Json::num(s.prefix_evictions as f64)),
                (
                    "prefix_saved_tokens",
                    Json::num(s.prefix_saved_tokens as f64),
                ),
                ("cost_dollars", Json::num(s.cost_dollars)),
                ("baseline_dollars", Json::num(s.baseline_dollars)),
                ("latency_table", Json::s(s.latency_table)),
                ("persist_enabled", Json::Bool(s.persist_enabled)),
                ("persist_generation", Json::num(s.persist_generation as f64)),
                ("wal_bytes", Json::num(s.wal_bytes as f64)),
                ("wal_records", Json::num(s.wal_records as f64)),
                ("compactions", Json::num(s.compactions as f64)),
                (
                    "last_compaction_unix",
                    Json::num(s.last_compaction_unix as f64),
                ),
                ("recovered_entries", Json::num(s.recovered_entries as f64)),
                ("stages", stage_rows(&s.stage_latency)),
                ("traces_finished", Json::num(s.traces_finished as f64)),
                ("degraded_hits", Json::num(s.degraded_hits as f64)),
                ("shed", Json::num(s.shed as f64)),
                ("failed", Json::num(s.failed as f64)),
                ("embed_bypasses", Json::num(s.embed_bypasses as f64)),
                ("miss_retries", Json::num(s.miss_retries as f64)),
                ("breaker_trips", Json::num(s.breaker_trips as f64)),
                ("breaker_embed", Json::s(s.breaker_embed)),
                ("breaker_small", Json::s(s.breaker_small)),
                ("breaker_big", Json::s(s.breaker_big)),
            ]),
            Err(e) => Json::obj_from(vec![("error", Json::s(format!("{e}")))]),
        };
    }
    if let Some(admin) = req.opt("admin") {
        return match admin.str() {
            Ok("snapshot") => match handle.snapshot() {
                Ok(r) => Json::obj_from(vec![
                    ("snapshot", Json::Bool(r.persist_enabled)),
                    ("generation", Json::num(r.generation as f64)),
                    ("entries", Json::num(r.entries as f64)),
                ]),
                Err(e) => Json::obj_from(vec![("error", Json::s(format!("{e}")))]),
            },
            Ok("trace") => {
                let n = req.opt("n").and_then(|v| v.usize().ok()).unwrap_or(16);
                match handle.traces(n) {
                    Ok(r) => Json::obj_from(vec![
                        (
                            "traces",
                            Json::Arr(r.traces.iter().map(|t| t.to_json()).collect()),
                        ),
                        (
                            "slow",
                            Json::Arr(r.slow.iter().map(|t| t.to_json()).collect()),
                        ),
                        ("finished", Json::num(r.finished as f64)),
                        ("dropped", Json::num(r.dropped as f64)),
                    ]),
                    Err(e) => Json::obj_from(vec![("error", Json::s(format!("{e}")))]),
                }
            }
            _ => Json::obj_from(vec![(
                "error",
                Json::s("unknown admin command (expected \"snapshot\" or \"trace\")"),
            )]),
        };
    }
    let query = match req.opt("query").and_then(|q| q.str().ok()) {
        Some(q) => q.to_string(),
        None => {
            return Json::obj_from(vec![(
                "error",
                Json::s("expected {\"query\": ...} or {\"stats\": true}"),
            )])
        }
    };
    match handle.request(&query) {
        Ok(r) => Json::obj_from(vec![
            ("text", Json::s(r.text)),
            ("pathway", Json::s(pathway_str(r.pathway))),
            (
                "similarity",
                r.similarity.map(|s| Json::num(s as f64)).unwrap_or(Json::Null),
            ),
            ("latency_us", Json::num(r.total_micros as f64)),
        ]),
        Err(e) => Json::obj_from(vec![("error", Json::s(format!("{e}")))]),
    }
}

/// Per-stage × per-pathway quantile rows for the `stats` verb.
fn stage_rows(rows: &[StageSummary]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj_from(vec![
                    ("stage", Json::s(r.stage)),
                    ("pathway", Json::s(r.pathway)),
                    ("n", Json::num(r.n as f64)),
                    ("p50_us", Json::num(r.p50_us)),
                    ("p90_us", Json::num(r.p90_us)),
                    ("p99_us", Json::num(r.p99_us)),
                ])
            })
            .collect(),
    )
}

/// Minimal blocking client for the line protocol (examples + tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn roundtrip(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line)
    }

    pub fn query(&mut self, text: &str) -> Result<Json> {
        self.roundtrip(&Json::obj_from(vec![("query", Json::s(text))]))
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.roundtrip(&Json::obj_from(vec![("stats", Json::Bool(true))]))
    }

    /// Ask the server to snapshot its cache now (`{"admin": "snapshot"}`).
    pub fn snapshot(&mut self) -> Result<Json> {
        self.roundtrip(&Json::obj_from(vec![("admin", Json::s("snapshot"))]))
    }

    /// Fetch the last `n` completed traces (`{"admin": "trace", "n": n}`).
    pub fn trace(&mut self, n: usize) -> Result<Json> {
        self.roundtrip(&Json::obj_from(vec![
            ("admin", Json::s("trace")),
            ("n", Json::num(n as f64)),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pathway_strings() {
        assert_eq!(pathway_str(Pathway::Miss), "miss");
        assert_eq!(pathway_str(Pathway::TweakHit), "tweak_hit");
        assert_eq!(pathway_str(Pathway::ExactHit), "exact_hit");
        assert_eq!(pathway_str(Pathway::DegradedHit), "degraded_hit");
    }

    #[test]
    fn bad_json_reports_error() {
        // process_line must not panic on garbage — build a dummy handle by
        // checking only the parse branch (no engine call happens).
        let j = Json::parse("{\"x\": 1}").unwrap();
        assert!(j.opt("query").is_none());
    }
}
